"""Equivalence suite: sort-based dispatch vs the one-hot oracle.

Three layers of coverage:

* packer level — ``_pack_sort`` must reproduce ``_pack_onehot`` bit for
  bit (send buffer, capacity mask, destinations, per-slot counts, drop
  count), including the first-come drop rule under tight capacity;
* router level — the fused Pallas softmax/top-k/histogram kernel
  (``interpret=True`` on CPU) must match the dense reference router;
* model level — a multi-device EP forward with ``dispatch_impl="sort"``
  must produce the same logits and ``MoEStats`` as ``"onehot"`` across
  top_k ∈ {1, 2}, loose/tight capacity factors, and the Token-to-Expert
  predicted-assignment mode (run in one subprocess, see
  tests/test_distributed.py for the pattern).
"""


import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.moe.dispatch import _pack_onehot, _pack_sort
from repro.moe.router import route
from tests.test_distributed import run_sub

PACK_FIELDS = ("send", "in_cap", "dest", "counts", "dropped")


@pytest.mark.parametrize("top_k", [1, 2])
@pytest.mark.parametrize("cap", [1, 8, 64])
@pytest.mark.parametrize("num_classes", [2, 16, 33])
def test_pack_sort_matches_onehot(top_k, cap, num_classes):
    rng = np.random.default_rng(top_k * 1000 + cap * 10 + num_classes)
    T, d = 96, 8
    N = T * top_k
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    token_of = jnp.arange(N, dtype=jnp.int32) // top_k
    # skewed assignment so some slots overflow the capacity
    gslot = jnp.asarray(rng.integers(0, num_classes, N) ** 2 % num_classes,
                        jnp.int32)
    for valid_frac in (1.0, 0.7):
        valid = jnp.asarray(rng.random(N) < valid_frac)
        ref = _pack_onehot(x, token_of, gslot, valid,
                           num_classes=num_classes, cap=cap)
        got = _pack_sort(x, token_of, gslot, valid,
                         num_classes=num_classes, cap=cap)
        for r, g, name in zip(ref, got, PACK_FIELDS):
            assert np.array_equal(np.asarray(r), np.asarray(g)), name


def test_pack_sort_kernel_histogram_matches_jnp():
    """`_pack_sort` with the Pallas histogram kernel (interpret=True on
    CPU) equals the pure-jnp scatter-add histogram path."""
    rng = np.random.default_rng(3)
    T, K, S, cap, d = 64, 2, 16, 8, 4
    N = T * K
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    token_of = jnp.arange(N, dtype=jnp.int32) // K
    gslot = jnp.asarray(rng.integers(0, S, N), jnp.int32)
    valid = jnp.asarray(rng.random(N) < 0.8)
    ref = _pack_sort(x, token_of, gslot, valid, num_classes=S, cap=cap,
                     use_kernel=False)
    got = _pack_sort(x, token_of, gslot, valid, num_classes=S, cap=cap,
                     use_kernel=True)
    for r, g, name in zip(ref, got, PACK_FIELDS):
        assert np.array_equal(np.asarray(r), np.asarray(g)), name


def test_pack_sort_drop_rule_is_first_come():
    """Capacity 1 with every token on one slot: only the FIRST token in
    token order survives — the drop rule both packers must share."""
    T, d, S = 16, 4, 4
    x = jnp.asarray(np.arange(T * d, dtype=np.float32).reshape(T, d))
    token_of = jnp.arange(T, dtype=jnp.int32)
    gslot = jnp.zeros((T,), jnp.int32)
    valid = jnp.ones((T,), bool)
    for pack in (_pack_onehot, _pack_sort):
        send, in_cap, _, counts, dropped = pack(
            x, token_of, gslot, valid, num_classes=S, cap=1)
        assert np.array_equal(np.asarray(in_cap),
                              [True] + [False] * (T - 1)), pack.__name__
        assert np.array_equal(np.asarray(send[0]), np.asarray(x[0]))
        assert int(dropped) == T - 1
        assert np.asarray(counts).tolist() == [1, 0, 0, 0]


@pytest.mark.parametrize("top_k", [1, 2])
def test_fused_router_matches_reference(top_k):
    rng = np.random.default_rng(top_k)
    d, E, T = 32, 8, 200
    moe = MoEConfig(num_experts=E, top_k=top_k, d_ff_expert=64)
    params = {"w": jnp.asarray(rng.normal(size=(d, E)) * 0.1, jnp.float32)}
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    ref = route(params, moe, x)
    got = route(params, moe, x, impl="fused")
    assert np.array_equal(np.asarray(ref.expert_idx), np.asarray(got.expert_idx))
    np.testing.assert_allclose(np.asarray(ref.gates), np.asarray(got.gates),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(ref.probs), np.asarray(got.probs),
                               atol=1e-6)
    np.testing.assert_allclose(float(ref.aux_loss), float(got.aux_loss),
                               rtol=1e-5)
    np.testing.assert_allclose(float(ref.z_loss), float(got.z_loss),
                               rtol=1e-5)


def test_fused_router_histogram_counts_assignments():
    """The kernel's histogram side-output equals the scatter-add of its
    own top-k assignments (the Distribution-Only predictor's input)."""
    from repro.kernels import ops as kernel_ops
    rng = np.random.default_rng(7)
    T, E, K = 300, 16, 2
    logits = jnp.asarray(rng.normal(size=(T, E)), jnp.float32)
    idx, _, _, _, counts = kernel_ops.fused_topk_route(logits, K)
    ref = np.zeros((E,), np.int64)
    np.add.at(ref, np.asarray(idx).reshape(-1), 1)
    assert np.array_equal(ref, np.asarray(counts))
    assert int(counts.sum()) == T * K


@pytest.mark.slow
def test_ep_forward_sort_matches_onehot_multidevice():
    """Full EP forward equivalence on a (2, 4) mesh across top_k,
    capacity factors (loose AND tight — identical drop decisions), and
    predicted-assignment mode with deliberately wrong predictions."""
    res = run_sub("""
        import dataclasses, itertools
        from repro.configs.registry import get_config
        from repro.models.transformer import Runtime, forward, init_model

        base = get_config("mixtral-8x7b").reduced()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4)
        out = {}
        for top_k, cap_f, predicted in itertools.product(
                (1, 2), (1.0, 8.0), (False, True)):
            cfg0 = dataclasses.replace(base, moe=dataclasses.replace(
                base.moe, top_k=top_k, capacity_factor=cap_f))
            params = init_model(jax.random.PRNGKey(0), cfg0)
            B, S = 4, 32
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (B, S), 0, cfg0.vocab_size)}
            pred = (jnp.zeros((cfg0.num_layers, B, S, top_k), jnp.int32)
                    if predicted else None)
            runs = {}
            for impl in ("onehot", "sort"):
                cfg = dataclasses.replace(cfg0, moe=dataclasses.replace(
                    cfg0.moe, dispatch_impl=impl))
                logits, _, stats = jax.jit(
                    lambda p, b, pr, c=cfg: forward(
                        p, c, b, rt, mode="train", predicted_idx=pr)
                )(params, batch, pred)
                runs[impl] = (logits, stats)
            la, sa = runs["onehot"]; lb, sb = runs["sort"]
            key = f"k{top_k}_c{cap_f}_p{int(predicted)}"
            out[key] = {
                "logits_diff": float(jnp.abs(
                    la.astype(jnp.float32) - lb.astype(jnp.float32)).max()),
                "counts_eq": bool(jnp.array_equal(sa["expert_counts"],
                                                  sb["expert_counts"])),
                "slots_eq": bool(jnp.array_equal(sa["slot_counts"],
                                                 sb["slot_counts"])),
                "dropped_a": int(np.asarray(sa["dropped"]).sum()),
                "dropped_b": int(np.asarray(sb["dropped"]).sum()),
            }
        print(json.dumps(out))
    """, timeout=1800)
    for key, r in res.items():
        assert r["counts_eq"], key
        assert r["slots_eq"], key
        assert r["dropped_a"] == r["dropped_b"], key
        assert r["logits_diff"] < 1e-5, (key, r["logits_diff"])
    # tight capacity on a skewed router must actually drop something,
    # otherwise the drop-rule legs of the suite test nothing
    assert any(r["dropped_a"] > 0 for k, r in res.items()
               if "_c1.0_" in k), res


@pytest.mark.slow
def test_ep_decode_sort_matches_onehot_multidevice():
    """Replicated-token decode dispatch: both impls agree bit-for-bit."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.models.transformer import Runtime, init_cache, init_model
        from repro.train.steps import make_decode_step

        base = get_config("mixtral-8x7b").reduced()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4)
        B = 4
        tok = jnp.ones((B, 1), jnp.int32)
        out = {}
        for impl in ("onehot", "sort"):
            cfg = dataclasses.replace(base, moe=dataclasses.replace(
                base.moe, dispatch_impl=impl))
            params = init_model(jax.random.PRNGKey(0), cfg)
            cache = init_cache(cfg, rt, B, 32)
            with mesh:
                _, logits, _, stats = jax.jit(
                    lambda p, t, c, cfg=cfg: make_decode_step(cfg, rt)(
                        p, t, c, 5))(params, tok, cache)
            out[impl] = {
                "logits": np.asarray(logits).astype(np.float64).sum().item(),
                "max": float(jnp.abs(logits).max()),
                "slots": np.asarray(stats["slot_counts"]).tolist(),
                "dropped": int(np.asarray(stats["dropped"]).sum()),
            }
        print(json.dumps({
            "sum_diff": abs(out["onehot"]["logits"] - out["sort"]["logits"]),
            "slots_eq": out["onehot"]["slots"] == out["sort"]["slots"],
            "dropped_eq": out["onehot"]["dropped"] == out["sort"]["dropped"],
        }))
    """)
    assert res["slots_eq"]
    assert res["dropped_eq"]
    assert res["sum_diff"] < 1e-4
