"""Serving engine + trainer + checkpoint + data substrate tests
(single device)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.predictors import ConditionalProbabilityModel
from repro.data.synthetic import (make_routing_trace, measured_skewness,
                                  skewed_distribution, token_batches)
from repro.models.transformer import Runtime, init_model
from repro.optim.adamw import adamw_init
from repro.optim.schedules import cosine_schedule, wsd_schedule
from repro.serve import BatchScheduler, Request, ServeConfig, ServeEngine
from repro.train import checkpoint as ckpt
from repro.train.steps import make_train_step

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# serving engine (single device: dense MoE path, estimator + replan still run)
# --------------------------------------------------------------------------

def test_engine_generate_and_estimator_updates():
    cfg = get_config("mixtral-8x7b").reduced()
    params = init_model(KEY, cfg)
    eng = ServeEngine(cfg, params, ServeConfig(strategy="dist_only",
                                               max_len=64))
    gen = token_batches(0, cfg.vocab_size, batch=2, seq_len=16)
    out, tele = eng.generate({"tokens": jnp.asarray(next(gen)["tokens"])},
                             max_new_tokens=4)
    assert out.shape == (2, 4)
    assert eng.batches_seen == 1
    dist = eng.estimator.predict()
    assert dist.shape == (cfg.num_layers, cfg.moe.num_experts)
    np.testing.assert_allclose(dist.sum(1), 1.0, atol=1e-6)


def test_engine_replan_produces_duplicates_for_skewed_estimate():
    cfg = get_config("mixtral-8x7b").reduced()
    params = init_model(KEY, cfg)
    eng = ServeEngine(cfg, params, ServeConfig(strategy="dist_only",
                                               dup_slots=1), ep_ranks=4)
    skewed = np.stack([skewed_distribution(cfg.moe.num_experts, 3.0)
                       for _ in range(cfg.num_layers)])
    eng.estimator.update(skewed * 1000)
    plan = eng.replan()
    assert int(np.asarray(plan.n_replicas).max()) >= 2


def test_scheduler_batches_and_finishes():
    sched = BatchScheduler(batch_size=4, seq_len=8)
    for rid in range(6):
        sched.submit(Request(rid, np.arange(5, dtype=np.int32),
                             max_new_tokens=2))
    b1 = sched.next_batch()
    assert b1["tokens"].shape == (4, 8) and len(b1["requests"]) == 4
    sched.finish(b1["requests"], np.zeros((4, 2), np.int32))
    b2 = sched.next_batch()
    assert len(b2["requests"]) == 2          # padded partial batch
    assert b2["tokens"].shape == (4, 8)
    sched.finish(b2["requests"], np.zeros((2, 2), np.int32))
    assert not sched.has_work() and len(sched.completed) == 6


def test_engine_token_to_expert_predictor_integration():
    cfg = get_config("mixtral-8x7b").reduced()
    params = init_model(KEY, cfg)
    tr = make_routing_trace(num_sequences=16, seq_len=16,
                            vocab=cfg.vocab_size,
                            num_experts=cfg.moe.num_experts,
                            num_layers=cfg.num_layers, skew=1.5, seed=0)
    pred = ConditionalProbabilityModel(
        cfg.num_layers, cfg.moe.num_experts, cfg.vocab_size
    ).fit(tr.experts, tr.tokens)
    eng = ServeEngine(cfg, params, ServeConfig(strategy="token_to_expert"),
                      predictor=pred)
    p = eng._predict_tokens(tr.tokens[:2])
    assert p.shape == (cfg.num_layers, 2, 16, cfg.moe.top_k)


# --------------------------------------------------------------------------
# trainer substrate
# --------------------------------------------------------------------------

def test_train_driver_loss_goes_down():
    from repro.launch.train import main
    rc = main(["--arch", "qwen1.5-0.5b", "--reduced", "--steps", "20",
               "--batch", "4", "--seq", "32", "--log-every", "50"])
    assert rc == 0


def test_schedules():
    cos = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(cos(0)) == 0.0
    assert float(cos(10)) == pytest.approx(1e-3)
    assert float(cos(100)) == pytest.approx(1e-4, rel=0.01)
    wsd = wsd_schedule(1e-3, warmup=10, total=100)
    assert float(wsd(50)) == pytest.approx(1e-3)      # stable phase
    assert float(wsd(99)) < 5e-4                      # decay phase


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("olmo-1b").reduced()
    params = init_model(KEY, cfg)
    opt = adamw_init(params)
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, {"params": params, "opt": opt})
    loaded = ckpt.load(path)
    restored = ckpt.restore_like({"params": params, "opt": opt}, loaded)
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # training continues from a restored state
    step = jax.jit(make_train_step(cfg, Runtime()))
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.zeros((2, 8), jnp.int32)}
    p2, o2, m = step(restored["params"], restored["opt"], batch)
    assert np.isfinite(float(m["loss"]))


# --------------------------------------------------------------------------
# synthetic data substrate
# --------------------------------------------------------------------------

def test_routing_trace_properties():
    tr = make_routing_trace(num_sequences=64, seq_len=32, vocab=128,
                            num_experts=8, num_layers=2, skew=2.0,
                            predictability=1.0, seed=0)
    assert tr.tokens.shape == (64, 32)
    assert tr.experts.shape == (2, 64, 32)
    # predictability=1.0 -> expert is a pure function of (token, layer)
    for l in range(2):
        m = {}
        for t, e in zip(tr.tokens.reshape(-1), tr.experts[l].reshape(-1)):
            assert m.setdefault(int(t), int(e)) == int(e)
    # marginal skew lands near the target (sampling noise allowed)
    assert measured_skewness(np.bincount(tr.experts[0].reshape(-1),
                                         minlength=8)) > 1.4


def test_token_batches_shapes():
    gen = token_batches(0, vocab=128, batch=4, seq_len=16)
    b = next(gen)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()


def test_remat_and_microbatch_equivalence():
    """remat + gradient-accumulation microbatching produce the same loss
    and the same updated params as the plain step (memory-perf knobs must
    not change semantics)."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_model(KEY, cfg)
    batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
             "labels": jnp.zeros((4, 16), jnp.int32)}
    outs = {}
    for name, kw in (("plain", {}), ("remat", dict(remat=True)),
                     ("mb4", dict(microbatches=4))):
        step = jax.jit(make_train_step(cfg, Runtime(), lr_fn=lambda s: 1e-3,
                                       **kw))
        p2, _, m = step(params, adamw_init(params), batch)
        outs[name] = (float(m["loss"]), p2)
    for name in ("remat", "mb4"):
        assert outs[name][0] == pytest.approx(outs["plain"][0], abs=1e-5)
        for a, b in zip(jax.tree.leaves(outs["plain"][1]),
                        jax.tree.leaves(outs[name][1])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-3)
