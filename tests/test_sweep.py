"""Sweep harness: matrix expansion, reference bands, trend database,
k8s manifests, and the check_trend/check_regression gate edge cases."""

import json

import pytest

from repro.sweep.history import (append_entry, bench_history_entry,
                                 load_history, series, sweep_history_entry,
                                 trend)
from repro.sweep.k8s import (job_manifest, manifest_name, validate_manifest,
                             write_manifests)
from repro.sweep.matrix import (FULL_SPEC, SMOKE_SPEC, MeshShape, SweepPoint,
                                SweepSpec, parse_mesh)
from repro.sweep.references import (check_metric, classify_metric,
                                    gate_document, refresh_references,
                                    structural_failures)
from repro.sweep.report import drift_warnings, sparkline, trend_table


# ---------------------------------------------------------------------------
# matrix expansion
# ---------------------------------------------------------------------------

class TestMatrix:
    def test_expansion_deterministic(self):
        a = [p.key for p in SMOKE_SPEC.expand()]
        b = [p.key for p in SMOKE_SPEC.expand()]
        assert a == b
        assert len(a) == len(set(a)), "config keys must be unique"

    def test_smoke_tier_is_at_least_four_points(self):
        pts = SMOKE_SPEC.expand()
        assert len(pts) >= 4
        assert len({p.mesh for p in pts}) >= 2     # >= 2 mesh shapes
        assert len({p.workload for p in pts}) >= 2

    def test_product_order_and_size(self):
        spec = SweepSpec(archs=("a", "b"),
                         meshes=(MeshShape(1, 2), MeshShape(2, 2)),
                         workloads=("w1",), strategies=("s1", "s2"),
                         seeds=(0, 1))
        pts = spec.expand()
        assert len(pts) == 2 * 2 * 1 * 2 * 2
        # arch is the slowest axis, seed the fastest
        assert pts[0].key == "a@1x2/w1/s1/s0"
        assert pts[1].key == "a@1x2/w1/s1/s1"
        assert pts[-1].key == "b@2x2/w1/s2/s1"

    def test_point_roundtrip(self):
        p = SweepPoint("mixtral-8x7b", MeshShape(2, 4), "steady",
                       "dist_only", seed=3)
        assert SweepPoint.from_obj(p.to_obj()) == p
        assert p.to_obj()["key"] == p.key

    def test_parse_mesh(self):
        assert parse_mesh("2x4") == MeshShape(2, 4)
        assert parse_mesh("2x4").devices == 8
        with pytest.raises(ValueError):
            parse_mesh("2by4")
        with pytest.raises(ValueError):
            parse_mesh("0x4")

    def test_restrict_filters_and_rejects_unknown(self):
        spec = FULL_SPEC.restrict(meshes=[MeshShape(2, 4)],
                                  workloads=["steady"])
        pts = spec.expand()
        assert {p.mesh.key for p in pts} == {"2x4"}
        assert {p.workload for p in pts} == {"steady"}
        with pytest.raises(ValueError, match="unknown workload"):
            FULL_SPEC.restrict(workloads=["nope"])


# ---------------------------------------------------------------------------
# reference bands
# ---------------------------------------------------------------------------

class TestReferences:
    def test_inside_band_passes(self):
        assert check_metric("m", 1.0, [1.0, 0.1, 0.1]) is None
        assert check_metric("m", 1.09, [1.0, 0.1, 0.1]) is None
        assert check_metric("m", 0.91, [1.0, 0.1, 0.1]) is None

    def test_band_violations(self):
        assert "above" in check_metric("m", 1.2, [1.0, 0.1, 0.1])
        assert "below" in check_metric("m", 0.8, [1.0, 0.1, 0.1])

    def test_missing_metric_fails(self):
        msg = check_metric("m", None, [1.0, 0.1, 0.1])
        assert msg is not None and "missing" in msg

    def test_zero_reference_uses_absolute_tolerance(self):
        # exact flag at ref 0 (e.g. recompiled): only 0 passes
        assert check_metric("recompiled", 0.0, [0.0, 0.0, 0.0]) is None
        assert check_metric("recompiled", 1.0, [0.0, 0.0, 0.0]) is not None
        # non-exact tolerance around 0 is absolute, not relative
        assert check_metric("m", 0.3, [0.0, None, 0.5]) is None
        assert check_metric("m", 0.7, [0.0, None, 0.5]) is not None

    def test_upper_only_tolerance(self):
        ref = [100.0, None, 1.0]         # timings: faster is always fine
        assert check_metric("wall_us", 1.0, ref) is None
        assert check_metric("wall_us", 199.0, ref) is None
        assert "above" in check_metric("wall_us", 201.0, ref)

    def test_lower_only_tolerance(self):
        ref = [2.0, 0.5, None]           # speedups: higher is always fine
        assert check_metric("speedup", 50.0, ref) is None
        assert check_metric("speedup", 1.01, ref) is None
        assert "below" in check_metric("speedup", 0.99, ref)

    def test_malformed_reference(self):
        assert "malformed" in check_metric("m", 1.0, [1.0, 0.1])
        assert "malformed" in check_metric("m", 1.0, None)
        assert "malformed" in check_metric("m", 1.0, ["x", 0.1, 0.1])

    def test_structural_failures(self):
        assert structural_failures({"benches": {}, "total_wall_s": 0})
        assert structural_failures({"total_wall_s": 5.0})
        assert not structural_failures(
            {"benches": {"b": {}}, "total_wall_s": 5.0})

    def test_gate_document(self):
        refs = {"schema": 1, "total_wall_s": [10.0, None, 0.5],
                "benches": {"b": {"ok": [1.0, 0.0, 0.0],
                                  "wall_us": [100.0, None, 1.0],
                                  "speedup": [2.0, 0.5, None]}}}
        good = {"total_wall_s": 12.0,
                "benches": {"b": {"wall_us": 150.0, "ok": True,
                                  "summary": {"speedup": 1.8}}}}
        failures, checked = gate_document(good, refs)
        assert failures == [] and checked == 4

        bad = {"total_wall_s": 16.0,     # +60% > +50%
               "benches": {"b": {"wall_us": 250.0, "ok": False,
                                 "summary": {}}}}
        failures, _ = gate_document(bad, refs)
        joined = "\n".join(failures)
        assert "total_wall_s" in joined
        assert "b.wall_us" in joined
        assert "b.ok" in joined
        assert "b.speedup" in joined and "missing" in joined

    def test_gate_document_missing_bench(self):
        refs = {"benches": {"gone": {"ok": [1.0, 0.0, 0.0]}}}
        failures, _ = gate_document(
            {"total_wall_s": 1.0, "benches": {"other": {"ok": True}}}, refs)
        assert any("disappeared" in f for f in failures)

    def test_gate_empty_document_fails_loudly(self):
        failures, _ = gate_document({"benches": {}}, {"benches": {}})
        assert any("structurally empty" in f or "no benches" in f
                   for f in failures)

    def test_refresh_refuses_empty_and_classifies(self):
        with pytest.raises(ValueError, match="refusing"):
            refresh_references({"benches": {}, "total_wall_s": 0.0})
        doc = {"total_wall_s": 50.0, "meta": {"git_sha": "abc"},
               "benches": {"b": {"wall_us": 1e6, "ok": True, "summary": {
                   "pack_speedup": 1.9, "trace_ok": 1.0,
                   "meshed_recompiled": 0.0, "phase_route_us": 123.0}}}}
        refs = refresh_references(doc)
        b = refs["benches"]["b"]
        assert b["ok"] == [1.0, 0.0, 0.0]
        assert b["wall_us"][1:] == [None, 1.0]
        assert b["pack_speedup"][1:] == [0.5, None]
        assert b["trace_ok"][1:] == [0.0, 0.0]
        assert b["meshed_recompiled"] == [0.0, 0.0, 0.0]
        assert "phase_route_us" not in b, "unclassified metrics untracked"
        # a refreshed document always round-trips through the gate
        failures, checked = gate_document(doc, refs)
        assert failures == [] and checked >= 5

    def test_classify_metric(self):
        assert classify_metric("overlap_bitexact") == (0.0, 0.0)
        assert classify_metric("meshed_slo_ok") == (0.0, 0.0)
        assert classify_metric("store_speedup") == (0.5, None)
        assert classify_metric("step_p50_ms") == (None, 1.5)
        assert classify_metric("goodput_req_s") is None


# ---------------------------------------------------------------------------
# history / trend database
# ---------------------------------------------------------------------------

class TestHistory:
    def test_append_load_roundtrip(self, tmp_path):
        path = str(tmp_path / "h.jsonl")
        e1 = {"kind": "bench", "timestamp_utc": "t1", "total_wall_s": 10.0,
              "benches": {"b": {"wall_us": 5.0, "ok": True,
                                "summary": {"speedup": 2.0}}}}
        e2 = {"kind": "sweep", "timestamp_utc": "t2", "key": "a@1x4/w/s/s0",
              "ok": True, "wall_s": 3.0, "metrics": {"step_p50_ms": 9.0}}
        append_entry(path, e1)
        append_entry(path, e2)
        entries = load_history(path)
        assert entries == [e1, e2]

    def test_series_keys(self, tmp_path):
        path = str(tmp_path / "h.jsonl")
        append_entry(path, {"kind": "bench", "timestamp_utc": "t1",
                            "total_wall_s": 10.0,
                            "benches": {"b": {"wall_us": 5.0, "ok": True,
                                              "summary": {"m": 1.5}}}})
        append_entry(path, {"kind": "sweep", "timestamp_utc": "t2",
                            "key": "cfg", "ok": False, "wall_s": 3.0,
                            "metrics": {"step_p50_ms": 9.0}})
        s = series(load_history(path))
        assert s[("run", "total_wall_s", "default")] == [("t1", 10.0)]
        assert s[("b", "m", "default")] == [("t1", 1.5)]
        assert s[("b", "ok", "default")] == [("t1", 1.0)]
        assert s[("sweep", "step_p50_ms", "cfg")] == [("t2", 9.0)]
        assert s[("sweep", "ok", "cfg")] == [("t2", 0.0)]

    def test_legacy_lines_without_kind_still_read(self):
        legacy = {"git_sha": "x", "timestamp_utc": "t0", "smoke": True,
                  "total_wall_s": 90.0,
                  "benches": {"b": {"wall_us": 1.0, "ok": True}}}
        s = series([legacy])
        assert ("b", "wall_us", "default") in s

    def test_torn_write_skipped(self, tmp_path):
        path = tmp_path / "h.jsonl"
        path.write_text(json.dumps({"kind": "sweep", "key": "k", "ok": True,
                                    "wall_s": 1.0, "metrics": {}}) +
                        "\n{\"torn")
        assert len(load_history(str(path))) == 1

    def test_trend_drift_detection(self):
        rising = [1.0, 1.1, 1.2, 1.3, 1.5]
        t = trend(rising)
        assert t["drifting"] and t["rel_change"] > 0.1
        wobble = [1.0, 1.4, 0.9, 1.3, 1.0]
        assert not trend(wobble)["drifting"]        # not monotonic
        flatish = [1.0, 1.01, 1.02, 1.03]
        assert not trend(flatish)["drifting"]       # inside DRIFT_REL
        assert not trend([1.0, 2.0])["drifting"]    # too few points
        assert trend([])["n"] == 0

    def test_entry_builders(self):
        doc = {"smoke": True, "total_wall_s": 5.0,
               "meta": {"git_sha": "abc", "timestamp_utc": "t"},
               "benches": {"b": {"wall_us": 1.0, "ok": True,
                                 "summary": {"m": 2.0},
                                 "derived": "ignored"}}}
        e = bench_history_entry(doc)
        assert e["kind"] == "bench" and e["git_sha"] == "abc"
        assert e["benches"]["b"] == {"wall_us": 1.0, "ok": True,
                                     "summary": {"m": 2.0}}
        job = {"key": "k", "ok": True, "wall_s": 2.0,
               "config": {"smoke": True}, "metrics": {"m": 1.0}}
        se = sweep_history_entry(job, {"git_sha": "abc",
                                       "timestamp_utc": "t"})
        assert se["kind"] == "sweep" and se["key"] == "k"
        assert se["metrics"] == {"m": 1.0}


# ---------------------------------------------------------------------------
# k8s manifests
# ---------------------------------------------------------------------------

class TestK8s:
    def _point(self):
        return SweepPoint("mixtral-8x7b", MeshShape(2, 4), "skew_shift",
                          "token_to_expert", seed=0)

    def test_manifest_schema_valid(self):
        m = job_manifest(self._point(), image="repro:ci")
        assert validate_manifest(m) == []
        assert m["apiVersion"] == "batch/v1" and m["kind"] == "Job"
        c = m["spec"]["template"]["spec"]["containers"][0]
        assert c["command"] == ["python", "-m", "repro.sweep.job"]
        point = json.loads(c["args"][1])
        assert point["mesh"] == "2x4"
        env = {e["name"]: e["value"] for e in c["env"]}
        assert "device_count=8" in env["XLA_FLAGS"]

    def test_manifest_name_is_dns1123(self):
        name = manifest_name(self._point())
        assert len(name) <= 63
        assert name == name.lower()
        assert manifest_name(self._point()) == name   # deterministic
        long_point = SweepPoint("a" * 80, MeshShape(1, 1), "w", "s")
        assert len(manifest_name(long_point)) <= 63

    def test_validate_catches_breakage(self):
        m = job_manifest(self._point(), image="repro:ci")
        m["kind"] = "Deployment"
        m["metadata"]["name"] = "Bad_Name!"
        m["spec"]["template"]["spec"]["restartPolicy"] = "Always"
        del m["spec"]["template"]["spec"]["containers"][0]["image"]
        errors = validate_manifest(m)
        assert len(errors) >= 4

    def test_write_manifests(self, tmp_path):
        pts = SMOKE_SPEC.expand()
        paths = write_manifests(pts, str(tmp_path), image="repro:ci")
        assert len(paths) == len(pts)
        for p in paths:
            text = open(p).read()
            assert "batch/v1" in text and "repro-sweep" in text


# ---------------------------------------------------------------------------
# report rendering
# ---------------------------------------------------------------------------

class TestReport:
    def test_trend_table_renders_rows(self):
        smap = {("b", "m", "cfg"): [("t1", 1.0), ("t2", 2.0)]}
        md = trend_table(smap)
        assert "| b | m | cfg | 2 |" in md
        refs = {"benches": {"b": {"m": [1.0, 0.5, None]}}}
        md = trend_table(smap, refs=refs)
        assert "[0.5, inf]" in md

    def test_sparkline(self):
        assert sparkline([]) == ""
        assert sparkline([1.0, 1.0]) == "▁▁"
        s = sparkline([0.0, 0.5, 1.0])
        assert s[0] == "▁" and s[-1] == "█"

    def test_drift_warnings(self):
        smap = {("b", "m", "c"): [("t", v) for v in
                                  [1.0, 1.2, 1.4, 1.6, 1.8]]}
        warns = drift_warnings(smap)
        assert len(warns) == 1 and "b.m" in warns[0]


# ---------------------------------------------------------------------------
# gate CLIs (check_regression bugfix + check_trend)
# ---------------------------------------------------------------------------

class TestCollect:
    @staticmethod
    def _job_doc(key, *, ok=True, sha=None, metrics=None):
        doc = {"schema": 1, "kind": "sweep-job", "key": key,
               "config": {"smoke": True}, "ok": ok, "wall_s": 1.5,
               "metrics": metrics or {"step_p50_ms": 10.0}}
        if sha is not None:
            doc["meta"] = {"git_sha": sha, "timestamp_utc": "t0"}
        return doc

    def test_collect_skips_torn_and_non_job_docs(self, tmp_path):
        from repro.sweep.collect import collect_results
        d = tmp_path / "results"
        d.mkdir()
        (d / "a.json").write_text(json.dumps(self._job_doc("k-a")))
        (d / "torn.json").write_text('{"kind": "sweep-job", "key"')
        (d / "report.json").write_text(json.dumps({"kind": "report"}))
        hist = tmp_path / "h.jsonl"
        rep = collect_results(str(d), str(hist), meta={"git_sha": "s1"})
        assert [len(rep.appended), len(rep.torn), len(rep.skipped),
                len(rep.duplicates)] == [1, 1, 1, 0]
        entries = load_history(str(hist))
        assert len(entries) == 1
        assert entries[0]["key"] == "k-a"
        assert entries[0]["git_sha"] == "s1"
        assert entries[0]["kind"] == "sweep"
        assert "1/3" in rep.summarize()

    def test_collect_is_idempotent_across_reruns(self, tmp_path):
        from repro.sweep.collect import collect_results
        d = tmp_path / "results"
        d.mkdir()
        (d / "a.json").write_text(json.dumps(self._job_doc("k-a")))
        (d / "b.json").write_text(json.dumps(self._job_doc("k-b")))
        hist = tmp_path / "h.jsonl"
        meta = {"git_sha": "s1"}
        assert len(collect_results(str(d), str(hist), meta).appended) == 2
        rep = collect_results(str(d), str(hist), meta)
        assert len(rep.appended) == 0 and len(rep.duplicates) == 2
        assert len(load_history(str(hist))) == 2

    def test_collect_doc_meta_overrides_supplied_meta(self, tmp_path):
        from repro.sweep.collect import collect_results
        d = tmp_path / "results"
        d.mkdir()
        (d / "a.json").write_text(
            json.dumps(self._job_doc("k-a", sha="doc-sha")))
        hist = tmp_path / "h.jsonl"
        collect_results(str(d), str(hist), meta={"git_sha": "cli-sha"})
        assert load_history(str(hist))[0]["git_sha"] == "doc-sha"
        # a NEW sha for the same key is a fresh measurement, not a dup
        rep = collect_results(str(d), str(hist), meta={"git_sha": "other"})
        assert len(rep.duplicates) == 1        # doc sha still wins

    def test_collect_same_key_in_one_batch_deduped(self, tmp_path):
        from repro.sweep.collect import collect_results
        d = tmp_path / "results"
        d.mkdir()
        (d / "a.json").write_text(json.dumps(self._job_doc("k-a")))
        (d / "a_retry.json").write_text(json.dumps(self._job_doc("k-a")))
        hist = tmp_path / "h.jsonl"
        rep = collect_results(str(d), str(hist), meta={"git_sha": "s1"})
        assert len(rep.appended) == 1 and len(rep.duplicates) == 1

    def test_collect_cli_end_to_end(self, tmp_path, capsys):
        from repro.sweep.__main__ import main
        d = tmp_path / "results"
        d.mkdir()
        (d / "a.json").write_text(
            json.dumps(self._job_doc("k-cli", sha="s9")))
        hist = tmp_path / "h.jsonl"
        rc = main(["collect", "--dir", str(d), "--history", str(hist)])
        assert rc == 0
        assert "collected 1/1" in capsys.readouterr().out
        entries = load_history(str(hist))
        assert [e["key"] for e in entries] == ["k-cli"]
        # history series over the collected metric stays queryable
        s = series(entries)
        assert [v for _, v in s[("sweep", "step_p50_ms", "k-cli")]] == [10.0]


class TestGateCLIs:
    def test_check_regression_empty_current_fails(self):
        from benchmarks import check_regression
        baseline = {"total_wall_s": 10.0,
                    "benches": {"b": {"ok": True, "wall_us": 1.0}}}
        # the truncated-run shape that used to exit 0 when baseline was
        # also empty; now both directions fail loudly
        failures = check_regression.compare({"benches": {}}, baseline)
        assert any("structurally empty" in f for f in failures)
        failures = check_regression.compare(
            {"benches": {}}, {"benches": {}})
        assert failures, "empty vs empty must not pass"

    def test_check_regression_healthy_doc_passes(self):
        from benchmarks import check_regression
        doc = {"total_wall_s": 10.0,
               "benches": {"b": {"ok": True, "wall_us": 1.0}}}
        assert check_regression.compare(dict(doc), dict(doc)) == []

    def test_check_trend_cli_gates_and_writes_markdown(self, tmp_path):
        from benchmarks import check_trend
        doc = {"total_wall_s": 50.0, "meta": {},
               "benches": {"b": {"wall_us": 1e6, "ok": True,
                                 "summary": {"pack_speedup": 1.9}}}}
        doc_path = tmp_path / "doc.json"
        doc_path.write_text(json.dumps(doc))
        refs_path = tmp_path / "refs.json"
        refs_path.write_text(json.dumps(refresh_references(doc)))
        hist_path = tmp_path / "h.jsonl"
        append_entry(str(hist_path), bench_history_entry(doc))
        md_path = tmp_path / "trend.md"
        rc = check_trend.main([str(doc_path), "--references",
                               str(refs_path), "--history", str(hist_path),
                               "--markdown", str(md_path)])
        assert rc == 0
        md = md_path.read_text()
        assert "Perf-reference gate" in md and "| b |" in md

        # regressed speedup breaches its band -> exit 1
        bad = dict(doc, benches={"b": {"wall_us": 1e6, "ok": True,
                                       "summary": {"pack_speedup": 0.5}}})
        doc_path.write_text(json.dumps(bad))
        assert check_trend.main([str(doc_path), "--references",
                                 str(refs_path), "--history",
                                 str(hist_path)]) == 1

    def test_check_trend_refresh_roundtrip(self, tmp_path, monkeypatch):
        from benchmarks import check_trend
        doc = {"total_wall_s": 50.0, "meta": {},
               "benches": {"b": {"wall_us": 1e6, "ok": True,
                                 "summary": {"store_speedup": 2.0}}}}
        doc_path = tmp_path / "doc.json"
        doc_path.write_text(json.dumps(doc))
        refs_path = tmp_path / "refs.json"
        monkeypatch.setenv("REPRO_BENCH_REFRESH_REFERENCES", "1")
        assert check_trend.main([str(doc_path), "--references",
                                 str(refs_path)]) == 0
        refs = json.loads(refs_path.read_text())
        assert refs["benches"]["b"]["store_speedup"][0] == 2.0
