"""Predictor ladder tests (paper Sec 3.2 / Appendix B).

Validates the ladder ordering the paper's tradeoff rests on:
accuracy(probability) <= accuracy(conditional) <= accuracy(neural) on a
predictable synthetic corpus, and the Distribution-Only estimator's
error-vs-skew behaviour (Table 1 direction)."""

import numpy as np
import pytest

from repro.core.balance import error_rate
from repro.core.predictors import (ConditionalProbabilityModel,
                                   DistributionEstimator, FFNPredictor,
                                   LSTMPredictor, ProbabilityModel, accuracy)
from repro.data.synthetic import make_routing_trace

L, E, V = 2, 8, 256


@pytest.fixture(scope="module")
def trace():
    return make_routing_trace(num_sequences=192, seq_len=64, vocab=V,
                              num_experts=E, num_layers=L, skew=1.6,
                              predictability=0.9, seed=1)


def split(trace, frac=0.8):
    n = trace.tokens.shape[0]
    k = int(n * frac)
    return ((trace.tokens[:k], trace.experts[:, :k]),
            (trace.tokens[k:], trace.experts[:, k:]))


def test_probability_model_floor(trace):
    (tok_tr, ex_tr), (tok_te, ex_te) = split(trace)
    m = ProbabilityModel(L, E).fit(ex_tr)
    acc = accuracy(m.predict(tok_te), ex_te)
    # always guessing the hottest expert ~= its share (skew/E), plus slack
    assert 0.05 <= acc <= 0.65


def test_conditional_beats_probability(trace):
    (tok_tr, ex_tr), (tok_te, ex_te) = split(trace)
    prob = ProbabilityModel(L, E).fit(ex_tr)
    cond = ConditionalProbabilityModel(L, E, V).fit(ex_tr, tok_tr)
    acc_p = accuracy(prob.predict(tok_te), ex_te)
    acc_c = accuracy(cond.predict(tok_te), ex_te)
    assert acc_c > acc_p + 0.1           # token identity captures the rule
    assert acc_c > 0.6                   # predictability=0.9 is learnable


def test_ffn_predictor_learns(trace):
    (tok_tr, ex_tr), (tok_te, ex_te) = split(trace)
    m = FFNPredictor(L, E, V, seed=0).fit(ex_tr, tok_tr, steps=150, batch=32)
    acc = accuracy(m.predict(tok_te), ex_te)
    assert acc > 0.55


def test_lstm_predictor_learns(trace):
    (tok_tr, ex_tr), (tok_te, ex_te) = split(trace)
    m = LSTMPredictor(L, E, V, seed=0).fit(ex_tr, tok_tr, steps=120, batch=16)
    acc = accuracy(m.predict(tok_te), ex_te)
    assert acc > 0.5


def test_overhead_ordering():
    """flops(probability) < flops(conditional) < flops(ffn) < flops(lstm)."""
    ffn = FFNPredictor(L, E, V)
    lstm = LSTMPredictor(L, E, V)
    fl = [ProbabilityModel.flops_per_token(L),
          ConditionalProbabilityModel.flops_per_token(L),
          ffn.flops_per_token(L), lstm.flops_per_token(L)]
    assert fl == sorted(fl) and fl[0] < fl[-1]


def test_distribution_estimator_mle_and_ema():
    est = DistributionEstimator(num_layers=1, num_experts=4, ema=0.5)
    est.update(np.array([[8, 4, 2, 2]]))
    np.testing.assert_allclose(est.predict()[0], [0.5, 0.25, 0.125, 0.125])
    est.update(np.array([[0, 0, 8, 8]]))         # EMA moves halfway
    p = est.predict()[0]
    np.testing.assert_allclose(p, [0.25, 0.125, 0.3125, 0.3125])
    assert DistributionEstimator.flops_per_token(32) == 0.0


def test_distribution_error_grows_with_skew():
    """Table 1 direction: higher skew -> larger relative estimation error
    (cold experts see few tokens). Measured over small-sample batches."""
    errs = {}
    for skew in (1.4, 3.0):
        tr = make_routing_trace(num_sequences=40, seq_len=16, vocab=V,
                                num_experts=E, num_layers=1, skew=skew,
                                predictability=0.0, seed=2)
        (tok_tr, ex_tr), (tok_te, ex_te) = split(tr)
        est = DistributionEstimator(1, E)
        counts = np.stack([np.bincount(ex_tr[0].reshape(-1), minlength=E)])
        est.update(counts.astype(np.float64))
        p_te = np.stack([np.bincount(ex_te[0].reshape(-1), minlength=E)])
        p_te = p_te / p_te.sum()
        errs[skew] = error_rate(est.predict(), p_te)
    assert errs[1.4] < 0.5               # low skew estimates well
