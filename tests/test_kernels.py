"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).
Kernels run interpret=True on CPU — the exact TPU program body."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.histogram import histogram
from repro.kernels.moe_gemm import moe_gemm
from repro.kernels.ref import histogram_ref, moe_gemm_ref, rg_lru_ref
from repro.kernels.rg_lru import rg_lru_scan

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("S,T,d,F", [
    (1, 8, 128, 256),          # minimal
    (4, 64, 256, 512),         # aligned
    (2, 100, 128, 300),        # ragged T and F (padding path)
    (8, 8, 512, 1024),         # tall weights
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("activation", ["swiglu", "gelu", "relu"])
def test_moe_gemm_matches_ref(S, T, d, F, dtype, activation):
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (S, T, d), jnp.float32) * 0.1).astype(dtype)
    wg = (jax.random.normal(ks[1], (S, d, F), jnp.float32) * 0.05).astype(dtype)
    wu = (jax.random.normal(ks[2], (S, d, F), jnp.float32) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[3], (S, F, d), jnp.float32) * 0.05).astype(dtype)
    out = moe_gemm(x, wg, wu, wd, activation=activation)
    ref = moe_gemm_ref(x, wg, wu, wd, activation)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_moe_gemm_ops_wrapper_matches_dispatch_grouped_ffn():
    from repro.moe.dispatch import grouped_ffn
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (4, 32, 128), jnp.float32) * 0.1
    slot_w = {
        "w_gate": jax.random.normal(ks[1], (4, 128, 256), jnp.float32) * 0.05,
        "w_up": jax.random.normal(ks[2], (4, 128, 256), jnp.float32) * 0.05,
        "w_down": jax.random.normal(ks[3], (4, 256, 128), jnp.float32) * 0.05,
    }
    out = ops.moe_gemm(x, slot_w, "swiglu")
    ref = grouped_ffn(slot_w, x, "swiglu")
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("N,E", [(8, 4), (100, 8), (5000, 64), (17, 128),
                                 (1024, 256)])
def test_histogram_matches_ref(N, E):
    idx = jax.random.randint(KEY, (N,), 0, E)
    out = histogram(idx, E)
    ref = histogram_ref(idx, E)
    assert (np.asarray(out) == np.asarray(ref)).all()
    assert int(out.sum()) == N


def test_histogram_ops_wrapper_topk_shape():
    idx = jax.random.randint(KEY, (16, 2), 0, 8)     # (T, K) assignments
    out = ops.expert_histogram(idx, 8)
    assert int(out.sum()) == 32


@pytest.mark.parametrize("B,S,D", [
    (1, 16, 128), (2, 64, 128), (1, 300, 500),       # ragged
    (4, 2000, 256),                                  # multi time-chunk carry
    (2, 1025, 257),                                  # both dims ragged
])
def test_rg_lru_matches_ref(B, S, D):
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, D), jnp.float32, 0.5, 0.99)
    b = jax.random.normal(ks[1], (B, S, D), jnp.float32) * 0.1
    h0 = jax.random.normal(ks[2], (B, D), jnp.float32)
    out, hl = rg_lru_scan(a, b, h0)
    ro, rh = rg_lru_ref(a, b, h0)
    np.testing.assert_allclose(out, ro, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(hl, rh, atol=1e-5, rtol=1e-5)


def test_rg_lru_matches_griffin_associative_scan():
    """The kernel and the model's associative_scan agree (same recurrence)."""
    from repro.models.griffin import rg_lru, init_recurrent_block
    from repro.configs.registry import get_config
    cfg = get_config("recurrentgemma-2b").reduced()
    params = init_recurrent_block(KEY, cfg)
    dr = cfg.rnn_width or cfg.d_model
    x = jax.random.normal(KEY, (2, 32, dr), jnp.float32) * 0.1
    h0 = jnp.zeros((2, dr), jnp.float32)
    y_model, h_model = rg_lru(params, x, h0)

    # rebuild (a, b) exactly as the model does, then run the kernel
    from repro.models.layers import dense
    f32 = jnp.float32
    r = jax.nn.sigmoid(dense(params["w_a"], x).astype(f32))
    i = jax.nn.sigmoid(dense(params["w_x"], x).astype(f32))
    log_a = -8.0 * jax.nn.softplus(params["lam"].astype(f32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * (i * x.astype(f32))
    b = b.at[:, 0].add(a[:, 0] * h0)
    out, hl = rg_lru_scan(a, b, jnp.zeros_like(h0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(y_model, np.float32),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(h_model), atol=1e-4,
                               rtol=1e-4)
