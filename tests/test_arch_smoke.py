"""Per-architecture smoke tests (assignment requirement).

Every assigned arch instantiates its REDUCED variant (<=2 layers,
d_model<=512, <=4 experts) and runs one forward/train step and one decode
step on a single CPU device, asserting output shapes and finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.models.transformer import Runtime, forward, init_cache, init_model
from repro.optim.adamw import adamw_init
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step

KEY = jax.random.PRNGKey(0)
RT = Runtime()


def _batch(cfg, B=2, S=16):
    b = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S)
         % cfg.vocab_size,
         "labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.input_mode == "mixed" and cfg.num_prefix_embeddings:
        b["prefix_embeds"] = 0.01 * jnp.ones(
            (B, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        b["frames"] = 0.01 * jnp.ones((B, 8, cfg.encoder.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_model(KEY, cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, _, stats = forward(params, cfg, batch, RT, mode="train")
    S_out = S + (cfg.num_prefix_embeddings
                 if cfg.input_mode == "mixed" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    if cfg.is_moe:
        counts = stats["expert_counts"]
        assert counts.shape == (cfg.num_layers, cfg.moe.num_experts)
        # every routed (token, k) pair lands on exactly one expert
        assert float(counts.sum()) == pytest.approx(
            cfg.num_layers * B * S_out * cfg.moe.top_k)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_reduces_loss_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = init_model(KEY, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, RT, lr_fn=lambda s: 1e-3))
    batch = _batch(cfg, 2, 16)
    losses = []
    for _ in range(3):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
        assert np.isfinite(float(metrics["grad_norm"]))
    assert losses[-1] < losses[0]        # same batch: loss must drop


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch).reduced()
    params = init_model(KEY, cfg)
    B, S = 2, 16
    cache = init_cache(cfg, RT, B, 32)
    batch = _batch(cfg, B, S)
    logits, cache, _ = make_prefill_step(cfg, RT)(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    decode = make_decode_step(cfg, RT)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = S + (cfg.num_prefix_embeddings if cfg.input_mode == "mixed" else 0)
    for t in range(3):
        tok, logits, cache, _ = decode(params, tok, cache, pos + t)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_decode_consistent_with_train_forward():
    """Greedy decode logits == train-mode logits at the same position
    (dense arch, deterministic): validates cache correctness."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_model(KEY, cfg)
    B, S = 1, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = forward(params, cfg, {"tokens": toks}, RT, mode="train")

    cache = init_cache(cfg, RT, B, S + 4)
    pre, cache, _ = make_prefill_step(cfg, RT)(
        params, {"tokens": toks[:, :S - 1]}, cache)
    np.testing.assert_allclose(np.asarray(pre[:, 0], np.float32),
                               np.asarray(full_logits[:, S - 2], np.float32),
                               atol=2e-2, rtol=2e-2)
    decode = make_decode_step(cfg, RT)
    _, dlogits, cache, _ = decode(params, toks[:, S - 1:S], cache, S - 1)
    np.testing.assert_allclose(np.asarray(dlogits[:, 0], np.float32),
                               np.asarray(full_logits[:, S - 1], np.float32),
                               atol=6e-2, rtol=6e-2)   # bf16 accumulation


@pytest.mark.parametrize("arch", ["rwkv6-7b", "recurrentgemma-2b"])
def test_recurrent_state_decode_windowed(arch):
    """SSM/hybrid archs decode with O(1)/O(window) state (long_500k path)."""
    cfg = get_config(arch).reduced()
    params = init_model(KEY, cfg)
    B = 2
    cache = init_cache(cfg, RT, B, 10_000)
    # state size must not scale with the 10k max_len
    leaves = jax.tree.leaves(cache)
    assert all(10_000 not in l.shape for l in leaves)
    decode = make_decode_step(cfg, RT)
    tok = jnp.zeros((B, 1), jnp.int32)
    tok, logits, cache, _ = decode(params, tok, cache, 9_000)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_config_parameter_counts_match_specs():
    """Analytical num_params is in the right ballpark for the full configs."""
    expect = {        # billions, loose bands (embeddings/heads vary)
        "minicpm-2b": (2.0, 4.0), "stablelm-3b": (2.0, 4.5),
        "rwkv6-7b": (5.5, 9.0), "qwen1.5-0.5b": (0.3, 0.8),
        "llava-next-34b": (30.0, 40.0), "olmo-1b": (0.9, 1.6),
        "deepseek-v2-lite-16b": (12.0, 20.0), "recurrentgemma-2b": (2.0, 3.6),
        "arctic-480b": (400.0, 520.0), "seamless-m4t-medium": (0.7, 1.8),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).num_params() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo}, {hi}]"


def test_moe_active_params_below_total():
    for arch in ("arctic-480b", "deepseek-v2-lite-16b", "mixtral-8x7b"):
        cfg = get_config(arch)
        assert cfg.active_params() < 0.5 * cfg.num_params()


def test_input_shapes_table():
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
