"""MoE-GPS simulator + strategy selection tests — validates the paper's
claims qualitatively AND the >23% headline quantitatively."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.core.gps import (T2EPoint, default_dist_eps,
                            fit_overhead_curve, run_gps, sweep)
from repro.core.simulator import (A100_NVLINK, A100_PCIE, TPU_V5E_DCN,
                                  TPU_V5E_POD, duplication_is_hideable,
                                  duplication_move_time, layer_latency)

MIX = get_config("mixtral-8x7b")


def test_baseline_latency_scales_with_skew():
    lats = [layer_latency(MIX, A100_NVLINK, batch=1, seq=512, skew=s).total
            for s in (1.0, 1.4, 2.0, 3.0)]
    assert all(b > a for a, b in zip(lats, lats[1:]))


def test_ffn_term_scales_linearly_with_skew():
    l1 = layer_latency(MIX, A100_NVLINK, batch=1, seq=512, skew=1.0)
    l3 = layer_latency(MIX, A100_NVLINK, batch=1, seq=512, skew=3.0)
    assert l3.ffn == pytest.approx(3 * l1.ffn, rel=0.01)
    assert l3.attention == pytest.approx(l1.attention)   # skew-independent


def test_dist_only_reduces_ffn_not_comm():
    base = layer_latency(MIX, A100_PCIE, batch=1, seq=512, skew=2.0)
    d = layer_latency(MIX, A100_PCIE, batch=1, seq=512, skew=2.0,
                      strategy="dist_only", eps=0.05)
    assert d.ffn < base.ffn
    assert d.dispatch == pytest.approx(base.dispatch)    # paper accounting
    assert d.overhead == 0.0


def test_t2e_reduces_comm_but_adds_overhead():
    base = layer_latency(MIX, A100_PCIE, batch=1, seq=512, skew=2.0)
    t = layer_latency(MIX, A100_PCIE, batch=1, seq=512, skew=2.0,
                      strategy="token_to_expert", eps=0.1, overhead_frac=0.2)
    assert t.dispatch < base.dispatch
    assert t.overhead > 0


def test_pessimistic_worse_than_typical():
    kw = dict(batch=1, seq=512, skew=1.4, strategy="dist_only", eps=0.1)
    t = layer_latency(MIX, A100_NVLINK, scenario="typical", **kw)
    p = layer_latency(MIX, A100_NVLINK, scenario="pessimistic", **kw)
    o = layer_latency(MIX, A100_NVLINK, scenario="optimistic", **kw)
    assert o.ffn < t.ffn < p.ffn


def test_headline_23_percent_mixtral_mmlu_nvlink():
    """Paper abstract: Distribution-Only beats the best Token-to-Expert
    config by >23% on Mixtral 8x7B at MMLU skewness (1.4) on NVLink."""
    rep = run_gps(MIX, A100_NVLINK, batch=1, seq=512, skew=1.4)
    assert rep.best is rep.dist_only
    assert rep.dist_only_speedup_over_t2e > 0.23


def test_t2e_gains_ground_at_high_skew_low_bandwidth():
    """Fig 7 direction: the dist-only advantage shrinks (or flips) as
    skew rises and interconnect bandwidth drops."""
    adv = {}
    for name, hw in (("nvlink", A100_NVLINK), ("pcie", A100_PCIE)):
        for skew in (1.4, 2.5):
            rep = run_gps(MIX, hw, skew=skew)
            adv[(name, skew)] = rep.saving_difference
    assert adv[("pcie", 2.5)] < adv[("nvlink", 1.4)]
    assert adv[("nvlink", 2.5)] < adv[("nvlink", 1.4)]
    assert adv[("pcie", 1.4)] < adv[("nvlink", 1.4)]


def test_t2e_wins_when_comm_dominates():
    """Force a communication-starved link: token-level prediction's comm
    savings must eventually beat dist-only (paper guideline, Fig 1)."""
    slow = A100_PCIE.with_(link_bw=2e9, name="slow")
    rep = run_gps(MIX, slow, skew=3.5)
    assert rep.best_t2e.total < rep.baseline.total
    assert rep.saving_difference < 0.05      # advantage gone or flipped


def test_u_shape_in_t2e_accuracy():
    """Fig 4: with rising accuracy, latency first falls then rises
    (overhead wins) — the curve is not monotone."""
    curve = [T2EPoint(f"p{i}", a, 0.002 * np.exp(6 * a))
             for i, a in enumerate(np.linspace(0.3, 0.99, 12))]
    rep = run_gps(MIX, A100_PCIE, skew=2.0, t2e_curve=curve)
    tot = [r.total for r in rep.t2e_points]
    best = int(np.argmin(tot))
    assert 0 < best < len(tot) - 1


def test_guideline_text_and_sweep():
    reps = sweep(MIX, [A100_NVLINK, A100_PCIE], [1.4, 2.0])
    assert len(reps) == 4
    assert all(isinstance(r.guideline(), str) and "use " in r.guideline()
               for r in reps)
    rows = reps[0].summary_rows()
    assert rows[0]["strategy"] == "none" and len(rows) >= 3


def test_fit_overhead_curve_exponential():
    pts = [T2EPoint("a", 0.5, 0.01), T2EPoint("b", 0.7, 0.05),
           T2EPoint("c", 0.9, 0.25)]
    f = fit_overhead_curve(pts)
    assert f(0.5) == pytest.approx(0.01, rel=0.5)
    assert f(0.95) > f(0.6)


def test_default_dist_eps_interpolates_table1():
    assert default_dist_eps(1.39) == pytest.approx(0.018, abs=1e-3)
    assert default_dist_eps(1.99) == pytest.approx(0.16, abs=1e-2)
    assert default_dist_eps(1.7) > default_dist_eps(1.45)


def test_gps_rejects_dense_arch():
    with pytest.raises(ValueError):
        run_gps(get_config("qwen1.5-0.5b"), A100_NVLINK)


def test_duplication_overhead_hideable_at_paper_sizes():
    """Paper Sec 5: expert move ~0.1ms on a 2TB/s link; hidden under
    attention for modest batch/seq. NOTE: the paper claims PCIe hideability
    at batch 16 x seq 2K with a conservatively-overestimated (no-Flash)
    attention; our flash-style attention model needs ~4x more tokens
    (recorded in EXPERIMENTS.md)."""
    fast = A100_NVLINK.with_(link_bw=2e12)       # the paper's 2 TB/s figure
    t = duplication_move_time(MIX, fast)
    assert t < 0.3e-3
    assert not duplication_is_hideable(MIX, A100_PCIE, batch=16, seq=2048)
    assert duplication_is_hideable(MIX, A100_PCIE, batch=64, seq=2048)


@given(st.floats(1.0, 4.0), st.floats(0.0, 0.5))
@settings(max_examples=30, deadline=None)
def test_latency_terms_positive_and_finite(skew, eps):
    lb = layer_latency(MIX, TPU_V5E_POD, batch=32, seq=2048, skew=skew,
                       strategy="dist_only", eps=eps)
    for v in lb.as_dict().values():
        assert np.isfinite(v) and v >= 0


def test_tpu_presets_exist():
    assert TPU_V5E_POD.num_devices == 256
    assert TPU_V5E_DCN.link_bw < TPU_V5E_POD.link_bw
