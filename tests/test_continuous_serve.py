"""Continuous-batching subsystem tests: KV block pool, scheduler
admission/eviction, BatchScheduler-compat property, paged-decode
correctness vs the synchronous reference, online GPS controller, and the
no-recompile-after-warmup guarantee."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.models.transformer import Runtime, forward, init_cache, init_model
from repro.serve import (BatchScheduler, BlockAllocator, ContinuousConfig,
                         ContinuousEngine, ContinuousScheduler,
                         ControllerConfig, OnlineGPSController, Request,
                         ServeRequest)
from repro.serve.metrics import imbalance, plan_rank_loads
from repro.serve.scheduler import RequestState

KEY = jax.random.PRNGKey(0)


def _cfg():
    return get_config("mixtral-8x7b").reduced()


# --------------------------------------------------------------------------
# KV block allocator
# --------------------------------------------------------------------------

def test_block_allocator_alloc_free_roundtrip():
    a = BlockAllocator(num_blocks=9, block_size=4)
    assert a.free_blocks == 8                       # block 0 reserved
    got = a.alloc(5)
    assert len(got) == 5 and 0 not in got
    assert a.alloc(4) is None                       # all-or-nothing
    assert a.free_blocks == 3
    a.free(got)
    assert a.free_blocks == 8
    with pytest.raises(ValueError):
        a.free([0])                                 # null block protected


def test_block_allocator_blocks_for():
    a = BlockAllocator(num_blocks=4, block_size=8)
    assert a.blocks_for(1) == 1
    assert a.blocks_for(8) == 1
    assert a.blocks_for(9) == 2


# --------------------------------------------------------------------------
# continuous scheduler: admission / growth / eviction
# --------------------------------------------------------------------------

def _sched(max_slots=2, prefill_len=8, max_len=16, num_blocks=None,
           block_size=4, **kw):
    if num_blocks is None:
        num_blocks = 1 + max_slots * (max_len // block_size)
    alloc = BlockAllocator(num_blocks, block_size)
    return ContinuousScheduler(max_slots, prefill_len, max_len, alloc, **kw)


def _req(rid, plen=6, new=4, arrival=0.0):
    return ServeRequest(rid=rid, tokens=np.arange(plen, dtype=np.int32),
                        max_new_tokens=new, arrival=arrival)


def test_admission_respects_slots_and_arrival_times():
    s = _sched(max_slots=2)
    for i in range(3):
        s.submit(_req(i, arrival=float(i)))
    plan = s.schedule(now=0.0)
    assert [r.rid for r in plan.prefills] == [0]    # only rid 0 has arrived
    plan = s.schedule(now=5.0)
    assert [r.rid for r in plan.prefills] == [1]    # rid 2 waits for a slot
    assert s.request_in(0).rid == 0
    s.finish_slot(0, now=6.0)
    plan = s.schedule(now=6.0)
    assert [r.rid for r in plan.prefills] == [2]


def test_finish_frees_blocks_and_slot():
    s = _sched(max_slots=1)
    free0 = s.alloc.free_blocks
    s.submit(_req(0, plen=6))
    s.schedule(0.0)
    assert s.alloc.free_blocks == free0 - 2         # ceil(6/4) blocks
    req = s.finish_slot(0, 1.0)
    assert req.state == RequestState.FINISHED
    assert s.alloc.free_blocks == free0
    assert s.slots[0] is None


def test_decode_growth_allocates_block_on_boundary():
    s = _sched(max_slots=1, block_size=4)
    s.submit(_req(0, plen=4, new=4))
    plan = s.schedule(0.0)
    assert len(s.tables.owned[0]) == 1              # prompt fits one block
    s.ensure_decode_capacity(plan)                  # next write at pos 4
    assert len(s.tables.owned[0]) == 2


def test_pool_exhaustion_preempts_youngest():
    # pool of 3 usable blocks; two requests of 2 blocks each can't both run
    s = _sched(max_slots=2, prefill_len=8, max_len=12, num_blocks=4,
               block_size=4)
    s.submit(_req(0, plen=4, new=7, arrival=0.0))
    s.submit(_req(1, plen=4, new=7, arrival=0.1))
    plan = s.schedule(1.0)
    assert len(plan.prefills) == 2                  # both admitted (1 blk each)
    s.tables.lengths[:] = 4                         # both hit a block boundary
    s.ensure_decode_capacity(plan)
    # one grew, the other (younger rid 1) was preempted back to waiting
    assert [r.rid for r in plan.preempted] == [1]
    assert s.slots[1] is None and s.waiting[0].rid == 1
    assert s.waiting[0].n_preemptions == 1
    assert plan.decode_slots == [0]


def test_oversized_request_rejected():
    s = _sched(max_slots=1, prefill_len=8, max_len=16, num_blocks=3,
               block_size=4)
    with pytest.raises(ValueError):
        s.submit(_req(0, plen=8, new=8))            # needs 4 of 2 blocks


# --------------------------------------------------------------------------
# compatibility mode property: BatchScheduler semantics preserved
# --------------------------------------------------------------------------

@given(st.integers(0, 20), st.integers(1, 6), st.integers(1, 12),
       st.integers(1, 16))
@settings(max_examples=25, deadline=None)
def test_compat_fifo_matches_batch_scheduler(n_reqs, batch_size, seq_len,
                                             seed):
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(1, seq_len + 2)) for _ in range(n_reqs)]
    old = BatchScheduler(batch_size, seq_len)
    alloc = BlockAllocator(2 + n_reqs * seq_len, 4)
    new = ContinuousScheduler(batch_size, seq_len, 2 * seq_len, alloc,
                              compat_fifo=True)
    for rid, ln in enumerate(lens):
        toks = rng.integers(0, 100, size=ln).astype(np.int32)
        old.submit(Request(rid, toks.copy()))
        new.submit(ServeRequest(rid=rid, tokens=toks.copy()))
    while True:
        a, b = old.next_batch(), new.next_batch()
        if a is None or b is None:
            assert a is None and b is None
            break
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["mask"], b["mask"])
        assert [r.rid for r in a["requests"]] == [r.rid for r in b["requests"]]


# --------------------------------------------------------------------------
# engine correctness: paged continuous decode == synchronous reference
# --------------------------------------------------------------------------

def _reference_generate(cfg, params, prompt, new_tokens):
    """Isolated greedy continuation: a single-slot engine serving exactly
    one request. Uses the same paged decode path as the engine under test
    (the fused kernel keeps attention scores in f32, so its logits differ
    from the linear-cache path by activation-dtype rounding — enough to
    flip greedy argmax near-ties on a random-init model; paged-vs-linear
    numerical agreement is covered by tolerance tests in
    tests/test_paged_attention.py)."""
    eng = ContinuousEngine(cfg, params, ContinuousConfig(
        max_slots=1, prefill_len=32, block_size=8, max_len=64,
        strategy="none", max_prefills_per_step=1))
    eng.warmup()
    eng.run_trace([ServeRequest(rid=0, tokens=np.asarray(prompt, np.int32),
                                max_new_tokens=new_tokens)])
    (done,) = eng.scheduler.completed
    return list(done.generated)


@pytest.fixture(scope="module")
def moe_model():
    cfg = _cfg()
    return cfg, init_model(KEY, cfg)


def test_paged_decode_matches_reference_multi_request(moe_model):
    """Requests of different lengths admitted at different times must each
    reproduce their isolated greedy continuation exactly."""
    cfg, params = moe_model
    prompts = [(np.arange(p, dtype=np.int32) * 13 + s) % cfg.vocab_size
               for s, p in enumerate((5, 11, 17))]
    refs = [_reference_generate(cfg, params, p, 5) for p in prompts]

    eng = ContinuousEngine(cfg, params, ContinuousConfig(
        max_slots=2, prefill_len=32, block_size=8, max_len=64,
        strategy="none", max_prefills_per_step=1))
    eng.warmup()
    reqs = [ServeRequest(rid=i, tokens=p, max_new_tokens=5,
                         arrival=0.0 if i < 2 else 0.01)
            for i, p in enumerate(prompts)]
    eng.run_trace(reqs)
    got = {r.rid: r.generated for r in eng.scheduler.completed}
    assert len(got) == 3
    for i, ref in enumerate(refs):
        assert got[i] == ref, (i, got[i], ref)


def test_preemption_recompute_is_deterministic(moe_model):
    """A starved pool forces preemption; greedy recompute must converge to
    the same outputs as an unconstrained run."""
    cfg, params = moe_model
    prompts = [(np.arange(9, dtype=np.int32) * 7 + s) % cfg.vocab_size
               for s in range(3)]

    outs, preempts = {}, {}
    for label, blocks in (("roomy", 0), ("starved", 10)):
        # starved: 9 usable blocks of 4; three 9-token prompts fill them,
        # and every request must still grow past position 12
        ccfg = ContinuousConfig(max_slots=3, prefill_len=16, block_size=4,
                                max_len=48, strategy="none",
                                num_blocks=blocks)
        eng = ContinuousEngine(cfg, params, ccfg)
        eng.warmup()
        reqs = [ServeRequest(rid=i, tokens=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        eng.run_trace(reqs)
        assert len(eng.scheduler.completed) == 3
        outs[label] = {r.rid: list(r.generated)
                       for r in eng.scheduler.completed}
        preempts[label] = sum(r.n_preemptions
                              for r in eng.scheduler.completed)
    assert preempts["starved"] > 0                  # starvation really hit
    assert outs["roomy"] == outs["starved"]


def test_no_recompilation_after_warmup(moe_model):
    cfg, params = moe_model
    eng = ContinuousEngine(cfg, params, ContinuousConfig(
        max_slots=4, prefill_len=32, block_size=8, max_len=64))
    eng.warmup()
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(rid=i,
                         tokens=rng.integers(0, cfg.vocab_size,
                                             size=int(rng.integers(1, 30))
                                             ).astype(np.int32),
                         max_new_tokens=int(rng.integers(1, 8)),
                         arrival=float(i) * 0.01)
            for i in range(10)]
    eng.run_trace(reqs)
    assert len(eng.scheduler.completed) == 10
    eng.assert_no_recompiles()


def test_strategy_switch_does_not_recompile(moe_model):
    cfg, params = moe_model
    eng = ContinuousEngine(cfg, params, ContinuousConfig(
        max_slots=2, prefill_len=16, block_size=8, max_len=32,
        strategy="dist_only"))
    eng.warmup()
    for i, strat in enumerate(("none", "dist_only", "none")):
        eng.strategy = strat
        eng.replan()
        eng.run_trace([ServeRequest(rid=i, tokens=np.arange(
            6, dtype=np.int32), max_new_tokens=3)])
    eng.assert_no_recompiles()


def test_paged_decode_applies_sliding_window(moe_model):
    """Past the window boundary, paged decode must mask exactly like the
    linear windowed reference — and the mask must actually bind."""
    from repro.models import attention as attn
    cfg, params_model = moe_model
    p = jax.tree.map(lambda a: a[0], params_model["layers"])["attn"]
    rng = np.random.default_rng(0)
    B, S, K, hd = 1, 16, cfg.num_kv_heads, cfg.head_dim
    kv = rng.normal(size=(2, B, S, K, hd)).astype(np.float32) * 0.1
    x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)).astype(np.float32))
    pos = 10
    cache = {"k": jnp.asarray(kv[0]), "v": jnp.asarray(kv[1])}
    ref, _ = attn.gqa_decode_windowed(p, cfg, x, cache, pos, window=4)
    # same KV laid out as 4 paged blocks of 4 (pool block 0 = null)
    bs = 4
    pool = {n: jnp.zeros((6, bs, K, hd)).at[1:5].set(
        jnp.asarray(kv[i]).reshape(S // bs, bs, K, hd))
        for i, n in enumerate(("k", "v"))}
    table = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    lengths = jnp.asarray([pos], jnp.int32)
    got, _ = attn.gqa_decode_paged(p, cfg, x, pool, table, lengths, window=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)
    unmasked, _ = attn.gqa_decode_paged(p, cfg, x, pool, table, lengths)
    assert not np.allclose(np.asarray(unmasked), np.asarray(ref),
                           rtol=2e-2, atol=2e-3)


def test_full_length_prompt_accepted_on_tight_pool():
    """A prompt of exactly max_len must be admissible: its single token
    comes from prefill logits and never writes KV (no +1 block)."""
    s = _sched(max_slots=1, prefill_len=8, max_len=8, num_blocks=3,
               block_size=4)
    s.submit(_req(0, plen=8, new=4))              # clamped to 1 new token
    plan = s.schedule(0.0)
    assert [r.rid for r in plan.prefills] == [0]
    assert s.slots[0].max_new_tokens == 1


# --------------------------------------------------------------------------
# token-weighted histograms
# --------------------------------------------------------------------------

def test_prefill_histogram_ignores_padding(moe_model):
    """Same prompt, different padding: weighted expert counts identical,
    and they sum to prompt_len * top_k per layer."""
    cfg, params = moe_model
    rt = Runtime(window_override=64)
    prompt = (np.arange(7, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    counts = {}
    for S in (16, 32):
        toks = np.zeros((1, S), np.int32)
        toks[0, :7] = prompt
        tw = np.zeros((1, S), np.float32)
        tw[0, :7] = 1.0
        cache = init_cache(cfg, rt, 1, S)
        _, _, stats = forward(params, cfg, {"tokens": jnp.asarray(toks)},
                              rt, mode="prefill", cache=cache,
                              token_weight=jnp.asarray(tw))
        counts[S] = np.asarray(stats["expert_counts"])
    np.testing.assert_allclose(counts[16], counts[32], atol=1e-5)
    np.testing.assert_allclose(counts[16].sum(axis=-1),
                               7 * cfg.moe.top_k, atol=1e-5)


# --------------------------------------------------------------------------
# online GPS controller
# --------------------------------------------------------------------------

def _counts_with_skew(L, E, skew, total=1000.0):
    p_max = skew / E
    rest = (1.0 - p_max) / (E - 1)
    p = np.full((E,), rest)
    p[0] = p_max
    return np.tile(p * total, (L, 1))


def test_controller_switches_on_skew_shift():
    full = get_config("mixtral-8x7b")
    ctl = OnlineGPSController(
        full, ControllerConfig(window_iters=2, patience=1),
        predictor_available=True, initial_strategy="dist_only")
    L, E = full.num_layers, full.moe.num_experts
    decisions = []
    t = 0.0
    for skew in (1.5, 1.5, 3.2, 3.2, 3.2, 1.05, 1.05):
        for _ in range(2):
            t += 1.0
            d = ctl.observe(_counts_with_skew(L, E, skew), t)
            if d is not None:
                decisions.append(d)
    strategies = [d.strategy for d in decisions]
    assert "token_to_expert" in strategies          # high-skew window
    assert ctl.num_switches >= 1
    # measured skew is faithfully reported
    assert decisions[0].skew == pytest.approx(1.5, abs=0.01)


def test_controller_hysteresis_needs_patience():
    full = get_config("mixtral-8x7b")
    ctl = OnlineGPSController(
        full, ControllerConfig(window_iters=1, patience=3),
        predictor_available=True, initial_strategy="dist_only")
    L, E = full.num_layers, full.moe.num_experts
    d1 = ctl.observe(_counts_with_skew(L, E, 3.2), 1.0)
    assert d1.recommended == "token_to_expert" and not d1.switched
    d2 = ctl.observe(_counts_with_skew(L, E, 3.2), 2.0)
    assert not d2.switched
    d3 = ctl.observe(_counts_with_skew(L, E, 3.2), 3.0)
    assert d3.switched and d3.strategy == "token_to_expert"


def test_controller_skew_transfer():
    full = get_config("mixtral-8x7b")
    ctl = OnlineGPSController(
        full, ControllerConfig(window_iters=1, patience=1,
                               skew_cap_observed=2.0, skew_cap_target=4.0),
        predictor_available=True)
    # measured 1.9 on a cap-2.0 model ~ concentration 0.9 -> mapped 3.7:
    # well inside token_to_expert territory on the default (PCIe) hardware
    d = ctl.observe(_counts_with_skew(full.num_layers, 4, 1.9), 1.0)
    assert d.recommended == "token_to_expert"


# --------------------------------------------------------------------------
# metrics: plan-aware imbalance
# --------------------------------------------------------------------------

def test_plan_rank_loads_identity_vs_duplicated():
    from repro.core.duplication import duplicate_experts_host
    from repro.core.placement import stack_plans
    E, R, D = 8, 4, 1
    counts = _counts_with_skew(2, E, 3.0)
    home = plan_rank_loads(counts, None, R, 0)
    assert home.shape == (2, R)
    assert imbalance(home) > 1.5                    # skewed home placement
    plans = [duplicate_experts_host(counts[l] / counts[l].sum(), R, D, 4).plan
             for l in range(2)]
    dup = plan_rank_loads(counts, stack_plans(plans), R, D)
    assert imbalance(dup) < imbalance(home)         # duplication rebalances


# --------------------------------------------------------------------------
# workloads
# --------------------------------------------------------------------------

def test_arrival_processes_basic_properties():
    from repro.workloads import (bursty_arrivals, diurnal_arrivals,
                                 poisson_arrivals)
    rng = np.random.default_rng(0)
    for times in (poisson_arrivals(5.0, 50.0, rng),
                  bursty_arrivals(1.0, 20.0, 50.0, rng),
                  diurnal_arrivals(5.0, 0.8, 20.0, 50.0, rng)):
        assert (np.diff(times) >= 0).all()
        assert times.min() >= 0 and times.max() < 50.0
        assert len(times) > 10


def test_bursty_has_heavier_tail_than_poisson():
    rng = np.random.default_rng(1)
    from repro.workloads import bursty_arrivals, poisson_arrivals
    po = np.diff(poisson_arrivals(5.0, 400.0, rng))
    bu = np.diff(bursty_arrivals(0.5, 30.0, 400.0, rng))
    # burstiness: coefficient of variation of inter-arrival gaps > Poisson's
    cv = lambda g: g.std() / g.mean()
    assert cv(bu) > cv(po) * 1.2


def test_shifting_corpus_moves_concentration():
    from repro.workloads import ShiftingCorpus, Topic
    c = ShiftingCorpus(512, [Topic("flat", 0.3, 1.0, 1),
                             Topic("hot", 3.0, 0.05, 2)],
                       schedule=[(0.0, [1, 0]), (10.0, [0, 1])])
    rng = np.random.default_rng(0)
    def top_frac(t):
        toks = np.concatenate([c.sample_prompt(t, 64, rng)
                               for _ in range(30)])
        _, cnt = np.unique(toks, return_counts=True)
        return np.sort(cnt)[-5:].sum() / cnt.sum()
    assert top_frac(10.0) > top_frac(0.0) + 0.2     # late traffic concentrated
    np.testing.assert_allclose(c.mixture(5.0), [0.5, 0.5], atol=1e-9)


def test_trace_assembly_multi_tenant():
    from repro.workloads import (ShiftingCorpus, TenantSpec, Topic,
                                 make_trace, to_serve_requests)
    corp = ShiftingCorpus(256, [Topic("t", 1.0)], [(0.0, [1.0])])
    tenants = [TenantSpec("a", corp, rate=2.0, prompt_len_max=16),
               TenantSpec("b", corp, arrivals="bursty", rate=0.5,
                          burst_rate=8.0, prompt_len_max=32)]
    trace = make_trace(tenants, horizon=40.0, seed=0)
    assert len(trace) > 20
    assert all(trace[i].arrival <= trace[i + 1].arrival
               for i in range(len(trace) - 1))
    assert {r.tenant for r in trace} == {"a", "b"}
    assert all(1 <= len(r.tokens) <= 32 for r in trace)
    reqs = to_serve_requests(trace)
    assert reqs[0].rid == trace[0].rid


# --------------------------------------------------------------------------
# end-to-end: the benchmark in smoke mode IS the acceptance test
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_bench_serve_traces_smoke():
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import bench_serve_traces
    summary, derived = bench_serve_traces.run(verbose=False, smoke=True)
    assert summary["completed"] > 0
    assert "completed=" in derived
