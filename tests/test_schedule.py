"""Token rescheduling subsystem tests (repro.schedule).

Four layers of coverage:

* quota representation — even quotas reproduce the legacy round-robin
  split; share -> quota -> share round-trips within quantisation error;
  quota rows are monotone with dead columns pinned unreachable;
* scheduler properties (greedy AND lp) — scheduled splits never exceed
  the even split's slot overflow, conserve every token (rows are
  distributions over live copies), never worsen rank imbalance, and are
  deterministic for a fixed input;
* dispatch equivalence — the sort packer consuming a reschedule quota
  stack matches the one-hot oracle bit for bit on a multi-device EP
  mesh (the same guarantee test_dispatch_equivalence.py gives the
  even-split path);
* engine integration — a meshed ContinuousEngine with the reschedule
  lever on serves a skewed trace with ZERO dropped tokens at smoke
  shapes and ZERO post-warmup recompiles.
"""

import numpy as np
import pytest

from repro.core.duplication import duplicate_experts_host
from repro.data.synthetic import skewed_distribution
from repro.schedule import (RESCHED_Q, even_quota, even_quota_stack,
                            even_shares, make_scheduler,
                            quota_realized_shares)
from tests.test_distributed import run_sub

EP_RANKS, DUP_SLOTS, MAX_COPIES = 4, 2, 4


def _plan(dist, seed=0):
    return duplicate_experts_host(np.asarray(dist, np.float64), EP_RANKS,
                                  DUP_SLOTS, MAX_COPIES).plan


def _skewed_case(E=16, alpha=3.0, tokens=4096, seed=0):
    rng = np.random.default_rng(seed)
    dist = skewed_distribution(E, alpha, rng=rng)
    counts = np.asarray(dist, np.float64) * tokens
    return counts, _plan(dist)


# --------------------------------------------------------------------------
# quota representation
# --------------------------------------------------------------------------

def test_even_quota_reproduces_round_robin_shares():
    counts, plan = _skewed_case(seed=1)
    n_rep = np.asarray(plan.n_replicas, np.int64)
    got = quota_realized_shares(even_quota(plan))
    want = even_shares(n_rep, np.asarray(plan.replica_table).shape[1])
    np.testing.assert_allclose(got, want, atol=2.0 / RESCHED_Q)


def test_quota_roundtrip_and_monotonicity():
    counts, plan = _skewed_case(seed=2)
    sched = make_scheduler("greedy")
    res = sched.plan_layer(counts, plan, ep_ranks=EP_RANKS,
                           dup_slots=DUP_SLOTS, cap=counts.sum() / 8)
    q = res.quota
    n_rep = np.asarray(plan.n_replicas, np.int64)
    assert q.dtype == np.int32 and q.shape == res.shares.shape
    # monotone rows; dead columns unreachable; realized ~= planned shares
    assert (np.diff(q, axis=1) >= 0).all()
    cols = np.arange(q.shape[1])[None, :]
    assert (q[cols >= np.maximum(n_rep, 1)[:, None] - 1] == RESCHED_Q).all()
    np.testing.assert_allclose(quota_realized_shares(q), res.shares,
                               atol=2.0 / RESCHED_Q)


def test_even_quota_stack_shape_is_static():
    _, plan = _skewed_case(seed=3)
    stack = even_quota_stack(6, plan)
    E, C = np.asarray(plan.replica_table).shape
    assert stack.shape == (6, E, C) and stack.dtype == np.int32
    assert (stack[0] == stack[-1]).all()


# --------------------------------------------------------------------------
# scheduler properties (both impls behind one interface)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["greedy", "lp"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_scheduler_never_worse_than_even_split(impl, seed):
    counts, plan = _skewed_case(alpha=2.0 + seed, seed=seed)
    n_rep = np.asarray(plan.n_replicas, np.int64)
    cap = counts.sum() / (counts.shape[0] * 0.6)    # tight: forces overflow
    res = make_scheduler(impl).plan_layer(counts, plan, ep_ranks=EP_RANKS,
                                          dup_slots=DUP_SLOTS, cap=cap)
    sh = res.shares
    cols = np.arange(sh.shape[1])[None, :]
    live = cols < np.maximum(n_rep, 1)[:, None]
    # conservation: every row a distribution over live copies only
    assert (sh >= 0).all() and (sh[~live] == 0).all()
    np.testing.assert_allclose(sh.sum(1), 1.0, atol=1e-9)
    tok = (sh * counts[:, None]).sum()
    np.testing.assert_allclose(tok, counts.sum(), rtol=1e-12)
    # capacity: scheduled split never overflows more than the even split
    assert res.overflow_sched <= res.overflow_even + 1e-9, impl
    # balance: rank imbalance never degrades
    assert res.imbalance_sched <= res.imbalance_even + 1e-9, impl
    assert 0.0 <= res.overflow_absorbed_frac <= 1.0


@pytest.mark.parametrize("impl", ["greedy", "lp"])
@pytest.mark.parametrize("seed", [0, 1, 4])
def test_scheduler_strictly_levels_rank_loads(impl, seed):
    """With replicas on other ranks and headroom under the slot cap, the
    scheduler must strictly reduce rank imbalance by moving real token
    mass — without manufacturing any slot overflow (the quota only splits
    an expert's traffic across its OWN copies, so per-expert overflow can
    never beat the even split; absorption of genuine overflow is the
    dispatch rescue round's job, tested below at the engine level)."""
    counts, plan = _skewed_case(E=16, alpha=5.0, seed=seed)
    cap = counts.mean() * 4
    res = make_scheduler(impl).plan_layer(counts, plan, ep_ranks=EP_RANKS,
                                          dup_slots=DUP_SLOTS, cap=cap)
    assert res.overflow_even == 0.0 and res.overflow_sched == 0.0
    assert res.imbalance_sched < res.imbalance_even - 0.01, impl
    assert res.moved_tokens > 0


@pytest.mark.parametrize("impl", ["greedy", "lp"])
def test_scheduler_deterministic(impl):
    counts, plan = _skewed_case(seed=11)
    kw = dict(ep_ranks=EP_RANKS, dup_slots=DUP_SLOTS,
              cap=counts.sum() / 10)
    a = make_scheduler(impl).plan_layer(counts, plan, **kw)
    b = make_scheduler(impl).plan_layer(counts, plan, **kw)
    assert np.array_equal(a.quota, b.quota)
    assert np.array_equal(a.shares, b.shares)


def test_plan_stack_stacks_per_layer_quotas():
    L, E = 3, 16
    rng = np.random.default_rng(5)
    counts = np.stack([skewed_distribution(E, 2.0 + l) * 2048
                       for l in range(L)])
    plans = [_plan(counts[l] / counts[l].sum()) for l in range(L)]
    quota, results = make_scheduler("greedy").plan_stack(
        counts, plans, ep_ranks=EP_RANKS, dup_slots=DUP_SLOTS, cap=256.0)
    assert quota.shape[0] == L and quota.dtype == np.int32
    assert len(results) == L
    for l, r in enumerate(results):
        assert np.array_equal(quota[l], r.quota)


def test_make_scheduler_rejects_unknown_impl():
    with pytest.raises(ValueError):
        make_scheduler("simplex")


# --------------------------------------------------------------------------
# dispatch equivalence + engine integration (multi-device, slow)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_ep_forward_with_resched_sort_matches_onehot_multidevice():
    """Sort dispatch consuming a scheduler quota stack is bit-exact with
    the one-hot oracle on a (2, 4) mesh — counts, slots, drops, logits."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.core.duplication import duplicate_experts_host
        from repro.core.placement import stack_plans
        from repro.data.synthetic import skewed_distribution
        from repro.models.transformer import Runtime, forward, init_model
        from repro.schedule import make_scheduler

        base = get_config("mixtral-8x7b").reduced()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4)
        m = base.moe
        B, S = 4, 32

        layers, plans = [], []
        sched = make_scheduler("greedy")
        quotas = []
        for l in range(base.num_layers):
            dist = skewed_distribution(m.num_experts, 3.0 + l)
            plan = duplicate_experts_host(dist, 4, m.duplication_slots,
                                          m.max_copies).plan
            plans.append(plan)
            counts = dist * B * S * m.top_k
            cap = (B * S // 4) * m.top_k * m.capacity_factor
            quotas.append(sched.plan_layer(
                counts, plan, ep_ranks=4, dup_slots=m.duplication_slots,
                cap=float(cap) * 4).quota)
        plan_stack = stack_plans(plans)
        resched = jnp.asarray(np.stack(quotas))

        out = {}
        runs = {}
        for impl in ("onehot", "sort"):
            cfg = dataclasses.replace(base, moe=dataclasses.replace(
                m, dispatch_impl=impl, capacity_factor=1.0))
            params = init_model(jax.random.PRNGKey(0), cfg)
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)}
            logits, _, stats = jax.jit(
                lambda p, b, r, c=cfg: forward(p, c, b, rt, mode="train",
                                               plan=plan_stack, resched=r)
            )(params, batch, resched)
            runs[impl] = (logits, stats)
        la, sa = runs["onehot"]; lb, sb = runs["sort"]
        print(json.dumps({
            "logits_diff": float(jnp.abs(
                la.astype(jnp.float32) - lb.astype(jnp.float32)).max()),
            "counts_eq": bool(jnp.array_equal(sa["expert_counts"],
                                              sb["expert_counts"])),
            "slots_eq": bool(jnp.array_equal(sa["slot_counts"],
                                             sb["slot_counts"])),
            "dropped_a": int(np.asarray(sa["dropped"]).sum()),
            "dropped_b": int(np.asarray(sb["dropped"]).sum()),
            "moved": int(np.abs(np.asarray(sa["slot_counts"], np.int64)
                                ).sum()),
        }))
    """)
    assert res["counts_eq"]
    assert res["slots_eq"]
    assert res["dropped_a"] == res["dropped_b"]
    assert res["logits_diff"] < 1e-5, res["logits_diff"]


@pytest.mark.slow
def test_engine_reschedule_zero_drops_no_recompiles_multidevice():
    """Meshed ContinuousEngine, reschedule lever on, tight capacity:
    the rescue round + scheduler quotas absorb ALL capacity overflow
    (zero dropped tokens) and the lever never recompiles post-warmup."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.models.transformer import init_model
        from repro.serve import (ContinuousConfig, ContinuousEngine,
                                 ServeRequest)

        base = get_config("mixtral-8x7b").reduced()
        # cap floor is 8/rank (moe.dispatch.capacity), so the per-rank
        # token count must exceed it for capacity pressure to exist:
        # prefill_len=64 seq-sharded over 4 EP ranks = 16 tokens/rank,
        # constant prompts route them all to one expert, capf 0.5 -> cap 8
        cfg = dataclasses.replace(base, moe=dataclasses.replace(
            base.moe, capacity_factor=0.5, duplication_slots=1))
        params = init_model(jax.random.PRNGKey(0), cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = {}
        for lever in ("duplicate", "reschedule"):
            ccfg = ContinuousConfig(max_slots=4, prefill_len=64,
                                    block_size=8, max_len=96,
                                    strategy="dist_only", lever=lever)
            eng = ContinuousEngine(cfg, params, ccfg, mesh=mesh, ep_ranks=4)
            eng.warmup()
            rng = np.random.default_rng(0)
            reqs = [ServeRequest(
                        rid=i,
                        tokens=np.full(int(rng.integers(40, 60)), 7,
                                       np.int32),
                        max_new_tokens=int(rng.integers(1, 6)),
                        arrival=float(i) * 0.01)
                    for i in range(10)]
            eng.run_trace(reqs)
            eng.assert_no_recompiles()
            s = eng.metrics.summary()
            out[lever] = {
                "completed": len(eng.scheduler.completed),
                "dropped": s.get("dropped_tokens", -1.0),
                "overflow": s.get("overflow_tokens", -1.0),
                "absorbed": s.get("overflow_absorbed_frac", -1.0),
                "a2a": s.get("resched_a2a_bytes", 0.0),
                "plans": s.get("resched_plans", 0.0),
            }
        print(json.dumps(out))
    """)
    dup, rs = res["duplicate"], res["reschedule"]
    assert dup["completed"] == 10 and rs["completed"] == 10
    # duplicate-only genuinely drops under this pressure...
    assert dup["dropped"] > 0, res
    # ...and the reschedule lever absorbs ALL of it: the rescue round sees
    # every round-1 overflow token and re-lands it within capacity
    assert rs["plans"] >= 1
    assert rs["overflow"] > 0, res
    assert rs["dropped"] == 0.0, res
    assert rs["absorbed"] == 1.0, res
    assert rs["a2a"] > 0, res
