"""Multi-device tests (EP dispatch, duplication, serving loop, sharding).

These need >1 device, so each test runs in a SUBPROCESS with
xla_force_host_platform_device_count=8 — the main pytest process keeps the
single-device view required by the smoke tests."""

import json
import os
import subprocess

import pytest
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


pytestmark = pytest.mark.slow  # every test here boots a subprocess mesh


def run_sub(body: str, timeout=900) -> dict:
    """Run `body` under 8 fake devices; it must print a JSON dict."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=timeout,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_ep_dispatch_matches_dense_reference():
    """EP shard_map dispatch == single-device dense MoE forward (same
    params, capacity high enough that nothing drops)."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.models.transformer import Runtime, forward, init_model

        cfg = get_config("mixtral-8x7b").reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = init_model(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 32), 0, cfg.vocab_size)}
        dense_logits, _, _ = forward(params, cfg, batch, Runtime(),
                                     mode="train")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4)
        ep_logits, _, stats = jax.jit(
            lambda p, b: forward(p, cfg, b, rt, mode="train"))(params, batch)
        diff = float(jnp.abs(dense_logits.astype(jnp.float32)
                             - ep_logits.astype(jnp.float32)).max())
        print(json.dumps({"diff": diff}))
    """)
    assert res["diff"] < 0.1             # bf16 path differences only


def test_duplication_improves_measured_balance():
    res = run_sub("""
        from repro.configs.registry import get_config
        from repro.models.transformer import init_model
        from repro.serve import ServeEngine, ServeConfig
        from repro.data.synthetic import token_batches

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("mixtral-8x7b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        out = {}
        for strat in ("none", "dist_only"):
            eng = ServeEngine(cfg, params,
                              ServeConfig(strategy=strat, dup_slots=1),
                              mesh=mesh, ep_ranks=4)
            gen = token_batches(0, cfg.vocab_size, batch=4, seq_len=32)
            for i in range(4):
                _, _, stats = eng.prefill(
                    {"tokens": jnp.asarray(next(gen)["tokens"])})
            rl = eng.rank_loads(np.asarray(stats["slot_counts"]))
            out[strat] = float((rl.max(1) / rl.mean(1)).mean())
        print(json.dumps(out))
    """)
    assert res["dist_only"] < res["none"] - 0.05


def test_t2e_predicted_dispatch_correctness():
    """Predicted pre-routing with correction == unpredicted dispatch
    outputs (same tokens end at the same experts regardless of route)."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.models.transformer import Runtime, forward, init_model

        cfg = get_config("mixtral-8x7b").reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = init_model(jax.random.PRNGKey(0), cfg)
        B, S = 4, 32
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (B, S), 0, cfg.vocab_size)}
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4)
        ref_logits, _, _ = jax.jit(
            lambda p, b: forward(p, cfg, b, rt, mode="train"))(params, batch)
        # deliberately WRONG predictions: correction round must fix them
        pred = jnp.zeros((cfg.num_layers, B, S, cfg.moe.top_k), jnp.int32)
        lg, _, _ = jax.jit(
            lambda p, b, pr: forward(p, cfg, b, rt, mode="train",
                                     predicted_idx=pr))(params, batch, pred)
        diff = float(jnp.abs(ref_logits.astype(jnp.float32)
                             - lg.astype(jnp.float32)).max())
        print(json.dumps({"diff": diff}))
    """)
    # all-wrong predictions stress the correction path; capacity 8x keeps
    # drops at zero so outputs must match
    assert res["diff"] < 0.1


def test_param_specs_shard_and_gather_consistency():
    """Sharded + fsdp params produce the same forward as replicated."""
    res = run_sub("""
        from repro.configs.registry import get_config
        from repro.models.transformer import Runtime, forward, init_model
        from repro.sharding import make_shardings, param_specs

        cfg = get_config("olmo-1b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.zeros((4, 16), jnp.int32)}
        ref, _, _ = forward(params, cfg, batch, Runtime(), mode="train")

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        specs = param_specs(params, fsdp_axes=("data",), fsdp_size=2,
                            mesh=mesh)
        sharded = jax.device_put(params, make_shardings(mesh, specs))
        rt = Runtime(mesh=mesh)
        with mesh:
            out, _, _ = jax.jit(
                lambda p, b: forward(p, cfg, b, rt, mode="train"))(
                    sharded, batch)
        diff = float(jnp.abs(ref.astype(jnp.float32)
                             - out.astype(jnp.float32)).max())
        print(json.dumps({"diff": diff}))
    """)
    assert res["diff"] < 5e-2          # bf16 matmul partitioning noise


def test_dev_mesh_decode_moe():
    """EP decode path (replicated tokens + psum combine) matches dense."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.models.transformer import Runtime, forward, init_model, \
            init_cache
        from repro.train.steps import make_decode_step

        cfg = get_config("deepseek-v2-lite-16b").reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = init_model(jax.random.PRNGKey(0), cfg)
        B = 4
        tok = jnp.ones((B, 1), jnp.int32)

        dense_cache = init_cache(cfg, Runtime(), B, 32)
        _, dl, _, _ = make_decode_step(cfg, Runtime())(
            params, tok, dense_cache, 5)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4)
        ep_cache = init_cache(cfg, rt, B, 32)
        with mesh:
            _, el, _, _ = jax.jit(
                lambda p, t, c: make_decode_step(cfg, rt)(p, t, c, 5))(
                    params, tok, ep_cache)
        diff = float(jnp.abs(dl.astype(jnp.float32)
                             - el.astype(jnp.float32)).max())
        print(json.dumps({"diff": diff}))
    """)
    assert res["diff"] < 0.1


def test_expert_tp_decode_matches_dense():
    """2D expert sharding (EP x f-TP, EXPERIMENTS.md Perf cycle 2): decode
    outputs match the dense reference; weights stay resident."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.models.transformer import Runtime, forward, init_model, \
            init_cache
        from repro.train.steps import make_decode_step

        cfg = get_config("mixtral-8x7b").reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = init_model(jax.random.PRNGKey(0), cfg)
        B = 4
        tok = jnp.ones((B, 1), jnp.int32)
        _, dl, _, _ = make_decode_step(cfg, Runtime())(
            params, tok, init_cache(cfg, Runtime(), B, 32), 5)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4, decode_expert_tp=True)
        cache = init_cache(cfg, rt, B, 32)
        with mesh:
            _, el, _, stats = jax.jit(
                lambda p, t, c: make_decode_step(cfg, rt)(p, t, c, 5))(
                    params, tok, cache)
        diff = float(jnp.abs(dl.astype(jnp.float32)
                             - el.astype(jnp.float32)).max())
        counts = float(np.asarray(stats["expert_counts"]).sum())
        print(json.dumps({"diff": diff, "counts": counts,
                          "expect": cfg.num_layers * B * cfg.moe.top_k}))
    """)
    assert res["diff"] < 5e-2
    assert res["counts"] == res["expect"]


def test_in_graph_replan_balances():
    """Fused predict->plan->dispatch (duplicate_experts_jax inside the
    prefill step) balances as well as the host-side planner.

    The pass threshold is DERIVED per run: round-robin over the active
    plan's replica sets has an achievable imbalance for the observed
    expert histogram (`plan_rank_loads`), and the measured slot loads may
    only exceed it by the round-robin discretization + capacity-drop
    margin. A fixed magic constant here was flaky — borderline runs
    measured ~1.42 against an asserted 1.35 (see CHANGES.md, PR 1)."""
    res = run_sub("""
        from repro.configs.registry import get_config
        from repro.models.transformer import init_model
        from repro.serve import ServeEngine, ServeConfig
        from repro.serve.metrics import imbalance, plan_rank_loads
        from repro.data.synthetic import token_batches

        np.random.seed(0)                       # routing inputs: one stream
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("mixtral-8x7b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        out = {}
        for in_graph in (False, True):
            eng = ServeEngine(cfg, params,
                              ServeConfig(strategy="dist_only", dup_slots=1,
                                          in_graph_replan=in_graph),
                              mesh=mesh, ep_ranks=4)
            gen = token_batches(0, cfg.vocab_size, batch=4, seq_len=32)
            for i in range(4):
                plan_used = eng._current_plan()   # active DURING the batch
                _, _, stats = eng.prefill(
                    {"tokens": jnp.asarray(next(gen)["tokens"])})
            rl = eng.rank_loads(np.asarray(stats["slot_counts"]))
            counts = np.asarray(stats["expert_counts"], np.float64)
            ach = imbalance(plan_rank_loads(
                counts, plan_used, eng.ep_ranks,
                eng.moe_cfg.duplication_slots))
            key = "graph" if in_graph else "host"
            out[key] = float((rl.max(1) / rl.mean(1)).mean())
            out[key + "_achievable"] = float(ach)
        print(json.dumps(out))
    """)
    for mode in ("graph", "host"):
        # achievable + round-robin discretization / drop slack
        assert res[mode] < res[mode + "_achievable"] * 1.1 + 0.1, res
        assert res[mode] < 1.6                 # none-strategy level: unbalanced
    assert abs(res["graph"] - res["host"]) < 0.25
