"""Property tests on the EP dispatch helpers (single device, hypothesis)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.duplication import duplicate_experts_jax
from repro.core.placement import identity_plan, plan_dims
from repro.data.synthetic import skewed_distribution
from repro.moe.dispatch import _positions_in_slot, capacity, choose_replica


@given(st.integers(1, 200), st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_positions_in_slot_are_dense_ranks(n, num_slots):
    rng = np.random.default_rng(n * 31 + num_slots)
    gslot = rng.integers(0, num_slots, size=n).astype(np.int32)
    pos = np.asarray(_positions_in_slot(jnp.asarray(gslot), num_slots))
    for s in range(num_slots):
        got = sorted(pos[gslot == s].tolist())
        assert got == list(range(len(got)))      # 0..count-1, no gaps


@given(st.integers(1, 4096), st.integers(1, 8), st.integers(4, 64),
       st.floats(1.0, 4.0))
@settings(max_examples=50, deadline=None)
def test_capacity_covers_expected_load(t_local, top_k, slots, factor):
    c = capacity(t_local, top_k, slots, factor)
    assert c >= 8 and c % 8 == 0
    assert c * slots >= t_local * top_k          # factor >= 1: no forced drop


@given(st.floats(1.0, 7.5), st.integers(0, 2), st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_choose_replica_targets_host_slots(skew, dup_slots, salt0):
    """Every chosen slot actually hosts the token's expert (identity AND
    post-duplication plans)."""
    E, R = 8, 4
    e_loc, n_slots = plan_dims(E, R, dup_slots)
    dist = skewed_distribution(E, skew)
    plans = [identity_plan(E, R, dup_slots, 4)]
    if dup_slots:
        plans.append(duplicate_experts_jax(jnp.asarray(dist), R, dup_slots, 4))
    expert = jnp.arange(64, dtype=jnp.int32) % E
    salt = (jnp.arange(64, dtype=jnp.int32) + salt0)
    for plan in plans:
        gslot = np.asarray(choose_replica(plan, expert, salt))
        table = np.asarray(plan.replica_table)
        n_rep = np.asarray(plan.n_replicas)
        for e, g in zip(np.asarray(expert), gslot):
            assert g in table[e, :n_rep[e]], (e, g, table[e])


@given(st.floats(1.5, 7.5))
@settings(max_examples=25, deadline=None)
def test_round_robin_spreads_hot_expert(skew):
    """Tokens of a duplicated expert land on ALL of its replicas."""
    E, R, D = 8, 4, 2
    dist = skewed_distribution(E, skew)
    plan = duplicate_experts_jax(jnp.asarray(dist), R, D, 4)
    n_rep = np.asarray(plan.n_replicas)
    hot = int(np.argmax(n_rep))
    if n_rep[hot] < 2:
        return
    expert = jnp.full((256,), hot, jnp.int32)
    salt = jnp.arange(256, dtype=jnp.int32)
    gslot = np.asarray(choose_replica(plan, expert, salt))
    assert len(set(gslot.tolist())) == n_rep[hot]
    # round-robin is near-even
    counts = np.bincount(gslot)
    counts = counts[counts > 0]
    assert counts.max() - counts.min() <= 1
