"""Deterministic fallback for the ``hypothesis`` package.

The property tests in this suite use a small slice of the hypothesis API
(``given``, ``settings``, ``strategies.integers/floats/lists/sampled_from``
and ``flatmap``). When the real package is unavailable (the benchmark
container does not ship it and tier-1 must not pip-install), ``conftest``
installs this module under ``sys.modules['hypothesis']`` so the tests
still collect AND run — each ``@given`` test executes over a fixed,
seeded sample of the strategy space instead of an adaptive search.

This is intentionally NOT a shrinking property-based tester; it trades
adversarial example search for zero dependencies. With the real package
installed the stub is never used.
"""

from __future__ import annotations

import functools
import random
import zlib

_EXAMPLES_PER_TEST = 25


class Strategy:
    """A seeded example generator with the combinators our tests use."""

    def __init__(self, sample):
        self._sample = sample          # rng -> value

    def example(self, rng: random.Random):
        return self._sample(rng)

    def flatmap(self, fn):
        return Strategy(lambda rng: fn(self.example(rng)).example(rng))

    def map(self, fn):
        return Strategy(lambda rng: fn(self.example(rng)))

    def filter(self, pred, tries: int = 100):
        def sample(rng):
            for _ in range(tries):
                v = self.example(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return Strategy(sample)


def integers(min_value, max_value):
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans():
    return Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda rng: rng.choice(seq))


def lists(elements: Strategy, min_size=0, max_size=10):
    def sample(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return Strategy(sample)


def just(value):
    return Strategy(lambda rng: value)


def tuples(*strategies):
    return Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def given(*strategies, **kw_strategies):
    """Run the test over a fixed seeded sample of the strategy space."""

    def decorator(fn):
        n = getattr(fn, "_stub_max_examples", _EXAMPLES_PER_TEST)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # crc32, not hash(): str hashing is salted per process, and the
            # whole point is a reproducible example set across runs
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                vals = [s.example(rng) for s in strategies]
                kwvals = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *vals, **kwargs, **kwvals)
                except _Unsatisfied:
                    continue                      # failed assume(): skip example
                except Exception as e:
                    raise AssertionError(
                        f"stub-hypothesis example #{i} failed with args="
                        f"{vals} kwargs={kwvals}: {e}") from e
        # hide the strategy-filled parameters from pytest's fixture
        # resolution (real hypothesis does the same): positional strategies
        # fill the RIGHTMOST params, kw strategies fill by name.
        import inspect
        params = list(inspect.signature(fn).parameters.values())
        keep = params[:len(params) - len(strategies)]
        keep = [p for p in keep if p.name not in kw_strategies]
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(keep)
        wrapper.hypothesis_stub = True
        return wrapper

    return decorator


def settings(max_examples: int | None = None, **_kw):
    """Records max_examples; other knobs (deadline, ...) are no-ops."""

    def decorator(fn):
        if max_examples is not None:
            fn._stub_max_examples = min(max_examples, _EXAMPLES_PER_TEST * 4)
        return fn

    return decorator


def assume(condition):
    """Best-effort: a failed assumption just skips the remaining checks by
    raising a private exception ``given`` treats as pass."""
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    all = classmethod(lambda cls: [])


def _install():
    """Register this module as ``hypothesis`` + ``hypothesis.strategies``."""
    import sys
    import types

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "just", "tuples"):
        setattr(st, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
