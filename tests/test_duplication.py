"""Algorithm 1 (expert duplication) — unit + property tests.

Invariants proved:
  * balance: the post-duplication bottleneck load never exceeds the
    no-duplication bottleneck;
  * constraints: <= C_max copies per expert, <= dup_slots extra copies per
    rank, one pool contribution per source rank;
  * plan consistency: every replica_table entry points at a slot whose
    rank actually hosts the expert; n_replicas matches the table;
  * jax planner: produces feasible plans that do not regress the
    bottleneck (greedy parity with the host planner is not required).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.balance import bottleneck_factor, comm_factor, error_rate, skewness
from repro.core.duplication import (bottleneck_load, duplicate_experts_host,
                                    duplicate_experts_jax)
from repro.core.placement import identity_plan, plan_dims
from repro.data.synthetic import skewed_distribution


def rank_loads_from_plan(dist, plan, ep_ranks, dup_slots):
    """Recompute per-rank loads from plan arrays only."""
    E = len(dist)
    e_loc, n_slots = plan_dims(E, ep_ranks, dup_slots)
    loads = np.zeros(ep_ranks)
    n_rep = np.asarray(plan.n_replicas)
    table = np.asarray(plan.replica_table)
    for e in range(E):
        share = dist[e] / n_rep[e]
        for c in range(n_rep[e]):
            loads[table[e, c] // n_slots] += share
    return loads


dists = st.integers(2, 6).flatmap(
    lambda log_e: st.lists(st.floats(0.01, 1.0), min_size=2 ** log_e,
                           max_size=2 ** log_e))


@given(dists, st.sampled_from([2, 4, 8]), st.integers(1, 3))
@settings(max_examples=60, deadline=None)
def test_host_planner_invariants(weights, ep_ranks, dup_slots):
    dist = np.asarray(weights)
    if dist.shape[0] % ep_ranks:
        return
    dist = dist / dist.sum()
    E = dist.shape[0]
    res = duplicate_experts_host(dist, ep_ranks, dup_slots, max_copies=4)

    base = bottleneck_load(dist, ep_ranks)
    # balance invariant (never worse than home placement)
    assert res.rank_loads.max() <= base + 1e-9
    # constraint: copies per expert
    assert np.asarray(res.plan.n_replicas).max() <= 4
    # constraint: extra copies per destination rank
    e_loc, n_slots = plan_dims(E, ep_ranks, dup_slots)
    dests = [g for (_, g) in res.assignments]
    for g in set(dests):
        assert dests.count(g) <= dup_slots
    # constraint: one pool contribution per source rank
    srcs = {}
    for (e, _) in res.assignments:
        src = e // e_loc
        srcs.setdefault(src, set()).add(e)
    assert all(len(v) == 1 for v in srcs.values())
    # plan-array consistency: loads recomputed from the plan match
    loads = rank_loads_from_plan(dist, res.plan, ep_ranks, dup_slots)
    np.testing.assert_allclose(loads, res.rank_loads, atol=1e-9)


@given(st.floats(1.0, 7.9), st.integers(1, 3))
@settings(max_examples=30, deadline=None)
def test_jax_planner_feasible_and_no_regression(skew, dup_slots):
    dist = skewed_distribution(8, skew)
    plan = duplicate_experts_jax(jnp.asarray(dist), ep_ranks=4,
                                 dup_slots=dup_slots, max_copies=4)
    n_rep = np.asarray(plan.n_replicas)
    assert n_rep.min() >= 1 and n_rep.max() <= 4
    loads = rank_loads_from_plan(dist, plan, 4, dup_slots)
    assert loads.max() <= bottleneck_load(dist, 4) + 1e-6
    # table entries point into valid slots
    e_loc, n_slots = plan_dims(8, 4, dup_slots)
    table = np.asarray(plan.replica_table)
    assert table.min() >= 0 and table.max() < 4 * n_slots


def test_duplication_fixes_hot_expert():
    """Paper Fig 2/3 scenario: expert 0 takes 75% of tokens on 4 ranks."""
    dist = np.array([0.75, 0.05, 0.05, 0.05, 0.025, 0.025, 0.025, 0.025])
    res = duplicate_experts_host(dist, ep_ranks=4, dup_slots=1, max_copies=4)
    assert bottleneck_load(dist, 4) >= 0.80           # rank 0 held 80%
    assert res.rank_loads.max() < 0.45                # after: ~balanced
    assert np.asarray(res.plan.n_replicas)[0] >= 2    # the hot expert split


def test_identity_plan_roundtrip():
    plan = identity_plan(8, 4, 2, 4)
    assert np.asarray(plan.n_replicas).tolist() == [1] * 8
    table = np.asarray(plan.replica_table)
    e_loc, n_slots = plan_dims(8, 4, 2)
    for e in range(8):
        assert table[e, 0] == (e // e_loc) * n_slots + e % e_loc


# --------------------------------------------------------------------------
# metrics (paper Sec 2 / 3.3)
# --------------------------------------------------------------------------

def test_skewness_definition():
    assert skewness([0.75, 0.25 / 3, 0.25 / 3, 0.25 / 3]) == pytest.approx(3.0)
    assert skewness([0.25] * 4) == pytest.approx(1.0)


@given(st.floats(1.0, 16.0))
@settings(max_examples=20, deadline=None)
def test_skewed_distribution_calibration(skew):
    dist = skewed_distribution(16, skew)
    assert skewness(dist) == pytest.approx(skew, rel=1e-3)
    assert dist.sum() == pytest.approx(1.0)


def test_error_rate_metric():
    p = np.array([0.5, 0.5])
    assert error_rate(p, p) == 0.0
    assert error_rate(np.array([0.6, 0.4]), p) == pytest.approx(0.2)


def test_bottleneck_factor_scenarios():
    assert bottleneck_factor(0.1, 4, "optimistic") == 1.0
    assert bottleneck_factor(0.1, 4, "typical") == pytest.approx(1.1)
    assert bottleneck_factor(0.1, 4, "pessimistic") == pytest.approx(4.4)
    assert comm_factor(0.1) == pytest.approx(1.1)
