"""Observability subsystem tests (repro.obs): span tracer nesting /
ring-buffer overflow / Chrome trace-event round-trip, metrics registry
exporters, ServeMetrics registry integration + reset_phases guard, GPS
audit records (verdict inputs match what ``recommend_strategy`` saw), and
the predictor-accuracy tracker."""

import json

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.obs import (GPSAuditLog, MetricsRegistry, NULL_TRACER,
                       PredictorAccuracyTracker, SpanTracer, hist_hit_rate,
                       hist_kl, hist_l1, merge_traces, span_names,
                       validate_chrome_trace)
from repro.serve import ControllerConfig, OnlineGPSController
from repro.serve.metrics import RequestTiming, ServeMetrics


# --------------------------------------------------------------------------
# span tracer
# --------------------------------------------------------------------------

def test_span_nesting_containment():
    tr = SpanTracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    evs = tr.events()
    assert [e[1] for e in evs] == ["inner", "outer"]   # exit order
    (_, _, _, ts_i, dur_i, tid_i, _), (_, _, _, ts_o, dur_o, tid_o, _) = evs
    assert tid_i == tid_o                              # same thread row
    assert ts_o <= ts_i and ts_i + dur_i <= ts_o + dur_o   # containment


def test_ring_buffer_overflow_counts_drops():
    tr = SpanTracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 4 and tr.dropped == 6
    assert [e[1] for e in evs] == ["e6", "e7", "e8", "e9"]   # oldest first
    assert tr.to_chrome()["otherData"]["dropped_events"] == 6


def test_chrome_trace_round_trip_schema(tmp_path):
    tr = SpanTracer(process_name="test-proc")
    with tr.span("work", args={"k": 1}):
        tr.instant("mark", track="side")
        tr.counter("load", 0.5, track="side")
    path = tmp_path / "trace.json"
    tr.export(str(path), extra={"run": "x"})
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == []
    assert {"work", "mark", "load"} <= span_names(doc)
    assert doc["otherData"]["run"] == "x"
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M"}
    assert {"test-proc", "side"} <= names       # process + track metadata
    x = [e for e in doc["traceEvents"] if e["ph"] == "X"][0]
    assert x["dur"] >= 1 and x["ts"] >= 0 and x["args"] == {"k": 1}


def test_disabled_tracer_records_nothing():
    tr = SpanTracer(enabled=False)
    with tr.span("x") as sp:
        sp.set_args(a=1)                          # no-op, must not raise
    tr.instant("y")
    tr.counter("z", 1.0)
    tr.add_span("w", 0.1)
    assert tr.events() == [] and NULL_TRACER.events() == []


def test_retrospective_spans_lay_out_sequentially():
    tr = SpanTracer()
    end = tr.add_span("a", 0.001, track="profile")
    tr.add_span("b", 0.002, ts_ns=end, track="profile")
    a, b = tr.events()
    assert b[3] == a[3] + a[4]                   # b starts where a ended


def test_merge_traces_rekeys_pids():
    t1, t2 = SpanTracer(process_name="p1"), SpanTracer(process_name="p2")
    t1.instant("a")
    t2.instant("b")
    doc = merge_traces([t1.to_chrome(), t2.to_chrome()], names=["one", "two"])
    assert validate_chrome_trace(doc) == []
    assert {e["pid"] for e in doc["traceEvents"]} == {1, 2}
    assert {e["args"]["name"] for e in doc["traceEvents"]
            if e.get("name") == "process_name"} == {"one", "two"}


def test_merge_traces_three_docs_collision_free():
    tracers = [SpanTracer(process_name=f"p{i}") for i in range(3)]
    for i, tr in enumerate(tracers):
        tr.instant(f"ev{i}")
    doc = merge_traces([t.to_chrome() for t in tracers],
                       names=["m-a", "m-b", "m-c"])
    assert validate_chrome_trace(doc) == []
    pids = {e["pid"] for e in doc["traceEvents"]}
    assert len(pids) == 3                      # no pid collisions
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("name") == "process_name"}
    assert names == {"m-a", "m-b", "m-c"}


def test_merge_traces_multi_pid_doc_keeps_processes_distinct():
    # one doc already carrying two processes (a prior merge), merged with
    # a single-pid doc: all three processes get fresh distinct pids and
    # the multi-pid doc's rows keep their sibling-distinguishing suffix
    t1, t2 = SpanTracer(process_name="eng"), SpanTracer(process_name="drv")
    t1.instant("a")
    t2.instant("b")
    inner = merge_traces([t1.to_chrome(), t2.to_chrome()],
                         names=["eng", "drv"])
    t3 = SpanTracer(process_name="late")
    t3.instant("c")
    doc = merge_traces([inner, t3.to_chrome()], names=["fleet", "m2"])
    assert validate_chrome_trace(doc) == []
    assert len({e["pid"] for e in doc["traceEvents"]}) == 3
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("name") == "process_name"}
    assert names == {"fleet/eng", "fleet/drv", "m2"}


def test_merge_traces_tags_docs_missing_process_name_rows():
    bare = {"traceEvents": [
        {"ph": "i", "name": "x", "pid": 7, "tid": 0, "ts": 0.0, "s": "t"}]}
    t = SpanTracer(process_name="real")
    t.instant("y")
    doc = merge_traces([bare, t.to_chrome()], names=["synth", "real"])
    rows = {e["pid"]: e["args"]["name"] for e in doc["traceEvents"]
            if e.get("name") == "process_name"}
    assert set(rows.values()) == {"synth", "real"}
    assert len(rows) == 2


def test_validator_rejects_malformed_docs():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({}) != []
    bad = {"traceEvents": [{"ph": "Q", "name": "x"},
                           {"ph": "X", "name": "x", "ts": -5, "dur": 1,
                            "pid": 1, "tid": 1},
                           {"ph": "X", "name": "x", "ts": 1, "pid": 1,
                            "tid": 1}]}    # bad phase / neg ts / missing dur
    errs = validate_chrome_trace(bad)
    assert len(errs) == 3


def test_validate_cli(tmp_path):
    from repro.obs.validate import main
    tr = SpanTracer()
    tr.instant("present")
    good = tmp_path / "good.json"
    tr.export(str(good))
    assert main([str(good)]) == 0
    assert main([str(good), "--require", "present"]) == 0
    assert main([str(good), "--require", "absent"]) == 1
    bad = tmp_path / "bad.json"
    bad.write_text("[1, 2]")
    assert main([str(bad)]) == 1


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc()
    reg.counter("req_total", "requests").inc(2)
    with pytest.raises(ValueError):
        reg.counter("req_total", "x").inc(-1)
    reg.gauge("depth", "queue depth").set(7)
    h = reg.histogram("lat_s", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["req_total"] == 3.0 and snap["depth"] == 7.0
    assert snap["lat_s_count"] == 3.0
    assert snap["lat_s_sum"] == pytest.approx(5.55)


def test_registry_labels_and_type_conflicts():
    reg = MetricsRegistry()
    reg.counter("tok", "tokens", phase="prefill").inc(5)
    reg.counter("tok", "tokens", phase="decode").inc(2)
    assert reg.counter("tok", "tokens", phase="prefill").value == 5.0
    with pytest.raises(ValueError):
        reg.gauge("tok", "tokens")               # name already a counter


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests served", tenant="a").inc(4)
    reg.histogram("lat_s", "latency", buckets=(1.0,)).observe(0.5)
    text = reg.to_prometheus()
    assert "# HELP req_total requests served" in text
    assert "# TYPE req_total counter" in text
    assert 'req_total{tenant="a"} 4' in text
    assert 'lat_s_bucket{le="1.0"} 1' in text
    assert 'lat_s_bucket{le="+Inf"} 1' in text
    assert "lat_s_count 1" in text


def test_registry_jsonl_export(tmp_path):
    reg = MetricsRegistry()
    reg.gauge("x", "x").set(1.5)
    path = tmp_path / "m.jsonl"
    reg.to_jsonl(str(path), extra={"step": 1})
    reg.gauge("x", "x").set(2.5)
    reg.to_jsonl(str(path), extra={"step": 2})
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == 2
    assert all(l["metric"] == "x" and l["type"] == "gauge" for l in lines)
    assert lines[0]["value"] == 1.5 and lines[1]["value"] == 2.5
    assert lines[1]["step"] == 2


# --------------------------------------------------------------------------
# ServeMetrics integration
# --------------------------------------------------------------------------

def test_serve_metrics_publishes_registry():
    m = ServeMetrics(window_iters=2)
    m.record_completion(RequestTiming(rid=0, arrival=0.0, t_first_token=0.2,
                                      t_finished=1.0, prompt_len=8,
                                      new_tokens=5))
    m.record_iteration(0.0, 0.1, prefill_tokens=8, decode_tokens=0,
                       counts=None, plan=None, ep_ranks=1, dup_slots=0)
    s = m.summary()
    snap = m.registry.snapshot()
    assert snap["serve_requests_completed_total"] == 1.0
    assert snap["serve_ttft_seconds_count"] == 1.0
    assert snap["serve_completed"] == s["completed"] == 1.0
    assert snap["serve_ttft_p50"] == pytest.approx(s["ttft_p50"])
    assert "serve_completed" in m.registry.to_prometheus()


def test_reset_phases_guards_double_accumulation():
    m = ServeMetrics()
    m.record_phases({"route": 1e-3, "total": 2e-3})
    m.record_phases({"route": 1e-3, "total": 2e-3})   # accumulates by design
    assert m.phase_times["route"] == pytest.approx(2e-3)
    old = m.reset_phases()
    assert old["route"] == pytest.approx(2e-3)
    assert m.phase_times == {}
    m.record_phases({"route": 5e-4, "total": 1e-3})   # fresh shape, clean
    assert m.summary()["phase_route_us"] == pytest.approx(500.0)


def test_record_accuracy_lands_on_window():
    m = ServeMetrics(window_iters=2)
    m.record_iteration(0.0, 0.1, prefill_tokens=1, decode_tokens=0,
                       counts=None, plan=None, ep_ranks=1, dup_slots=0)
    m.record_accuracy(0.75, 0.1)
    m.record_iteration(0.1, 0.1, prefill_tokens=0, decode_tokens=1,
                       counts=None, plan=None, ep_ranks=1, dup_slots=0)
    assert m.windows[0].pred_hit_rate == pytest.approx(0.75)
    assert m.windows[0].pred_kl == pytest.approx(0.1)
    assert m.registry.snapshot()["serve_pred_hit_rate"] == 0.75


# --------------------------------------------------------------------------
# GPS decision audit
# --------------------------------------------------------------------------

def _counts_with_skew(L, E, skew, total=4096.0):
    p_max = skew / E
    rest = (1.0 - p_max) / (E - 1)
    p = np.full((E,), rest)
    p[0] = p_max
    return np.tile(p * total, (L, 1))


def test_audit_records_exact_recommend_inputs(monkeypatch):
    """Every audited input must be the value recommend_strategy actually
    received — capture the real call and compare field by field."""
    import repro.serve.controller as ctl_mod
    seen = {}
    real = ctl_mod.recommend_strategy

    def spy(model_cfg, hw, **kw):
        seen.update(kw)
        return real(model_cfg, hw, **kw)

    monkeypatch.setattr(ctl_mod, "recommend_strategy", spy)
    full = get_config("mixtral-8x7b")
    ctl = OnlineGPSController(
        full, ControllerConfig(window_iters=1, patience=1,
                               skew_cap_observed=2.0, skew_cap_target=4.0),
        predictor_available=True, initial_strategy="dist_only")
    d = ctl.observe(_counts_with_skew(full.num_layers, 4, 1.9), 1.0,
                    migration_bytes=1e6, migration_hidden_bytes=5e5)
    assert d is not None and len(ctl.audit) == 1
    rec = ctl.audit.records[0]
    assert rec.skew_input == pytest.approx(seen["skew"])
    assert rec.skew_input != pytest.approx(rec.skew_measured)  # transferred
    assert rec.batch == seen["batch"] and rec.seq_len == seen["seq"]
    assert rec.allow_t2e == seen["allow_t2e"]
    assert rec.min_saving == pytest.approx(seen["min_saving"])
    assert rec.migration_stall_s == pytest.approx(seen["migration_stall_s"])
    assert rec.migration_bytes == pytest.approx(1e6)
    assert rec.migration_hidden_frac == pytest.approx(0.5)
    assert rec.recommended == d.recommended
    assert rec.strategy_after == d.strategy
    assert rec.gate == ("switched" if d.switched else "pending")
    assert rec.baseline_total_s > 0 and "=>" in rec.explain()


def test_audit_gate_tracks_hysteresis():
    full = get_config("mixtral-8x7b")
    ctl = OnlineGPSController(
        full, ControllerConfig(window_iters=1, patience=2),
        predictor_available=True, initial_strategy="dist_only")
    L, E = full.num_layers, full.moe.num_experts
    ctl.observe(_counts_with_skew(L, E, 3.2), 1.0)
    ctl.observe(_counts_with_skew(L, E, 3.2), 2.0)
    gates = [r.gate for r in ctl.audit.records]
    assert gates == ["pending", "switched"]
    assert len(ctl.audit.switches) == 1
    assert ctl.audit.summary()["gps_verdicts"] == 2.0


def test_audit_log_bounded(tmp_path):
    log = GPSAuditLog(maxlen=2)
    full = get_config("mixtral-8x7b")
    ctl = OnlineGPSController(
        full, ControllerConfig(window_iters=1, patience=1),
        predictor_available=False, audit=log)
    for t in range(4):
        ctl.observe(_counts_with_skew(full.num_layers,
                                      full.moe.num_experts, 1.5), float(t))
    assert len(log) == 2 and log.dropped == 2
    assert log.records[-1].seq == 3                 # seq survives eviction
    path = tmp_path / "audit.jsonl"
    log.to_jsonl(str(path))
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == 2 and lines[-1]["seq"] == 3


# --------------------------------------------------------------------------
# predictor accuracy
# --------------------------------------------------------------------------

def test_hist_scores_perfect_and_wrong():
    p = np.array([[0.7, 0.2, 0.1], [0.6, 0.3, 0.1]])
    assert hist_hit_rate(p, p) == 1.0
    assert hist_kl(p, p) == pytest.approx(0.0, abs=1e-6)
    assert hist_l1(p, p) == pytest.approx(0.0, abs=1e-6)
    wrong = p[:, ::-1]
    assert hist_hit_rate(p, wrong) == 0.0
    assert hist_kl(p, wrong) > 0.1


def test_accuracy_tracker_windows_and_modes():
    tr = PredictorAccuracyTracker(num_layers=2, num_experts=3)
    pred = np.array([[0.7, 0.2, 0.1], [0.6, 0.3, 0.1]])
    # window 1: dist_only, realized matches the prediction
    tr.begin_window(pred, "dist_only")
    tr.observe(pred * 100)
    tr.observe(None)                                # MoE-less iteration
    w = tr.close_window()
    assert w.hit_rate == 1.0 and w.tokens == pytest.approx(200.0)
    # window 2: token_to_expert, realized argmax disagrees everywhere
    tr.begin_window(pred, "token_to_expert")
    tr.observe(pred[:, ::-1] * 100)
    assert tr.close_window().hit_rate == 0.0
    # window 3: no prediction (strategy none) -> not scored
    tr.begin_window(None, "none")
    tr.observe(pred * 100)
    assert tr.close_window() is None
    # window 4: prediction but zero routed tokens -> not scored
    tr.begin_window(pred, "dist_only")
    assert tr.close_window() is None
    s = tr.summary()
    assert s["pred_windows"] == 2.0
    assert s["pred_hit_rate"] == pytest.approx(0.5)
    assert s["pred_dist_hit_rate"] == 1.0
    assert s["pred_t2e_hit_rate"] == 0.0
    assert len(tr.to_obj()) == 2


# --------------------------------------------------------------------------
# migration executor tracing
# --------------------------------------------------------------------------

def test_executor_emits_migration_spans():
    import jax.numpy as jnp
    from repro.core.placement import identity_plan, stack_plans
    from repro.runtime import (MigrationExecutor, make_migrate_step,
                               plan_diff)
    from repro.core.duplication import duplicate_experts_host

    E, R, S, L = 4, 2, 1, 2
    experts = {"w": jnp.arange(L * E * 3, dtype=jnp.float32
                               ).reshape(L, E, 3)}
    step = make_migrate_step(None, num_experts=E, ep_ranks=R, dup_slots=S)
    ident = stack_plans([identity_plan(E, R, S, 2) for _ in range(L)])
    dist = np.array([[0.7, 0.1, 0.1, 0.1]] * L)
    target = stack_plans([
        duplicate_experts_host(dist[l], R, S, 2).plan for l in range(L)])
    diff = plan_diff(ident, target, R, S)
    assert diff.num_entries > 0
    n_slots = E // R + S
    weights = {"w": jnp.zeros((L, R * n_slots, 3))}

    tr = SpanTracer()
    ex = MigrationExecutor(step, experts, 128, chunk=1, tracer=tr)
    ex.begin(weights, diff, target)
    while ex.active:
        ex.tick(budget=1)
    names = [e[1] for e in tr.events()]
    assert "migration.begin" in names
    assert names.count("migration.tick") >= 1
    assert names[-1] == "migration.commit"
    assert "migration.cancel" not in names          # commit is not a cancel
    ex.begin(weights, diff, target)
    ex.cancel()
    assert [e[1] for e in tr.events()][-1] == "migration.cancel"
