"""Fused Pallas paged-decode attention vs its oracles.

The fused kernel (kernels.paged_attention, interpret=True on CPU — the
exact TPU program body) walks block_tables via scalar-prefetch index
maps and folds each block into a flash-style online-softmax state. The
gather oracle (kernels.ref.paged_decode_ref) runs the SAME block-ordered
op sequence over the materialised (B, M*bs, K, hd) view, so the two are
bit-exact in fp32 — not merely close. A separate naive full-softmax
reference checks both against textbook attention.

Lengths are deliberately ragged across the block-boundary edge cases:
``len % bs == 0`` (new token opens a fresh block) and ``len % bs ==
bs - 1`` (new token fills a block), plus a full-capacity slot.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.ref import paged_decode_ref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

M = 4  # table width (blocks per slot)


def _ragged_lengths(bs: int) -> list:
    # new token at position len: block-opening (len % bs == 0),
    # block-filling (len % bs == bs-1), interior, and full-capacity
    return [bs - 1, bs, 2 * bs + 3, M * bs - 1]


def _paged_state(bs, G, lengths, dtype, *, K=2, hd=32, seed=0):
    B = len(lengths)
    rng = np.random.default_rng(seed)
    N = 1 + B * M                                     # block 0 = null
    q = jnp.asarray(rng.normal(size=(B, K, G, hd)), dtype)
    k_pool = jnp.asarray(rng.normal(size=(N, bs, K, hd)), dtype)
    v_pool = jnp.asarray(rng.normal(size=(N, bs, K, hd)), dtype)
    # non-trivial physical placement: slots own disjoint shuffled blocks
    perm = rng.permutation(B * M).astype(np.int32)
    tables = jnp.asarray(1 + perm.reshape(B, M))
    return q, k_pool, v_pool, tables, jnp.asarray(lengths, jnp.int32)


def _gather_view(pool, tables):
    B = tables.shape[0]
    return pool[tables].reshape(B, -1, *pool.shape[2:])


def _naive_full(q, k_view, v_view, lengths, window):
    """Textbook per-slot softmax attention over the valid (windowed)
    prefix, fp32 numpy."""
    B, K, G, hd = q.shape
    qf = np.asarray(q, np.float32)
    kf = np.asarray(k_view, np.float32)
    vf = np.asarray(v_view, np.float32)
    out = np.zeros((B, K, G, hd), np.float32)
    for b in range(B):
        cl = int(lengths[b]) + 1
        lo = max(0, cl - window) if window > 0 else 0
        for k in range(K):
            for g in range(G):
                s = kf[b, lo:cl, k] @ qf[b, k, g] / np.sqrt(hd)
                p = np.exp(s - s.max())
                p /= p.sum()
                out[b, k, g] = p @ vf[b, lo:cl, k]
    return out


@pytest.mark.parametrize("bs", [8, 16])
@pytest.mark.parametrize("G", [1, 4])
@pytest.mark.parametrize("window", [0, "bs+2"])
def test_fused_matches_gather_bitexact_fp32(bs, G, window):
    window = bs + 2 if window == "bs+2" else 0
    q, kp, vp, tables, lengths = _paged_state(
        bs, G, _ragged_lengths(bs), jnp.float32)
    fused = paged_decode_attention(q, kp, vp, tables, lengths,
                                   window=window, interpret=True)
    ref = paged_decode_ref(q, _gather_view(kp, tables),
                           _gather_view(vp, tables), lengths,
                           window=window, block_size=bs)
    assert np.array_equal(np.asarray(fused), np.asarray(ref)), (
        np.abs(np.asarray(fused) - np.asarray(ref)).max())


@pytest.mark.parametrize("bs", [8, 16])
@pytest.mark.parametrize("G", [1, 4])
@pytest.mark.parametrize("window", [0, "bs+2"])
def test_fused_matches_gather_bf16(bs, G, window):
    window = bs + 2 if window == "bs+2" else 0
    q, kp, vp, tables, lengths = _paged_state(
        bs, G, _ragged_lengths(bs), jnp.bfloat16)
    fused = paged_decode_attention(q, kp, vp, tables, lengths,
                                   window=window, interpret=True)
    ref = paged_decode_ref(q, _gather_view(kp, tables),
                           _gather_view(vp, tables), lengths,
                           window=window, block_size=bs)
    np.testing.assert_allclose(np.asarray(fused, np.float32),
                               np.asarray(ref, np.float32),
                               atol=1e-2, rtol=1e-2)


@pytest.mark.parametrize("window", [0, 11])
def test_fused_matches_naive_full_softmax(window):
    bs = 8
    q, kp, vp, tables, lengths = _paged_state(
        bs, 2, _ragged_lengths(bs), jnp.float32)
    fused = paged_decode_attention(q, kp, vp, tables, lengths,
                                   window=window, interpret=True)
    ref = _naive_full(q, _gather_view(kp, tables), _gather_view(vp, tables),
                      np.asarray(lengths), window)
    np.testing.assert_allclose(np.asarray(fused), ref, atol=1e-5, rtol=1e-5)


def test_ops_wrapper_dispatches_to_kernel():
    bs = 8
    q, kp, vp, tables, lengths = _paged_state(
        bs, 2, _ragged_lengths(bs), jnp.float32)
    out = ops.paged_decode_attention(q, kp, vp, tables, lengths, window=0)
    direct = paged_decode_attention(q, kp, vp, tables, lengths,
                                    window=0, interpret=True)
    assert np.array_equal(np.asarray(out), np.asarray(direct))


# ---------------------------------------------------------------------------
# gqa_decode_paged: impl knob + inactive-slot write suppression
# ---------------------------------------------------------------------------

def _tiny_cfg(**kw):
    from repro.configs.base import ModelConfig
    return ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                       num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=128, **kw)


def _decode_paged_once(impl, lengths, dtype=jnp.float32):
    from repro.models.attention import gqa_decode_paged, init_gqa
    cfg = _tiny_cfg(paged_attn_impl=impl)
    params = init_gqa(jax.random.PRNGKey(0), cfg)
    params = jax.tree_util.tree_map(lambda a: a.astype(dtype), params)
    B, bs = len(lengths), 8
    N = 1 + B * M
    pool = {"k": jnp.zeros((N, bs, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((N, bs, cfg.num_kv_heads, cfg.head_dim), dtype)}
    # row of a released slot (lengths == 0) points wholly at null block 0
    tables = np.zeros((B, M), np.int32)
    for b, ln in enumerate(lengths):
        if ln > 0:
            tables[b] = 1 + b * M + np.arange(M)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (B, 1, cfg.d_model)).astype(dtype)
    out, pool = gqa_decode_paged(
        params, cfg, x, pool, jnp.asarray(tables),
        jnp.asarray(lengths, jnp.int32), window=0)
    return np.asarray(out, np.float32), pool


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_impl_knob_fused_matches_gather(dtype):
    lengths = [3, 8, 0, 17]
    fused, _ = _decode_paged_once("fused", lengths, dtype)
    gather, _ = _decode_paged_once("gather", lengths, dtype)
    if dtype == jnp.float32:
        assert np.array_equal(fused, gather), np.abs(fused - gather).max()
    else:
        np.testing.assert_allclose(fused, gather, atol=1e-2, rtol=1e-2)


def test_inactive_slot_write_suppressed():
    """Released slots (lengths == 0) must not write their projected KV
    into the null block their table rows point at — other slots' masked
    reads DMA that block and its contents must stay inert."""
    lengths = [5, 0, 0, 12]
    _, pool = _decode_paged_once("fused", lengths)
    assert float(jnp.abs(pool["k"][0]).max()) == 0.0
    assert float(jnp.abs(pool["v"][0]).max()) == 0.0
    # the active slots DID write their new token at position lengths[b]
    for b, ln in enumerate(lengths):
        if ln > 0:
            blk, off = 1 + b * M + ln // 8, ln % 8
            assert float(jnp.abs(pool["k"][blk, off]).max()) > 0.0


# ---------------------------------------------------------------------------
# meshed engine smoke: fused path on a real EP mesh, no recompiles
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_meshed_engine_fused_decode_no_recompiles():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, json
        import jax, numpy as np
        from repro.configs.registry import get_config
        from repro.models.transformer import init_model
        from repro.serve import ContinuousConfig, ContinuousEngine
        from repro.serve.scheduler import ServeRequest

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                                  paged_attn_impl="fused")
        params = init_model(jax.random.PRNGKey(0), cfg)
        ccfg = ContinuousConfig(max_slots=4, prefill_len=32, block_size=16,
                                max_len=48, strategy="dist_only",
                                predict_interval=4, dup_slots=1,
                                metrics_window=4)
        eng = ContinuousEngine(cfg, params, ccfg, mesh=mesh, ep_ranks=4)
        eng.warmup()
        rng = np.random.default_rng(0)
        for i in range(5):
            eng.submit(ServeRequest(
                rid=i, arrival=0.0,
                tokens=rng.integers(0, cfg.vocab_size, 12).tolist(),
                max_new_tokens=6))
        n = 0
        while eng.has_work() and n < 60:
            eng.step(float(n)); n += 1
        eng.assert_no_recompiles()
        s = eng.metrics.summary()
        print(json.dumps({
            "completed": int(s["completed"]),
            "decode_toks_per_s": float(s.get("decode_toks_per_s", 0.0)),
            "roofline": float(s.get("fused_vs_gather_speedup", 0.0)),
        }))
    """)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=900,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["completed"] == 5
    assert res["decode_toks_per_s"] > 0.0
    assert res["roofline"] >= 1.0
