"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see ONE CPU
device; multi-device tests run in subprocesses (tests/test_distributed.py)
or use the 8-device session started by tests that opt in explicitly."""

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    from tests import _hypothesis_stub
    _hypothesis_stub._install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
