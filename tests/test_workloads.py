"""Multi-tenant trace workloads: determinism under a fixed seed,
per-tenant arrival rates, tenant-mix fractions, and the skew dynamics
the corpora are supposed to produce."""

import numpy as np
import pytest

from repro.sweep.workloads import WORKLOADS, build_workload
from repro.workloads.arrivals import (bursty_arrivals, diurnal_arrivals,
                                      poisson_arrivals)
from repro.workloads.corpus import ShiftingCorpus, Topic
from repro.workloads.traces import TenantSpec, make_trace


def _two_tenant_specs(vocab=128, rate_a=3.0, rate_b=1.0):
    flat = Topic("broad", zipf_alpha=0.4, vocab_frac=1.0, seed=1)
    hot = Topic("hot", zipf_alpha=3.0, vocab_frac=0.05, seed=2)
    corpus_a = ShiftingCorpus(vocab, [flat], schedule=[(0.0, [1.0])])
    corpus_b = ShiftingCorpus(vocab, [hot], schedule=[(0.0, [1.0])])
    return [
        TenantSpec("a", corpus_a, arrivals="poisson", rate=rate_a,
                   prompt_len_mean=16.0, prompt_len_max=32,
                   out_len_mean=4.0, out_len_max=8),
        TenantSpec("b", corpus_b, arrivals="poisson", rate=rate_b,
                   prompt_len_mean=16.0, prompt_len_max=32,
                   out_len_mean=4.0, out_len_max=8),
    ]


# ---------------------------------------------------------------- determinism

def test_make_trace_deterministic_under_fixed_seed():
    a = make_trace(_two_tenant_specs(), horizon=60.0, seed=7)
    b = make_trace(_two_tenant_specs(), horizon=60.0, seed=7)
    assert len(a) == len(b) > 0
    for ra, rb in zip(a, b):
        assert ra.rid == rb.rid
        assert ra.arrival == rb.arrival
        assert ra.tenant == rb.tenant
        assert ra.max_new_tokens == rb.max_new_tokens
        assert np.array_equal(ra.tokens, rb.tokens)


def test_make_trace_seed_changes_arrivals():
    a = make_trace(_two_tenant_specs(), horizon=60.0, seed=7)
    b = make_trace(_two_tenant_specs(), horizon=60.0, seed=8)
    assert [r.arrival for r in a] != [r.arrival for r in b]


def test_registered_workloads_deterministic():
    for name in sorted(WORKLOADS):
        a = build_workload(name, 128, horizon=20.0, rate=1.5, seed=3)
        b = build_workload(name, 128, horizon=20.0, rate=1.5, seed=3)
        assert len(a) == len(b) > 0, name
        assert all(np.array_equal(x.tokens, y.tokens)
                   and x.arrival == y.arrival and x.tenant == y.tenant
                   for x, y in zip(a, b)), name


# ------------------------------------------------------------- rates and mix

def test_per_tenant_arrival_rate_within_tolerance():
    rate_a, rate_b, horizon = 3.0, 1.0, 400.0
    trace = make_trace(_two_tenant_specs(rate_a=rate_a, rate_b=rate_b),
                       horizon=horizon, seed=0)
    n_a = sum(r.tenant == "a" for r in trace)
    n_b = sum(r.tenant == "b" for r in trace)
    # Poisson(rate*horizon): sigma/mean ~ 1/sqrt(n); 15% is ~5 sigma
    assert abs(n_a - rate_a * horizon) < 0.15 * rate_a * horizon
    assert abs(n_b - rate_b * horizon) < 0.15 * rate_b * horizon


def test_tenant_mix_fraction_honored():
    trace = make_trace(_two_tenant_specs(rate_a=3.0, rate_b=1.0),
                       horizon=400.0, seed=1)
    frac_a = sum(r.tenant == "a" for r in trace) / len(trace)
    assert abs(frac_a - 0.75) < 0.06


def test_diurnal_ramp_back_loads_arrivals():
    # period = 4x horizon turns the sinusoid into a monotone ramp, so the
    # second half of the session must carry visibly more traffic
    horizon = 120.0
    rng = np.random.default_rng(0)
    t = diurnal_arrivals(6.0, 1.0, 4.0 * horizon, horizon, rng)
    first = int(np.sum(t < horizon / 2))
    second = int(np.sum(t >= horizon / 2))
    assert second > 1.15 * first      # analytic ratio ~1.38


def test_arrival_processes_sorted_and_bounded():
    rng = np.random.default_rng(0)
    for t in (poisson_arrivals(2.0, 50.0, rng),
              bursty_arrivals(1.0, 4.0, 50.0, rng),
              diurnal_arrivals(2.0, 0.8, 60.0, 50.0, rng)):
        assert t.size > 0
        assert np.all(np.diff(t) >= 0)
        assert t[0] >= 0.0 and t[-1] < 50.0


# ------------------------------------------------------------- skew dynamics

def test_fleet_shift_skew_ramps_for_chat_tenant():
    trace = build_workload("fleet_shift", 256, horizon=40.0, rate=2.0,
                           seed=0)
    tenants = {r.tenant for r in trace}
    assert tenants == {"chat", "batch"}
    chat = [r for r in trace if r.tenant == "chat"]

    def top_frac(reqs, k=13):        # mass on the top 5% of a 256 vocab
        toks = np.concatenate([r.tokens for r in reqs])
        counts = np.bincount(toks, minlength=256)
        return np.sort(counts)[-k:].sum() / counts.sum()

    early = [r for r in chat if r.arrival < 0.3 * 40.0]
    late = [r for r in chat if r.arrival > 0.7 * 40.0]
    assert len(early) >= 5 and len(late) >= 5
    # the chat corpus walks broad -> hot, so late prompts concentrate on
    # far fewer distinct tokens than early ones
    assert top_frac(late) > top_frac(early) + 0.2


def test_corpus_token_dist_tracks_schedule():
    vocab = 128
    flat = Topic("broad", zipf_alpha=0.4, vocab_frac=1.0, seed=1)
    hot = Topic("hot", zipf_alpha=3.0, vocab_frac=0.05, seed=2)
    corpus = ShiftingCorpus(vocab, [flat, hot], schedule=[
        (0.0, [1.0, 0.0]), (10.0, [0.0, 1.0])])
    assert corpus.token_dist(0.0).max() < corpus.token_dist(10.0).max()
    mid = corpus.mixture(5.0)
    assert mid == pytest.approx([0.5, 0.5])


# ------------------------------------------------------------------- lengths

def test_lengths_clamped_and_rids_ordered():
    trace = make_trace(_two_tenant_specs(), horizon=80.0, seed=2)
    arrivals = [r.arrival for r in trace]
    assert arrivals == sorted(arrivals)
    assert [r.rid for r in trace] == list(range(len(trace)))
    for r in trace:
        assert 1 <= len(r.tokens) <= 32
        assert 1 <= r.max_new_tokens <= 8
        assert r.tokens.dtype == np.int32
