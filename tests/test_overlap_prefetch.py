"""Async predicted-hot expert prefetch (overlapped plan-diff migration).

Five layers of coverage:

* LayerStagedExecutor — entries fill in layer order, the per-layer ready
  vector is monotone, layers with an empty diff are ready immediately,
  and cancel-on-misprediction leaves the live buffers untouched (a
  subsequent migration to a third plan still lands exactly);
* cost model — the compute-aware chunk budget, the hidden/exposed stall
  split, the exposed-only ``should_migrate`` gate, ``run_gps``'s
  ``migration_hidden_frac`` discount, and the controller charging only
  exposed bytes;
* store-aware memory clamp — ``clamp_dup_slots`` math, the ServeEngine
  applying it from ``MoEConfig.store_hbm_budget_gb``, and the roofline's
  duplication residency term;
* multi-device bit-exactness — at EVERY intermediate state of a staged
  migration, a forward reading (live, back, ready, target) equals the
  gather-pool oracle evaluated on the equivalent per-layer mixed plan,
  and the completed async path equals the synchronous migration;
* engine integration — a meshed ContinuousEngine with overlap on
  pre-begins migration toward the predicted plan, commits with zero
  post-warmup compiles, reports a hidden stall share, and cancels a
  mispredicted pre-begin without corrupting the store.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.duplication import duplicate_experts_host
from repro.core.placement import (clamp_dup_slots, identity_plan,
                                  stack_plans, store_bytes_per_rank)
from repro.data.synthetic import skewed_distribution
from repro.runtime import (LayerStagedExecutor, ReplicaStore,
                           make_migrate_step, migrate_all,
                           overlap_chunk_budget, plan_diff,
                           should_migrate, split_hidden_exposed,
                           stacked_slot_experts)
from tests.test_distributed import run_sub

E, R = 8, 4


def _dup_stack(layers, dup, seed=0, base_skew=2.0):
    return stack_plans([
        duplicate_experts_host(
            skewed_distribution(E, base_skew + l + seed * 0.1), R, dup, 4).plan
        for l in range(layers)])


def _identity_stack(layers, dup):
    return stack_plans([identity_plan(E, R, dup, 4) for _ in range(layers)])


def _toy_experts(layers, d=4, f=6, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w_gate": jnp.asarray(rng.normal(size=(layers, E, d, f)), jnp.float32),
        "w_up": jnp.asarray(rng.normal(size=(layers, E, d, f)), jnp.float32),
        "w_down": jnp.asarray(rng.normal(size=(layers, E, f, d)), jnp.float32),
    }


# ---------------------------------------------------------------------------
# layer-staged executor
# ---------------------------------------------------------------------------

def test_staged_fill_is_layer_ordered_and_ready_monotone():
    layers, dup = 3, 2
    experts = _toy_experts(layers)
    old, new = _identity_stack(layers, dup), _dup_stack(layers, dup, seed=2)
    store = ReplicaStore.from_params(experts, old, num_experts=E,
                                     ep_ranks=R, dup_slots=dup)
    step = make_migrate_step(None, num_experts=E, ep_ranks=R, dup_slots=dup)
    diff = plan_diff(old, new, R, dup)
    assert diff.num_entries > 0
    ex = LayerStagedExecutor(step, experts, store.entry_bytes,
                             num_layers=layers, chunk=1)
    ex.begin(store.weights, diff, new)
    # entries were re-sorted by layer
    assert np.all(np.diff(ex._diff.layer) >= 0)
    se_new = stacked_slot_experts(new, R, dup)
    prev = ex.ready_mask()
    commit = None
    while commit is None:
        commit, _ = ex.tick(1)
        mask = (np.ones(layers, bool) if not ex.active
                else ex.ready_mask())
        assert np.all(mask >= prev), "ready vector must be monotone"
        if ex.active:
            # every READY layer's back buffer equals the target store
            back = ex.back_weights
            for l in np.nonzero(mask)[0]:
                live = se_new[l] >= 0
                for k, w in back.items():
                    ref = np.asarray(experts[k])[l, se_new[l][live]]
                    assert np.array_equal(np.asarray(w)[l][live], ref), (k, l)
        prev = mask
    weights, plan, se = commit
    assert np.array_equal(se, se_new)
    ref = migrate_all(step, store.weights, experts, diff, chunk=5)
    for k in weights:
        assert np.array_equal(np.asarray(weights[k]), np.asarray(ref[k])), k
    # layers whose diff is empty must be ready from the first tick
    empty_layers = np.setdiff1d(np.arange(layers), np.unique(diff.layer))
    ex.begin(store.weights, diff, new)
    if empty_layers.size:
        assert np.all(ex.ready_mask()[empty_layers])


def test_staged_cancel_then_remigrate_is_consistent():
    """Cancel mid-fill (misprediction), then migrate to a THIRD plan: the
    result equals migrating old -> third directly — no state leaked from
    the abandoned fill."""
    layers, dup = 2, 2
    experts = _toy_experts(layers)
    old = _identity_stack(layers, dup)
    wrong = _dup_stack(layers, dup, seed=4)
    right = _dup_stack(layers, dup, seed=9, base_skew=4.0)
    store = ReplicaStore.from_params(experts, old, num_experts=E,
                                     ep_ranks=R, dup_slots=dup)
    step = make_migrate_step(None, num_experts=E, ep_ranks=R, dup_slots=dup)
    ex = LayerStagedExecutor(step, experts, store.entry_bytes,
                             num_layers=layers, chunk=1)
    ex.begin(store.weights, plan_diff(old, wrong, R, dup), wrong)
    ex.tick(2)                               # partial fill toward WRONG plan
    assert ex.active
    ex.cancel()
    assert not ex.active and ex.tick() == (None, 0)
    assert not ex.ready_mask().any()
    # live buffers untouched by the abandoned fill
    ref_old = ReplicaStore.from_params(experts, old, num_experts=E,
                                       ep_ranks=R, dup_slots=dup)
    for k in store.weights:
        assert np.array_equal(np.asarray(store.weights[k]),
                              np.asarray(ref_old.weights[k])), k
    diff = plan_diff(old, right, R, dup)
    ex.begin(store.weights, diff, right)
    commit = None
    while commit is None:
        commit, _ = ex.tick(1)
    got, _, se = commit
    ref = ReplicaStore.from_params(experts, right, num_experts=E,
                                   ep_ranks=R, dup_slots=dup)
    live = stacked_slot_experts(right, R, dup) >= 0
    for k in got:
        assert np.array_equal(np.asarray(got[k])[live],
                              np.asarray(ref.weights[k])[live]), k


# ---------------------------------------------------------------------------
# cost model: budget, hidden/exposed split, GPS discount
# ---------------------------------------------------------------------------

class _HW:
    link_bw = 1e9


def test_overlap_chunk_budget_scales_with_window():
    kw = dict(chunk_entries=4, entry_bytes=int(1e6), hw=_HW)   # 4ms wire
    assert overlap_chunk_budget(0.0, **kw) == 1                # progress floor
    assert overlap_chunk_budget(0.004, **kw) == 1
    assert overlap_chunk_budget(0.040, **kw) == 10
    assert overlap_chunk_budget(1e9, **kw, max_chunks=64) == 64


def test_kind_window_ema_splits_prefill_and_decode():
    """Satellite: the overlap chunk budget must be sized against the
    iteration KIND being shadowed — one mixed EMA lets multi-ms prefill
    walls inflate the decode window by orders of magnitude."""
    from repro.runtime import KindWindowEMA
    ema = KindWindowEMA(beta=0.5)
    # decode window falls back to the only seeded kind until measured
    ema.update("prefill", 0.100)
    assert ema.window("decode") == pytest.approx(0.100)
    ema.update("decode", 0.002)
    assert ema.window("decode") == pytest.approx(0.002)
    assert ema.window("prefill") == pytest.approx(0.100)
    # each kind's EMA evolves independently of the other's samples
    ema.update("decode", 0.004)
    assert ema.window("decode") == pytest.approx(0.003)
    assert ema.window("prefill") == pytest.approx(0.100)
    assert set(ema.kinds()) == {"prefill", "decode"}


def test_continuous_engine_tracks_per_kind_windows():
    """The engine's overlap window EMA keeps separate prefill and decode
    estimates (prefill-bearing iterations must not drive the decode
    chunk budget)."""
    import jax
    from repro.configs.registry import get_config
    from repro.models.transformer import init_model
    from repro.serve import ContinuousConfig, ContinuousEngine, ServeRequest
    cfg = get_config("mixtral-8x7b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ContinuousEngine(cfg, params, ContinuousConfig(
        max_slots=2, prefill_len=16, block_size=8, max_len=32,
        strategy="dist_only"))
    eng.warmup()
    eng.run_trace([ServeRequest(rid=i, tokens=np.arange(6, dtype=np.int32),
                                max_new_tokens=4) for i in range(3)])
    kinds = eng._serve_ema.kinds()
    assert "prefill" in kinds and "decode" in kinds
    assert kinds["prefill"] > 0 and kinds["decode"] > 0


def test_split_and_gate_charge_only_exposed_stall():
    hidden, exposed = split_hidden_exposed(1.0, 0.3)
    assert hidden == pytest.approx(0.3) and exposed == pytest.approx(0.7)
    hidden, exposed = split_hidden_exposed(0.2, 5.0)
    assert hidden == pytest.approx(0.2) and exposed == 0.0
    # a stall too big to pay synchronously is accepted once mostly hidden
    assert not should_migrate(2.0, 0.5)
    assert should_migrate(2.0, 0.5, hidden_s=1.8)
    assert should_migrate(2.0, 0.0, hidden_s=99.0)


def test_run_gps_hidden_frac_discounts_duplicating_strategies():
    from repro.configs.registry import get_config
    from repro.core.gps import recommend_strategy, run_gps
    from repro.core.simulator import A100_PCIE
    cfg = get_config("mixtral-8x7b")
    base = run_gps(cfg, A100_PCIE, skew=1.8)
    stall = base.baseline.total * 10
    sync = run_gps(cfg, A100_PCIE, skew=1.8, migration_stall_s=stall)
    overlapped = run_gps(cfg, A100_PCIE, skew=1.8, migration_stall_s=stall,
                         migration_hidden_frac=1.0)
    half = run_gps(cfg, A100_PCIE, skew=1.8, migration_stall_s=stall,
                   migration_hidden_frac=0.5)
    assert overlapped.dist_only.total == pytest.approx(base.dist_only.total)
    assert (base.dist_only.total < half.dist_only.total
            < sync.dist_only.total)
    # churn that flips the verdict to "none" synchronously keeps the
    # duplicating strategy once the transfer is hidden
    name_sync, _ = recommend_strategy(cfg, A100_PCIE, skew=1.8,
                                      migration_stall_s=stall)
    name_async, _ = recommend_strategy(cfg, A100_PCIE, skew=1.8,
                                       migration_stall_s=stall,
                                       migration_hidden_frac=1.0)
    assert name_sync == "none" and name_async != "none"


def test_controller_charges_only_exposed_bytes():
    from repro.configs.registry import get_config
    from repro.serve.controller import ControllerConfig, OnlineGPSController

    def run(hidden_frac):
        ctl = OnlineGPSController(
            get_config("mixtral-8x7b"),
            ControllerConfig(window_iters=4, patience=1))
        counts = np.tile(skewed_distribution(64, 1.8) * 1000, (32, 1))
        d = None
        for i in range(4):
            d = ctl.observe(counts, float(i), migration_bytes=1e9,
                            migration_hidden_bytes=1e9 * hidden_frac)
        return d

    d_sync, d_half, d_async = run(0.0), run(0.5), run(1.0)
    assert d_sync.migration_stall_s > d_half.migration_stall_s > 0
    assert d_async.migration_stall_s == 0.0
    assert d_async.migration_hidden_frac == pytest.approx(1.0)
    assert d_half.migration_hidden_frac == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# store-aware memory clamp
# ---------------------------------------------------------------------------

def test_clamp_dup_slots_math():
    kw = dict(entry_bytes=100, num_layers=2)
    # n_slots = 2 + d -> bytes/rank = 2 * (2 + d) * 100
    assert store_bytes_per_rank(E, R, 2, **kw) == 800
    assert clamp_dup_slots(E, R, 4, hbm_budget_bytes=0, **kw) == 4
    assert clamp_dup_slots(E, R, 4, hbm_budget_bytes=1200, **kw) == 4
    assert clamp_dup_slots(E, R, 4, hbm_budget_bytes=900, **kw) == 2
    assert clamp_dup_slots(E, R, 4, hbm_budget_bytes=650, **kw) == 1
    assert clamp_dup_slots(E, R, 4, hbm_budget_bytes=100, **kw) == 0


def test_serve_engine_applies_store_hbm_budget():
    import dataclasses
    import jax
    from repro.configs.registry import get_config
    from repro.models.transformer import init_model
    from repro.runtime.cost import entry_bytes as _eb
    from repro.serve import ServeConfig, ServeEngine

    cfg = get_config("mixtral-8x7b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    entry = _eb(params["layers"]["moe"]["experts"])
    e_loc = cfg.moe.num_experts // 4
    # budget fits exactly one replica slot per rank
    budget_gb = (cfg.num_layers * (e_loc + 1) * entry) / 1e9
    clamped = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, store_hbm_budget_gb=budget_gb))
    # the clamp requires store mode, and a store requires a mesh (the
    # engine is only constructed, never stepped, so 1x1 is fine here)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ServeEngine(clamped, params, ServeConfig(dup_slots=4),
                      mesh=mesh, ep_ranks=4)
    assert eng.moe_cfg.duplication_slots == 1
    # no budget -> untouched
    eng = ServeEngine(cfg, params, ServeConfig(dup_slots=4),
                      mesh=mesh, ep_ranks=4)
    assert eng.moe_cfg.duplication_slots == 4
    # meshless (gather fallback) engines never build a store: no clamp
    eng = ServeEngine(clamped, params, ServeConfig(dup_slots=4), ep_ranks=4)
    assert eng.moe_cfg.duplication_slots == 4


def test_roofline_counts_store_residency():
    from repro.configs.base import INPUT_SHAPES
    from repro.configs.registry import get_config
    from repro.roofline import analytic_hbm_bytes
    import dataclasses
    cfg = get_config("mixtral-8x7b")
    dup = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, duplication_slots=2))
    shape = INPUT_SHAPES["decode_32k"]
    plain = analytic_hbm_bytes(cfg, shape, chips=8)
    with_store = analytic_hbm_bytes(dup, shape, chips=8)
    ff_mult = 3
    expected = 2 * ff_mult * cfg.d_model * cfg.moe.d_ff_expert * 2 \
        * cfg.num_layers
    assert with_store - plain == pytest.approx(expected)
    # training runs the gather path (plans change under autodiff), no store
    tr = INPUT_SHAPES["train_4k"]
    assert analytic_hbm_bytes(dup, tr, chips=8) == \
        pytest.approx(analytic_hbm_bytes(cfg, tr, chips=8))


# ---------------------------------------------------------------------------
# multi-device: async path bit-exact at every intermediate state
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_overlapped_forward_bitexact_vs_gather_midstream():
    """During a staged migration the forward reading (live, back, ready,
    target) must equal the gather-pool oracle on the per-layer MIXED plan
    (ready layers -> target, others -> old) at EVERY tick, and the final
    state must equal the synchronous migration."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.core.duplication import duplicate_experts_host
        from repro.core.placement import stack_plans
        from repro.data.synthetic import skewed_distribution
        from repro.models.transformer import Runtime, forward, init_model
        from repro.runtime import (LayerStagedExecutor, ReplicaStore,
                                   make_migrate_step, migrate_all, plan_diff)

        base = get_config("mixtral-8x7b").reduced()
        cfg = dataclasses.replace(base, moe=dataclasses.replace(
            base.moe, duplication_slots=2))
        E = cfg.moe.num_experts
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4, use_duplication=True)
        params = init_model(jax.random.PRNGKey(0), cfg)
        experts = params["layers"]["moe"]["experts"]
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}
        plan_a = stack_plans([duplicate_experts_host(
            skewed_distribution(E, 2.5 + l), 4, 2, 4).plan
            for l in range(cfg.num_layers)])
        plan_b = stack_plans([duplicate_experts_host(
            skewed_distribution(E, 5.0 - l), 4, 2, 4).plan
            for l in range(cfg.num_layers)])
        store = ReplicaStore.from_params(
            experts, plan_a, num_experts=E, ep_ranks=4, dup_slots=2,
            mesh=mesh)
        mig = make_migrate_step(mesh, num_experts=E, ep_ranks=4, dup_slots=2)
        diff = plan_diff(plan_a, plan_b, 4, 2)
        assert diff.num_entries > 2, diff.num_entries

        gather_fwd = jax.jit(lambda p, b, pl: forward(
            p, cfg, b, rt, mode="train", plan=pl))
        store_fwd = jax.jit(lambda p, b, pl, sw, bw, rd, tp: forward(
            p, cfg, b, rt, mode="train", plan=pl, slot_weights=sw,
            slot_weights_back=bw, slot_ready=rd, target_plan=tp))

        ex = LayerStagedExecutor(mig, experts, store.entry_bytes,
                                 num_layers=cfg.num_layers, chunk=1)
        ex.begin(store.weights, diff, plan_b)
        states = []
        commit = None
        with mesh:
            while commit is None:
                ready = ex.ready_mask()
                # gather oracle on the equivalent per-layer mixed plan
                mixed = jax.tree.map(
                    lambda a, b_: jnp.where(
                        jnp.asarray(ready).reshape(
                            (-1,) + (1,) * (a.ndim - 1)), b_, a),
                    plan_a, plan_b)
                lg, _, sg = gather_fwd(params, batch, mixed)
                ls, _, ss = store_fwd(params, batch, plan_a, store.weights,
                                      ex.back_weights, jnp.asarray(ready),
                                      plan_b)
                states.append({
                    "ready": int(ready.sum()),
                    "diff": float(jnp.abs(lg.astype(jnp.float32)
                                          - ls.astype(jnp.float32)).max()),
                    "counts_eq": bool(jnp.array_equal(sg["expert_counts"],
                                                      ss["expert_counts"])),
                })
                commit, _ = ex.tick(1)
        weights, _, se = commit
        store.adopt(weights, se)
        sync = migrate_all(mig, ReplicaStore.from_params(
            experts, plan_a, num_experts=E, ep_ranks=4, dup_slots=2,
            mesh=mesh).weights, experts, diff, chunk=3)
        final_eq = all(bool(jnp.array_equal(store.weights[k], sync[k]))
                       for k in sync)
        print(json.dumps({"states": states, "final_eq": final_eq,
                          "L": cfg.num_layers}))
    """, timeout=1800)
    assert res["final_eq"]
    assert len(res["states"]) >= 3
    partial = [s for s in res["states"] if 0 < s["ready"] < res["L"]]
    assert partial, "no intermediate mixed state was exercised"
    for s in res["states"]:
        assert s["diff"] == 0.0, s
        assert s["counts_eq"], s


@pytest.mark.slow
def test_serve_engine_generate_tokens_equal_overlap_on_off():
    """Greedy generation through a meshed ServeEngine (re-plans every
    batch, staged migrations in flight) produces IDENTICAL token ids with
    overlap on and off — catches any (plan, store) tear, e.g. reading
    pre-commit weights under a post-commit plan."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.models.transformer import init_model
        from repro.serve import ServeConfig, ServeEngine

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("mixtral-8x7b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        outs = {}
        for overlap in (True, False):
            c = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, overlap_migration=overlap))
            eng = ServeEngine(c, params,
                              ServeConfig(strategy="dist_only", dup_slots=1,
                                          max_len=64),
                              mesh=mesh, ep_ranks=4)
            rng = np.random.default_rng(0)
            toks = []
            for b in range(3):
                batch = {"tokens": jnp.asarray(
                    rng.integers(0, c.vocab_size // 4, (2, 16)))}
                gen, _ = eng.generate(batch, max_new_tokens=6)
                toks.append(np.asarray(gen))
            outs[overlap] = np.concatenate(toks)
        print(json.dumps({"equal": bool(np.array_equal(outs[True],
                                                       outs[False]))}))
    """, timeout=1800)
    assert res["equal"]


@pytest.mark.slow
def test_meshed_engine_prefetch_overlap_no_recompiles():
    """Meshed ContinuousEngine, overlap on: pre-begins migration toward
    the predicted plan before the boundary, commits, reports hidden
    stall, cancels a forced misprediction cleanly — zero XLA compiles
    after warmup throughout."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.models.transformer import init_model
        from repro.runtime import stacked_slot_experts
        from repro.serve import ContinuousConfig, ContinuousEngine
        from repro.serve.scheduler import ServeRequest

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("mixtral-8x7b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        ccfg = ContinuousConfig(max_slots=4, prefill_len=32, block_size=16,
                                max_len=48, strategy="dist_only",
                                predict_interval=4, dup_slots=1,
                                metrics_window=4, overlap_migration=True,
                                prefetch_lead=2, migration_gate=False)
        eng = ContinuousEngine(cfg, params, ccfg, mesh=mesh, ep_ranks=4)
        assert eng._overlap and eng._executor is not None
        eng.warmup()
        rng = np.random.default_rng(0)
        # skewed prompts so re-plans actually duplicate experts
        for i in range(8):
            eng.submit(ServeRequest(
                rid=i, arrival=0.0,
                tokens=rng.integers(0, cfg.vocab_size // 8, 16).tolist(),
                max_new_tokens=6))
        n = 0
        while eng.has_work() and n < 60:
            eng.step(float(n)); n += 1
        # force a misprediction: settle on the identity plan, pre-begin
        # toward the (duplicated) predicted plan, then adopt a DIFFERENT
        # plan at the boundary -> the stale fill must be cancelled
        eng._adopt_plan(eng._identity_stack())
        while eng._executor.active:
            eng._tick_migration()
        eng._prebegin_migration()
        assert eng._executor.active, "pre-begin produced no fill"
        m0 = eng.metrics.migration["cancelled"]
        eng._adopt_plan(eng._identity_stack())
        forced_cancel = eng.metrics.migration["cancelled"] > m0
        while eng._executor.active:
            eng._tick_migration()
        recompiled = False
        try:
            eng.assert_no_recompiles()
        except AssertionError:
            recompiled = True
        eng.metrics.flush(eng._plan_stack, eng.ep_ranks, 1)
        s = eng.metrics.summary()
        print(json.dumps({
            "recompiled": recompiled,
            "completed": int(s["completed"]),
            "commits": s["migration_commits"],
            "prebegun": s["migration_prebegun"],
            "hidden_s": s["migration_hidden_s"],
            "forced_cancel": forced_cancel,
            "store_version": np.asarray(eng._store.version).tolist(),
            # consistency: every slot the CURRENT plan can route to holds
            # the right expert (unused replica slots may keep stale ids —
            # dispatch never reads them)
            "store_matches_plan": (lambda se: bool(np.array_equal(
                eng._store.slot_experts[se >= 0], se[se >= 0])))(
                stacked_slot_experts(eng._plan_stack, 4, 1)),
        }))
    """, timeout=1800)
    assert not res["recompiled"]
    assert res["completed"] == 8
    assert res["commits"] >= 1
    assert res["prebegun"] >= 1, "prefetcher never pre-began a migration"
    assert res["hidden_s"] > 0.0
    assert res["forced_cancel"]
    assert res["store_matches_plan"]
