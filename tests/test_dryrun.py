"""Dry-run machinery tests: one real (arch x shape x 512-device mesh)
lower+compile in a subprocess (the full 40-combo matrix runs via
``python -m repro.launch.dryrun`` and is recorded in EXPERIMENTS.md)."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # each combo lowers+compiles in a subprocess

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [
    ("qwen1.5-0.5b", "decode_32k"),
    ("deepseek-v2-lite-16b", "long_500k"),
])
def test_dryrun_single_combo(arch, shape, tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")))
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "OK" in out.stdout
    path = tmp_path / f"{arch}_{shape}_16x16.json"
    with open(path) as f:
        rep = json.load(f)
    assert rep["status"] == "ok"
    assert rep["chips"] == 256
    assert rep["compute_s"] > 0 and rep["memory_s"] > 0
    assert rep["dominant"] in ("compute", "memory", "collective")


def test_multipod_mesh_combo(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "olmo-1b", "--shape", "decode_32k", "--multi-pod",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")))
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    with open(tmp_path / "olmo-1b_decode_32k_2x16x16.json") as f:
        rep = json.load(f)
    assert rep["chips"] == 512 and rep["status"] == "ok"


def test_mesh_functions_are_lazy():
    """Importing mesh.py must not initialise jax devices."""
    out = subprocess.run(
        [sys.executable, "-c",
         "import repro.launch.mesh, jax\n"
         "assert not jax._src.xla_bridge._backends, 'devices initialised!'\n"
         "print('lazy ok')"],
        capture_output=True, text=True, timeout=120,
        env=dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src")))
    assert out.returncode == 0 and "lazy ok" in out.stdout, out.stderr[-1500:]
