"""Replica-weight migration runtime (repro.runtime).

Four layers of coverage:

* plan_diff properties — diff(p, p) is empty; applying a diff to the old
  slot map reproduces the target on every live slot; diffs touch replica
  slots only (home assignments are fixed by construction);
* store construction — every live slot's buffer equals the occupying
  expert's weights; chunked migration (mesh-less step) reproduces the
  store built directly from the target plan;
* EP forward equivalence — a multi-device forward reading the store is
  BIT-EXACT against the per-step gather-pool oracle across dup_slots,
  top_k and predicted mode, and its jaxpr contains no weight all_gather
  (the identity-plan gather skip is exercised the same way);
* engine integration — a meshed ContinuousEngine in store mode serves,
  migrates on re-plans under a chunk budget, commits, and never
  recompiles after warmup.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.duplication import duplicate_experts_host
from repro.core.placement import (identity_plan, plan_dims, slot_expert_map,
                                  stack_plans)
from repro.data.synthetic import skewed_distribution
from repro.runtime import (MigrationExecutor, ReplicaStore, apply_diff,
                           entry_bytes, make_migrate_step, migrate_all,
                           migration_stall_s, plan_diff, should_migrate,
                           stacked_slot_experts)
from tests.test_distributed import run_sub

E, R = 8, 4


def _dup_stack(layers, dup, seed=0, base_skew=2.0):
    return stack_plans([
        duplicate_experts_host(
            skewed_distribution(E, base_skew + l + seed * 0.1), R, dup, 4).plan
        for l in range(layers)])


def _identity_stack(layers, dup):
    return stack_plans([identity_plan(E, R, dup, 4) for _ in range(layers)])


# ---------------------------------------------------------------------------
# plan_diff properties
# ---------------------------------------------------------------------------

@given(st.integers(1, 3), st.integers(1, 2), st.floats(1.5, 7.0))
@settings(max_examples=25, deadline=None)
def test_plan_diff_self_is_empty(layers, dup, skew):
    p = stack_plans([duplicate_experts_host(
        skewed_distribution(E, skew), R, dup, 4).plan
        for _ in range(layers)])
    assert plan_diff(p, p, R, dup).num_entries == 0


@given(st.integers(1, 3), st.integers(1, 2), st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_plan_diff_apply_reproduces_target(layers, dup, seed):
    old = (_identity_stack(layers, dup) if seed % 2
           else _dup_stack(layers, dup, seed))
    new = _dup_stack(layers, dup, seed + 1, base_skew=3.0)
    diff = plan_diff(old, new, R, dup)
    se_old = stacked_slot_experts(old, R, dup)
    se_new = stacked_slot_experts(new, R, dup)
    applied = apply_diff(se_old, diff)
    live = se_new >= 0
    assert np.array_equal(applied[live], se_new[live])
    # only replica slots may move, and only to a LIVE assignment
    e_loc, n_slots = plan_dims(E, R, dup)
    assert np.all(diff.dst_slot % n_slots >= e_loc)
    assert np.all(diff.src_expert >= 0)


def test_slot_expert_map_identity_and_home():
    dup = 2
    e_loc, n_slots = plan_dims(E, R, dup)
    se = slot_expert_map(identity_plan(E, R, dup, 4), R, dup)
    for e in range(E):
        assert se[(e // e_loc) * n_slots + e % e_loc] == e
    # identity plan: every replica slot is unused
    assert np.all(se.reshape(R, n_slots)[:, e_loc:] == -1)


# ---------------------------------------------------------------------------
# store construction + mesh-less migration
# ---------------------------------------------------------------------------

def _toy_experts(layers, d=4, f=6, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w_gate": jnp.asarray(rng.normal(size=(layers, E, d, f)), jnp.float32),
        "w_up": jnp.asarray(rng.normal(size=(layers, E, d, f)), jnp.float32),
        "w_down": jnp.asarray(rng.normal(size=(layers, E, f, d)), jnp.float32),
    }


def test_store_live_slots_hold_expert_weights():
    layers, dup = 2, 2
    experts = _toy_experts(layers)
    plan = _dup_stack(layers, dup)
    store = ReplicaStore.from_params(experts, plan, num_experts=E,
                                     ep_ranks=R, dup_slots=dup)
    se = stacked_slot_experts(plan, R, dup)
    for k, w in store.weights.items():
        ref = np.asarray(experts[k])
        got = np.asarray(w)
        for l in range(layers):
            for s in np.nonzero(se[l] >= 0)[0]:
                assert np.array_equal(got[l, s], ref[l, se[l, s]]), (k, l, s)
    assert store.entry_bytes == entry_bytes(experts)


@pytest.mark.parametrize("chunk", [1, 3, 64])
def test_meshless_migration_reaches_target_store(chunk):
    layers, dup = 3, 2
    experts = _toy_experts(layers)
    old, new = _identity_stack(layers, dup), _dup_stack(layers, dup, seed=2)
    store = ReplicaStore.from_params(experts, old, num_experts=E,
                                     ep_ranks=R, dup_slots=dup)
    step = make_migrate_step(None, num_experts=E, ep_ranks=R, dup_slots=dup)
    diff = plan_diff(old, new, R, dup)
    assert diff.num_entries > 0
    got = migrate_all(step, store.weights, experts, diff, chunk=chunk)
    ref = ReplicaStore.from_params(experts, new, num_experts=E,
                                   ep_ranks=R, dup_slots=dup)
    live = stacked_slot_experts(new, R, dup) >= 0
    for k in got:
        assert np.array_equal(np.asarray(got[k])[live],
                              np.asarray(ref.weights[k])[live]), k


def test_executor_budget_and_commit_bookkeeping():
    layers, dup = 2, 2
    experts = _toy_experts(layers)
    old, new = _identity_stack(layers, dup), _dup_stack(layers, dup, seed=3)
    store = ReplicaStore.from_params(experts, old, num_experts=E,
                                     ep_ranks=R, dup_slots=dup)
    step = make_migrate_step(None, num_experts=E, ep_ranks=R, dup_slots=dup)
    diff = plan_diff(old, new, R, dup)
    se_new = stacked_slot_experts(new, R, dup)
    ex = MigrationExecutor(step, experts, store.entry_bytes, chunk=2,
                           chunks_per_tick=1)
    ex.begin(store.weights, diff, new)
    ticks, moved_total, commit = 0, 0, None
    while commit is None:
        commit, moved = ex.tick()
        moved_total += moved
        ticks += 1
        assert ticks <= diff.num_entries + 1, "executor failed to converge"
    assert not ex.active
    assert moved_total == diff.num_entries * store.entry_bytes
    assert ticks == -(-diff.num_entries // 2)      # one 2-entry chunk per tick
    weights, plan, se = commit
    v0 = store.version.copy()
    store.adopt(weights, se)
    assert np.array_equal(se, se_new)
    changed = np.any(stacked_slot_experts(old, R, dup) != se_new, axis=1)
    assert np.array_equal(store.version - v0, changed.astype(np.int64))


def test_executor_cancel_discards_in_flight_migration():
    """A superseded target (e.g. the controller switching to strategy
    "none" mid-fill) must not commit later: cancel() drops the back
    buffer and the next tick is a no-op."""
    layers, dup = 2, 2
    experts = _toy_experts(layers)
    old, new = _identity_stack(layers, dup), _dup_stack(layers, dup, seed=4)
    store = ReplicaStore.from_params(experts, old, num_experts=E,
                                     ep_ranks=R, dup_slots=dup)
    step = make_migrate_step(None, num_experts=E, ep_ranks=R, dup_slots=dup)
    diff = plan_diff(old, new, R, dup)
    ex = MigrationExecutor(step, experts, store.entry_bytes, chunk=1,
                           chunks_per_tick=1)
    ex.begin(store.weights, diff, new)
    ex.tick()                          # partial fill in the back buffer
    assert ex.active
    ex.cancel()
    assert not ex.active
    assert ex.tick() == (None, 0)      # nothing left to commit
    # live buffers were never touched by the abandoned fill
    ref = ReplicaStore.from_params(experts, old, num_experts=E,
                                   ep_ranks=R, dup_slots=dup)
    for k in store.weights:
        assert np.array_equal(np.asarray(store.weights[k]),
                              np.asarray(ref.weights[k])), k


def test_cost_model_gate():
    assert should_migrate(stall_s=0.0, gain_s=0.0)

    class HW:
        link_bw = 1e9
    assert migration_stall_s(2e9, HW) == pytest.approx(2.0)
    assert not should_migrate(stall_s=2.0, gain_s=0.5)


def test_gps_charges_migration_to_duplicating_strategies():
    from repro.configs.registry import get_config
    from repro.core.gps import run_gps
    from repro.core.simulator import A100_PCIE
    cfg = get_config("mixtral-8x7b")
    base = run_gps(cfg, A100_PCIE, skew=1.8)
    heavy = run_gps(cfg, A100_PCIE, skew=1.8,
                    migration_stall_s=base.baseline.total * 10)
    # the baseline never migrates; duplicating strategies carry the stall
    assert heavy.baseline.total == base.baseline.total
    assert heavy.dist_only.total > base.dist_only.total
    assert all(h.total > b.total for h, b in
               zip(heavy.t2e_points, base.t2e_points))
    # heavy churn flips the online verdict to plain EP
    from repro.core.gps import recommend_strategy
    name, _ = recommend_strategy(cfg, A100_PCIE, skew=1.8,
                                 migration_stall_s=base.baseline.total * 10)
    assert name == "none"


# ---------------------------------------------------------------------------
# multi-device equivalence + no-collective guarantee
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_store_forward_matches_gather_multidevice():
    """Store-fed EP forward is BIT-EXACT vs the per-step gather pool
    across dup_slots/top_k/predicted, including after a chunked migration
    to a new plan; the store jaxpr has no weight all_gather."""
    res = run_sub("""
        import dataclasses, itertools
        from repro.configs.registry import get_config
        from repro.core.duplication import duplicate_experts_host
        from repro.core.placement import stack_plans
        from repro.data.synthetic import skewed_distribution
        from repro.models.transformer import Runtime, forward, init_model
        from repro.runtime import (ReplicaStore, make_migrate_step,
                                   migrate_all, plan_diff,
                                   stacked_slot_experts)

        base = get_config("mixtral-8x7b").reduced()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4, use_duplication=True)
        E = base.moe.num_experts
        out = {}
        for top_k, dup, predicted in itertools.product((1, 2), (1, 2),
                                                       (False, True)):
            cfg = dataclasses.replace(base, moe=dataclasses.replace(
                base.moe, top_k=top_k, duplication_slots=dup))
            params = init_model(jax.random.PRNGKey(0), cfg)
            experts = params["layers"]["moe"]["experts"]
            B, S = 4, 32
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)}
            pred = (jnp.zeros((cfg.num_layers, B, S, top_k), jnp.int32)
                    if predicted else None)
            plan = stack_plans([duplicate_experts_host(
                skewed_distribution(E, 2.5 + l), 4, dup, 4).plan
                for l in range(cfg.num_layers)])
            store = ReplicaStore.from_params(
                experts, plan, num_experts=E, ep_ranks=4, dup_slots=dup,
                mesh=mesh)
            # migrate to a DIFFERENT plan so equivalence also covers
            # store contents written by the chunked migration step
            plan2 = stack_plans([duplicate_experts_host(
                skewed_distribution(E, 5.0 - l), 4, dup, 4).plan
                for l in range(cfg.num_layers)])
            diff = plan_diff(plan, plan2, 4, dup)
            if diff.num_entries:
                mig = make_migrate_step(mesh, num_experts=E, ep_ranks=4,
                                        dup_slots=dup)
                w2 = migrate_all(mig, store.weights, experts, diff, chunk=3)
                store.adopt(w2, diff.target_slot_experts)
            lg, _, sg = jax.jit(lambda p, b, pl, pr: forward(
                p, cfg, b, rt, mode="train", plan=pl, predicted_idx=pr)
            )(params, batch, plan2, pred)
            ls, _, ss = jax.jit(lambda p, b, pl, pr, sw: forward(
                p, cfg, b, rt, mode="train", plan=pl, predicted_idx=pr,
                slot_weights=sw)
            )(params, batch, plan2, pred, store.weights)
            key = f"k{top_k}_d{dup}_p{int(predicted)}"
            out[key] = {
                "diff": float(jnp.abs(lg.astype(jnp.float32)
                                      - ls.astype(jnp.float32)).max()),
                "counts_eq": bool(jnp.array_equal(sg["expert_counts"],
                                                  ss["expert_counts"])),
                "slots_eq": bool(jnp.array_equal(sg["slot_counts"],
                                                 ss["slot_counts"])),
                "migrated": int(diff.num_entries),
            }
        # no weight collective in the store-fed program (tokens still
        # all_to_all); the gather program must still contain the pool
        cfg = dataclasses.replace(base, moe=dataclasses.replace(
            base.moe, duplication_slots=1))
        params = init_model(jax.random.PRNGKey(0), cfg)
        plan = stack_plans([duplicate_experts_host(
            skewed_distribution(E, 2.5), 4, 1, 4).plan
            for _ in range(cfg.num_layers)])
        store = ReplicaStore.from_params(
            params["layers"]["moe"]["experts"], plan, num_experts=E,
            ep_ranks=4, dup_slots=1, mesh=mesh)
        batch = {"tokens": jnp.zeros((4, 32), jnp.int32)}
        jx_store = str(jax.make_jaxpr(lambda p, b, pl, sw: forward(
            p, cfg, b, rt, mode="train", plan=pl, slot_weights=sw))(
            params, batch, plan, store.weights))
        jx_gather = str(jax.make_jaxpr(lambda p, b, pl: forward(
            p, cfg, b, rt, mode="train", plan=pl))(params, batch, plan))
        out["store_has_allgather"] = "all_gather" in jx_store
        out["gather_has_allgather"] = "all_gather" in jx_gather
        print(json.dumps(out))
    """, timeout=1800)
    assert not res.pop("store_has_allgather")
    assert res.pop("gather_has_allgather")
    migrated_any = False
    for key, r in res.items():
        assert r["diff"] == 0.0, (key, r)
        assert r["counts_eq"] and r["slots_eq"], (key, r)
        migrated_any |= r["migrated"] > 0
    assert migrated_any, "no case exercised the migration step"


@pytest.mark.slow
def test_identity_plan_skips_pool_gather_but_matches():
    """The lax.cond gather skip: identity plan (dup slots compiled in but
    nothing duplicated) produces the same logits as a forced gather, and
    the decode (replicated-token) path agrees too."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.core.placement import identity_plan, stack_plans
        from repro.models.transformer import Runtime, forward, init_model, \\
            init_cache
        from repro.train.steps import make_decode_step

        base = get_config("mixtral-8x7b").reduced()
        cfg = dataclasses.replace(base, moe=dataclasses.replace(
            base.moe, duplication_slots=1, capacity_factor=8.0))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rt = Runtime(mesh=mesh, ep=True, ep_ranks=4, use_duplication=True)
        params = init_model(jax.random.PRNGKey(0), cfg)
        E = cfg.moe.num_experts
        idp = stack_plans([identity_plan(E, 4, 1, 4)
                           for _ in range(cfg.num_layers)])
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 32), 0, cfg.vocab_size)}
        # identity plan exercises the cond's skip branch; the dense
        # reference (no EP at all) is the ground truth
        lg, _, _ = jax.jit(lambda p, b, pl: forward(
            p, cfg, b, rt, mode="train", plan=pl))(params, batch, idp)
        ref, _, _ = forward(params, cfg, batch, Runtime(), mode="train")
        tok = jnp.ones((4, 1), jnp.int32)
        cache = init_cache(cfg, rt, 4, 32)
        with mesh:
            _, dl, _, _ = jax.jit(lambda p, t, c, pl: make_decode_step(
                cfg, rt)(p, t, c, 5, pl))(params, tok, cache, idp)
        _, dr, _, _ = make_decode_step(cfg, Runtime())(
            params, tok, init_cache(cfg, Runtime(), 4, 32), 5)
        print(json.dumps({
            "train_diff": float(jnp.abs(lg.astype(jnp.float32)
                                        - ref.astype(jnp.float32)).max()),
            "decode_diff": float(jnp.abs(dl.astype(jnp.float32)
                                         - dr.astype(jnp.float32)).max()),
        }))
    """)
    assert res["train_diff"] < 0.1          # bf16 path differences only
    assert res["decode_diff"] < 0.1


@pytest.mark.slow
def test_continuous_engine_store_migrates_without_recompiles():
    """Meshed ContinuousEngine in store mode: serves a workload, re-plans
    under a 1-chunk-per-step budget, commits migrations, and performs
    ZERO XLA compilations after warmup."""
    res = run_sub("""
        import dataclasses
        from repro.configs.registry import get_config
        from repro.models.transformer import init_model
        from repro.serve import ContinuousConfig, ContinuousEngine
        from repro.serve.scheduler import ServeRequest

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("mixtral-8x7b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        ccfg = ContinuousConfig(max_slots=4, prefill_len=32, block_size=16,
                                max_len=48, strategy="dist_only",
                                predict_interval=2, dup_slots=1,
                                metrics_window=4, migrate_chunks_per_step=1)
        eng = ContinuousEngine(cfg, params, ccfg, mesh=mesh, ep_ranks=4)
        eng.warmup()
        rng = np.random.default_rng(0)
        for i in range(6):
            eng.submit(ServeRequest(
                rid=i, arrival=0.0,
                tokens=rng.integers(0, cfg.vocab_size, 16).tolist(),
                max_new_tokens=4))
        n = 0
        while eng.has_work() and n < 40:
            eng.step(float(n)); n += 1
        recompiled = False
        try:
            eng.assert_no_recompiles()
        except AssertionError:
            recompiled = True
        eng.metrics.flush(eng._plan_stack, eng.ep_ranks, 1)
        s = eng.metrics.summary()
        print(json.dumps({
            "recompiled": recompiled,
            "completed": int(s["completed"]),
            "replans": s["migration_replans"],
            "commits": s["migration_commits"],
            "moved": s["migration_bytes_moved"],
            "store_version": np.asarray(eng._store.version).tolist(),
        }))
    """, timeout=1800)
    assert not res["recompiled"]
    assert res["completed"] == 6
    assert res["replans"] >= 1
    assert res["commits"] >= 1
    assert res["moved"] > 0
    assert sum(res["store_version"]) >= 1    # per-layer versions advanced
