"""Fleet serving tests: HBM budget ledger + global clamp, allocator and
dup-slot quota semantics, tenant admission/SLO classes, arbiter
hysteresis + cost gate, and the meshless/meshed FleetEngine smokes
(zero post-warmup recompiles with arbiter moves applied)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.duplication import duplicate_experts_host
from repro.core.placement import (identity_plan, plan_from_assignments,
                                  quota_limited_plan, stack_plans,
                                  store_bytes_per_rank)
from repro.fleet import (BATCH, INTERACTIVE, ArbiterConfig, FleetAdmission,
                         FleetArbiter, FleetBudget, ModelShare, ModelSignals,
                         SLOClass, kv_block_bytes)
from repro.runtime.diff import vacated_slots
from repro.serve import BlockAllocator
from repro.serve.metrics import RequestTiming, ServeMetrics

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------
# budget ledger
# --------------------------------------------------------------------------

def _share(name, *, dup=2, kv=16, weights=1000, entry=10, layers=2,
           experts=8, ranks=4, kvb=8, **kw):
    return ModelShare(name=name, weights_bytes=weights, entry_bytes=entry,
                      num_layers=layers, num_experts=experts, ep_ranks=ranks,
                      dup_slots=dup, kv_blocks=kv, kv_block_bytes=kvb, **kw)


def test_share_bytes_match_placement_math():
    s = _share("m")
    assert s.store_bytes(2) == store_bytes_per_rank(
        8, 4, 2, entry_bytes=10, num_layers=2)
    assert s.provisioned_bytes == 1000 + s.store_bytes(2) + 16 * 8
    assert s.active_bytes == s.provisioned_bytes       # full quotas
    s.kv_block_quota = 4
    s.dup_slot_quota = 1
    assert s.active_bytes == 1000 + s.store_bytes(1) + 4 * 8
    assert s.dup_slot_entry_bytes == 2 * 10


def test_share_quota_defaults_and_clamping():
    assert _share("a").dup_slot_quota == 2
    assert _share("a").kv_block_quota == 16
    s = _share("b", dup_slot_quota=1, kv_block_quota=99)
    assert s.dup_slot_quota == 1
    assert s.kv_block_quota == 16                      # clamped to pool


def test_clamp_unlimited_budget_is_identity():
    b = FleetBudget(0.0)
    b.register(_share("a"))
    b.register(_share("b", dup=1))
    assert b.clamp() == {"a": 2, "b": 1}
    assert b.shares["a"].kv_block_quota == 16


def test_clamp_shrinks_largest_store_first_then_kv():
    b = FleetBudget(0.0)
    big = b.register(_share("big", dup=3))
    small = b.register(_share("small", dup=1))
    full = b.provisioned_bytes()
    # one dup-slot entry is layers*entry = 20 bytes/rank of store; ask to
    # shave a bit more than one slot so exactly the biggest store pays
    b.total_bytes = float(full - 1)
    out = b.clamp()
    assert out == {"big": 2, "small": 1}
    assert b.provisioned_bytes() <= b.total_bytes
    assert big.dup_slot_quota <= big.dup_slots
    # now force past all dup slots into proportional KV-quota shrink
    b2 = FleetBudget(0.0)
    b2.register(_share("a"))
    b2.register(_share("b"))
    no_dup_kv_half = (2 * 1000
                      + 2 * _share("x", dup=0).store_bytes(0)
                      + 16 * 8)                        # half of 2x16 blocks
    b2.total_bytes = float(no_dup_kv_half)
    out2 = b2.clamp()
    assert out2 == {"a": 0, "b": 0}
    assert b2.shares["a"].kv_block_quota < 16
    assert b2.provisioned_bytes() - b2.total_bytes <= sum(
        s.kv_blocks * s.kv_block_bytes for s in b2.shares.values())


def test_clamp_raises_when_residency_alone_overflows():
    b = FleetBudget(10.0)                              # absurdly small
    b.register(_share("a"))
    with pytest.raises(ValueError, match="cannot fit"):
        b.clamp()


def test_transfer_moves_quota_and_respects_bounds():
    b = FleetBudget(0.0)
    b.register(_share("hot", dup_slot_quota=1, kv_block_quota=8))
    b.register(_share("cold", dup_slot_quota=1, kv_block_quota=8))
    assert b.can_transfer("cold", "hot", dup_slots=1, kv_blocks=4)
    b.transfer("cold", "hot", dup_slots=1, kv_blocks=4)
    assert b.shares["hot"].dup_slot_quota == 2
    assert b.shares["cold"].dup_slot_quota == 0
    assert b.shares["hot"].kv_block_quota == 12
    assert b.shares["cold"].kv_block_quota == 4
    # dst at its compiled ceiling: no further dup grant
    assert not b.can_transfer("cold", "hot", dup_slots=1)
    # src exhausted
    assert not b.can_transfer("cold", "hot", kv_blocks=5)
    with pytest.raises(ValueError, match="violates"):
        b.transfer("cold", "hot", dup_slots=1)


def test_transfer_respects_active_byte_budget():
    b = FleetBudget(0.0)
    # hot's slot entries and blocks are pricier than cold's: a 1:1 quota
    # move GROWS the fleet's active bytes, which a tight budget refuses
    b.register(_share("hot", entry=50, dup_slot_quota=1))
    b.register(_share("cold", kvb=1, kv_block_quota=8))
    b.total_bytes = float(b.active_bytes())
    assert not b.can_transfer("cold", "hot", dup_slots=1)  # store grows
    assert not b.can_transfer("cold", "hot", kv_blocks=2)  # 8B>1B blocks
    assert b.can_transfer("hot", "cold", kv_blocks=2)      # shrinks active


def test_budget_summary_has_per_model_rows():
    b = FleetBudget(123.0)
    b.register(_share("m1"))
    s = b.summary()
    for k in ("budget_total_bytes", "m1_weights_bytes", "m1_store_bytes",
              "m1_kv_bytes", "m1_dup_slot_quota", "m1_kv_block_quota"):
        assert k in s


def test_kv_block_bytes_formula():
    # L * bs * kv_heads * head_dim * 2 bytes * (K and V)
    assert kv_block_bytes(2, 8, 4, 16) == 2 * 8 * 4 * 16 * 2 * 2


# --------------------------------------------------------------------------
# allocator quota (deferred handback)
# --------------------------------------------------------------------------

def test_allocator_quota_caps_in_use():
    a = BlockAllocator(num_blocks=9, block_size=4)
    a.set_quota(4)
    got = a.alloc(4)
    assert got is not None and a.in_use == 4
    assert a.alloc(1) is None                          # quota, pool not dry
    assert a.free_blocks == 4
    a.free(got[:1])
    assert a.alloc(1) is not None                      # drained back under


def test_allocator_quota_shrink_below_usage_defers_handback():
    a = BlockAllocator(num_blocks=9, block_size=4)
    got = a.alloc(6)
    a.set_quota(3)                                     # below in_use=6
    assert a.in_use == 6                               # nothing reclaimed
    assert a.alloc(1) is None                          # growth refused
    a.free(got[:3])
    assert a.alloc(1) is None                          # still at quota (3)
    a.free(got[3:4])
    assert a.alloc(1) is not None


def test_allocator_quota_clamps_to_pool():
    a = BlockAllocator(num_blocks=5, block_size=4)
    a.set_quota(99)
    assert a.quota == 4
    a.set_quota(-3)
    assert a.quota == 0
    assert a.alloc(1) is None


# --------------------------------------------------------------------------
# quota-limited placement plans (full compiled geometry)
# --------------------------------------------------------------------------

def _quota_plan(dist, E=8, R=4, D=2, C=4, q=1):
    res = duplicate_experts_host(dist, R, q, C)
    return quota_limited_plan(res.assignments, E, R, D, C, quota=q)


def test_quota_limited_plan_keeps_compiled_geometry():
    dist = [0.5, 0.2, 0.1, 0.05, 0.05, 0.05, 0.03, 0.02]
    full = plan_from_assignments(
        duplicate_experts_host(dist, 4, 2, 4).assignments, 8, 4, 2, 4)
    lim = _quota_plan(dist, q=1)
    for f in ("n_replicas", "replica_table", "pool_expert", "pool_sel"):
        assert np.asarray(getattr(lim, f)).shape \
            == np.asarray(getattr(full, f)).shape, f


def test_quota_limited_plan_respects_per_rank_quota():
    dist = [0.4, 0.3, 0.1, 0.05, 0.05, 0.04, 0.03, 0.03]
    E, R, D, q = 8, 4, 3, 1
    lim = _quota_plan(dist, E=E, R=R, D=D, q=q)
    # extra copies per destination rank = total replicas beyond homes,
    # grouped by the rank owning the replica slot
    e_loc, n_slots = E // R, E // R + D
    table = np.asarray(lim.replica_table)
    n_rep = np.asarray(lim.n_replicas)
    extra = np.zeros(R, np.int64)
    for e in range(E):
        for c in range(1, int(n_rep[e])):
            extra[int(table[e, c]) // n_slots] += 1
    assert (extra <= q).all(), extra


def test_quota_zero_is_identity_at_full_geometry():
    dist = [0.9] + [0.1 / 7] * 7
    lim = _quota_plan(dist, q=0)
    ident = identity_plan(8, 4, 2, 4)
    assert (np.asarray(lim.n_replicas) == 1).all()
    assert np.array_equal(np.asarray(lim.replica_table),
                          np.asarray(ident.replica_table))


def test_quota_shrink_strands_slots_with_zero_transfer():
    dist = [0.5, 0.2, 0.1, 0.05, 0.05, 0.05, 0.03, 0.02]
    E, R, D = 8, 4, 2
    rich = stack_plans([_quota_plan(dist, q=2)] * 2)
    poor = stack_plans([_quota_plan(dist, q=0)] * 2)
    assert vacated_slots(rich, poor, R, D) > 0
    assert vacated_slots(poor, rich, R, D) == 0
    assert vacated_slots(rich, rich, R, D) == 0


# --------------------------------------------------------------------------
# admission + SLO classes
# --------------------------------------------------------------------------

def _timing(tenant, ttft, tpot, toks=5):
    return RequestTiming(rid=0, arrival=0.0, t_first_token=ttft,
                         t_finished=ttft + tpot * (toks - 1),
                         prompt_len=8, new_tokens=toks, tenant=tenant)


def test_admission_routes_and_defaults():
    adm = FleetAdmission(routes={"a": "m1"}, default_model="m0")
    assert adm.route("a") == "m1"
    assert adm.route("unknown") == "m0"
    assert sorted(adm.tenants_for("m1")) == ["a"]
    strict = FleetAdmission(routes={"a": "m1"})
    with pytest.raises(KeyError):
        strict.route("unknown")


def test_strictest_slo_takes_min_per_bound():
    adm = FleetAdmission(
        routes={"chat": "m", "batch": "m"},
        slos={"chat": INTERACTIVE, "batch": BATCH})
    s = adm.strictest_slo("m")
    assert s.slo_ttft == INTERACTIVE.slo_ttft
    assert s.slo_tpot == INTERACTIVE.slo_tpot
    assert adm.strictest_slo("other") == adm.default_slo


def test_tenant_and_model_attainment_judged_per_class():
    adm = FleetAdmission(
        routes={"chat": "m", "batch": "m"},
        slos={"chat": SLOClass("chat", slo_ttft=1.0, slo_tpot=0.5),
              "batch": BATCH})
    m = ServeMetrics()
    m.timings.extend([
        _timing("chat", ttft=0.5, tpot=0.1),           # meets chat SLO
        _timing("chat", ttft=5.0, tpot=0.1),           # TTFT miss
        _timing("batch", ttft=5.0, tpot=0.1),          # batch has no TTFT
    ])
    assert adm.tenant_attainment(m, "chat") == 0.5
    assert adm.tenant_attainment(m, "batch") == 1.0
    assert adm.model_attainment(m, "m") == 0.5         # worst tenant
    assert adm.model_attainment(m, "empty-model") == 1.0


def test_slo_attainment_defaults_to_one_without_completions():
    assert ServeMetrics().slo_attainment(tenant="x") == 1.0


# --------------------------------------------------------------------------
# arbiter: pressure, hysteresis, cost gate
# --------------------------------------------------------------------------

def _signals(hot_attain=0.5, hot_queue=8, cold_attain=1.0, step_s=0.1,
             entry=64, hot_skew=2.0):
    return {
        "hot": ModelSignals(slo_attainment=hot_attain, queue_depth=hot_queue,
                            window_skew=hot_skew, step_s=step_s,
                            dup_entry_bytes=entry),
        "cold": ModelSignals(slo_attainment=cold_attain, queue_depth=0,
                             window_skew=1.0, step_s=step_s,
                             dup_entry_bytes=entry),
    }


def _arbiter(patience=2, **kw):
    b = FleetBudget(0.0)
    b.register(_share("hot", dup_slot_quota=1, kv_block_quota=8))
    b.register(_share("cold", dup_slot_quota=1, kv_block_quota=8))
    return FleetArbiter(ArbiterConfig(patience=patience, window_iters=4,
                                      kv_blocks_per_move=4,
                                      kv_floor_blocks=2, **kw), b)


def test_arbiter_waits_out_patience_then_moves():
    arb = _arbiter(patience=2)
    assert arb.observe(1.0, _signals()) == []          # vote 1 of 2
    moves = arb.observe(2.0, _signals())
    assert len(moves) == 1
    mv = moves[0]
    assert (mv.src, mv.dst) == ("cold", "hot")
    assert mv.kv_blocks == 4
    assert arb.budget.shares["hot"].kv_block_quota == 12
    assert arb.budget.shares["cold"].kv_block_quota == 4
    assert "cold->hot" in mv.explain()


def test_arbiter_resets_votes_when_pressure_gap_closes():
    arb = _arbiter(patience=2)
    arb.observe(1.0, _signals())
    arb.observe(2.0, _signals(hot_attain=1.0, hot_queue=0,
                              hot_skew=1.0))               # gap closes
    assert arb.observe(3.0, _signals()) == []          # vote restarted
    assert len(arb.observe(4.0, _signals())) == 1


def test_arbiter_single_model_never_moves():
    arb = _arbiter(patience=1)
    assert arb.observe(1.0, {"hot": _signals()["hot"]}) == []


def test_arbiter_cost_gate_blocks_dup_but_not_kv():
    # an absurd per-slot migration cost vs a tiny window gain: the dup
    # grant must be rejected by should_migrate, the KV move still lands
    arb = _arbiter(patience=1)
    sig = _signals(step_s=1e-9, entry=10 ** 15)
    moves = arb.observe(1.0, sig)
    assert len(moves) == 1
    assert moves[0].dup_slots == 0
    assert moves[0].kv_blocks == 4
    # cheap migration + real gain: dup slot moves too
    arb2 = _arbiter(patience=1)
    moves2 = arb2.observe(1.0, _signals(step_s=0.5, entry=64))
    assert moves2[0].dup_slots == 1
    assert moves2[0].stall_s >= 0.0
    assert arb2.budget.shares["hot"].dup_slot_quota == 2


def test_arbiter_kv_floor_protects_donor():
    arb = _arbiter(patience=1)
    for t in range(1, 6):
        arb.observe(float(t), _signals(step_s=1e-9, entry=10 ** 15))
    # cold started at 8; floor 2 with 4-block moves leaves exactly 4
    assert arb.budget.shares["cold"].kv_block_quota == 4
    assert arb.budget.shares["hot"].kv_block_quota == 12


def test_arbiter_max_moves_cap():
    arb = _arbiter(patience=1, max_moves=1)
    arb.observe(1.0, _signals())
    assert arb.observe(2.0, _signals()) == []
    assert len(arb.moves) == 1


# --------------------------------------------------------------------------
# ServeMetrics model label: two resident instances share one registry
# --------------------------------------------------------------------------

def test_serve_metrics_model_label_keeps_instances_separate():
    from repro.obs.metrics import MetricsRegistry
    reg = MetricsRegistry()
    m1 = ServeMetrics(registry=reg, model="m1")
    m2 = ServeMetrics(registry=reg, model="m2")
    m1.timings.append(_timing("", ttft=0.5, tpot=0.1))
    m1.record_completion(m1.timings[-1])
    m2.timings.append(_timing("", ttft=0.7, tpot=0.1))
    m2.record_completion(m2.timings[-1])
    snap = reg.snapshot()
    assert snap['serve_requests_completed_total{model="m1"}'] == 1.0
    assert snap['serve_requests_completed_total{model="m2"}'] == 1.0
    prom = reg.to_prometheus()
    assert 'model="m1"' in prom and 'model="m2"' in prom


def test_serve_metrics_without_model_keeps_unlabeled_series():
    from repro.obs.metrics import MetricsRegistry
    reg = MetricsRegistry()
    m = ServeMetrics(registry=reg)
    m.timings.append(_timing("", ttft=0.5, tpot=0.1))
    m.record_completion(m.timings[-1])
    assert "serve_requests_completed_total" in reg.snapshot()


# --------------------------------------------------------------------------
# FleetEngine end-to-end (meshless smoke; the meshed smoke is slow-marked)
# --------------------------------------------------------------------------

def _fleet(enable_arbiter=True, hbm=0.0, trace=False):
    import jax

    from repro.configs.registry import get_config
    from repro.fleet import FleetEngine, FleetModelSpec
    from repro.models.transformer import init_model
    from repro.serve import ContinuousConfig

    cfg = get_config("mixtral-8x7b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = ContinuousConfig(max_slots=2, prefill_len=16, block_size=8,
                            max_len=32, strategy="dist_only",
                            predict_interval=2, dup_slots=1,
                            metrics_window=2)
    adm = FleetAdmission(
        routes={"a": "m1", "b": "m2"},
        slos={"a": SLOClass("a", slo_ttft=4.0), "b": BATCH})
    specs = [FleetModelSpec("m1", cfg, params, ccfg),
             FleetModelSpec("m2", cfg, params, ccfg)]
    fleet = FleetEngine(specs, admission=adm, hbm_budget_bytes=hbm,
                        arbiter_cfg=ArbiterConfig(window_iters=2,
                                                  patience=1),
                        enable_arbiter=enable_arbiter, trace=trace)
    return fleet, cfg


def _fleet_requests(cfg, n=6):
    from repro.serve import ServeRequest
    rng = np.random.default_rng(0)
    return [ServeRequest(rid=i, arrival=0.25 * i,
                         tokens=rng.integers(0, cfg.vocab_size, 8),
                         max_new_tokens=3,
                         tenant="a" if i % 2 == 0 else "b")
            for i in range(n)]


def test_fleet_engine_meshless_smoke():
    fleet, cfg = _fleet(trace=True)
    fleet.warmup()
    for r in _fleet_requests(cfg):
        fleet.submit(r)
    assert len(fleet.engines["m1"].scheduler.waiting) == 3
    assert len(fleet.engines["m2"].scheduler.waiting) == 3
    now, n = 0.0, 0
    while fleet.has_work() and n < 60:
        fleet.step(now)
        now += 0.25
        n += 1
    assert not fleet.has_work()
    fleet.assert_no_recompiles()
    s = fleet.summary()
    assert s["fleet_completed"] == 6.0
    assert s["fleet_models"] == 2.0
    assert 0.0 <= s["fleet_slo_attainment"] <= 1.0
    assert s["m1_kv_block_quota"] > 0
    # merged trace: one process row per model, schema-valid
    from repro.obs import validate_chrome_trace
    doc = fleet.merged_trace()
    assert validate_chrome_trace(doc) == []
    pids = {e["pid"] for e in doc["traceEvents"]}
    assert pids == {1, 2}


def test_fleet_engine_applies_manual_quota_move():
    fleet, cfg = _fleet(enable_arbiter=False)
    fleet.warmup()
    eng1, eng2 = fleet.engines["m1"], fleet.engines["m2"]
    full = eng1.allocator.quota
    fleet.budget.transfer("m2", "m1", kv_blocks=0, dup_slots=0)
    # apply a KV quota move by hand the way _arbitrate does
    fleet.budget.shares["m2"].kv_block_quota -= 2
    fleet.budget.shares["m1"].kv_block_quota = min(
        fleet.budget.shares["m1"].kv_blocks,
        fleet.budget.shares["m1"].kv_block_quota)      # ceiling respected
    eng2.allocator.set_quota(fleet.budget.shares["m2"].kv_block_quota)
    assert eng2.allocator.quota == full - 2
    for r in _fleet_requests(cfg):
        fleet.submit(r)
    now, n = 0.0, 0
    while fleet.has_work() and n < 60:
        fleet.step(now)
        now += 0.25
        n += 1
    assert not fleet.has_work()
    fleet.assert_no_recompiles()


def test_fleet_engine_initial_quota_below_ceiling():
    import jax

    from repro.configs.registry import get_config
    from repro.fleet import FleetEngine, FleetModelSpec
    from repro.models.transformer import init_model
    from repro.serve import ContinuousConfig

    cfg = get_config("mixtral-8x7b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = ContinuousConfig(max_slots=2, prefill_len=16, block_size=8,
                            max_len=32, strategy="none", dup_slots=1)
    fleet = FleetEngine(
        [FleetModelSpec("m", cfg, params, ccfg,
                        dup_slot_quota=0, kv_block_quota=3)])
    eng = fleet.engines["m"]
    assert eng.allocator.quota == 3
    assert eng.dup_slot_quota == 0
    assert fleet.budget.shares["m"].kv_block_quota == 3


def test_fleet_rejects_duplicate_model_names():
    import jax

    from repro.configs.registry import get_config
    from repro.fleet import FleetEngine, FleetModelSpec
    from repro.models.transformer import init_model
    from repro.serve import ContinuousConfig

    cfg = get_config("mixtral-8x7b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    ccfg = ContinuousConfig(max_slots=2, prefill_len=16, block_size=8,
                            max_len=32, strategy="none")
    with pytest.raises(ValueError, match="duplicate"):
        FleetEngine([FleetModelSpec("m", cfg, params, ccfg),
                     FleetModelSpec("m", cfg, params, ccfg)])


@pytest.mark.slow
def test_fleet_meshed_smoke_arbiter_move_no_recompile():
    """Two model instances on a real 2x4 EP mesh: starve one model's KV
    quota, drive load at it, and require >= 1 arbiter move and zero
    post-warmup recompiles across the whole fleet."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, numpy as np
        from repro.configs.registry import get_config
        from repro.fleet import (ArbiterConfig, BATCH, FleetAdmission,
                                 FleetEngine, FleetModelSpec, SLOClass)
        from repro.models.transformer import init_model
        from repro.serve import ContinuousConfig, ServeRequest

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("mixtral-8x7b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        ccfg = ContinuousConfig(max_slots=2, prefill_len=16, block_size=8,
                                max_len=32, strategy="dist_only",
                                predict_interval=4, dup_slots=2,
                                metrics_window=4)
        adm = FleetAdmission(
            routes={"a": "m1", "b": "m2"},
            slos={"a": SLOClass("a", slo_ttft=0.75, slo_tpot=1.0),
                  "b": BATCH})
        specs = [FleetModelSpec(n, cfg, params, ccfg,
                                dup_slot_quota=1, kv_block_quota=4)
                 for n in ("m1", "m2")]
        fleet = FleetEngine(
            specs, mesh=mesh, ep_ranks=4, admission=adm,
            arbiter_cfg=ArbiterConfig(window_iters=4, patience=1,
                                      queue_norm=2.0, kv_blocks_per_move=2,
                                      kv_floor_blocks=1),
            enable_arbiter=True)
        fleet.warmup()
        rng = np.random.default_rng(0)
        for i in range(8):
            fleet.submit(ServeRequest(
                rid=i, arrival=0.25 * i,
                tokens=rng.integers(0, cfg.vocab_size, 12),
                max_new_tokens=4, tenant="a"))
        now, n = 0.0, 0
        while fleet.has_work() and n < 80:
            fleet.step(now)
            now += 0.25
            n += 1
        recompiled = 0
        try:
            fleet.assert_no_recompiles()
        except AssertionError:
            recompiled = 1
        s = fleet.summary()
        print(json.dumps({
            "drained": not fleet.has_work(),
            "recompiled": recompiled,
            "moves": s["fleet_arbiter_moves"],
            "m1_kv": s["m1_kv_block_quota"],
            "completed": s["fleet_completed"],
        }))
    """)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=900, env=dict(os.environ, PYTHONPATH=os.path.join(ROOT,
                                                                  "src")))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["drained"], res
    assert res["recompiled"] == 0, res
    assert res["moves"] >= 1, res
    assert res["m1_kv"] > 4, res                       # quota moved to m1
