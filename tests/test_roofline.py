"""Roofline machinery tests: HLO collective parsing (incl. loop-trip
correction), analytic op model sanity, report plumbing."""

import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.roofline import (RooflineReport, _shape_bytes, analytic_flops,
                            analytic_hbm_bytes, collective_bytes, model_flops)

HLO_FLAT = """
HloModule test
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128] parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = f32[64,128]{1,0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[16,128]{1,0} reduce-scatter(%ag), dimensions={0}
}
"""

HLO_LOOPED = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8] get-tuple-element(%p), index=1
  %ar = f32[8,8]{1,0} all-reduce(%x), to_apply=%add
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  %ag = f32[32,8]{1,0} all-gather(%p0), dimensions={0}
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert _shape_bytes("f32[]") == 4
    assert _shape_bytes("pred[7]") == 7


def test_collective_bytes_flat():
    out = collective_bytes(HLO_FLAT)
    assert out["all-reduce"] == 16 * 128 * 4
    assert out["all-gather"] == 64 * 128 * 4
    assert out["reduce-scatter"] == 16 * 128 * 4
    assert out["count"] == 3


def test_collective_bytes_loop_correction():
    """Collectives inside a while body are multiplied by the trip count;
    entry-level collectives are not."""
    out1 = collective_bytes(HLO_LOOPED, loop_trips=1)
    out10 = collective_bytes(HLO_LOOPED, loop_trips=10)
    ar, ag = 8 * 8 * 4, 32 * 8 * 4
    assert out1["all-reduce"] == ar and out1["all-gather"] == ag
    assert out10["all-reduce"] == 10 * ar        # in the loop
    assert out10["all-gather"] == ag             # outside the loop


# --------------------------------------------------------------------------
# analytic op model
# --------------------------------------------------------------------------

def test_model_flops_train_6nd():
    cfg = get_config("olmo-1b")
    sh = INPUT_SHAPES["train_4k"]
    assert model_flops(cfg, sh) == pytest.approx(
        6.0 * cfg.num_params() * sh.global_batch * sh.seq_len)


def test_model_flops_moe_counts_active_only():
    cfg = get_config("arctic-480b")
    sh = INPUT_SHAPES["prefill_32k"]
    assert model_flops(cfg, sh) < 2.0 * cfg.num_params() * \
        sh.global_batch * sh.seq_len * 0.2


def test_analytic_flops_ordering():
    """train > prefill (3x backward) >> decode, for the same arch."""
    cfg = get_config("stablelm-3b")
    f = {k: analytic_flops(cfg, INPUT_SHAPES[k]) for k in INPUT_SHAPES}
    assert f["train_4k"] > f["prefill_32k"] > f["decode_32k"] > f["long_500k"]


def test_analytic_flops_close_to_model_flops_dense():
    """For a dense arch at train shapes, the analytic total is within ~2x
    of 6ND (attention + vocab head explain the excess)."""
    cfg = get_config("olmo-1b")
    sh = INPUT_SHAPES["train_4k"]
    ratio = analytic_flops(cfg, sh) / model_flops(cfg, sh)
    assert 1.0 <= ratio <= 2.5


def test_analytic_hbm_decode_dominated_by_cache_or_weights():
    cfg = get_config("stablelm-3b")
    b = analytic_hbm_bytes(cfg, INPUT_SHAPES["decode_32k"], 256)
    # full KV cache (32k x 32 kv-heads) read dominates a 3B model's weights
    params_term = cfg.num_params() * 2 / 256
    assert b > params_term


def test_report_dominant_and_ratio():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        analytic_flops_per_device=197e12,      # exactly 1s compute
        analytic_hbm_per_device=819e9 / 2,     # 0.5s memory
        hlo_flops_per_device=1e12, hlo_bytes_per_device=1e9,
        collective_bytes_per_device=9e9,       # 0.1s collective
        model_flops_total=197e12 * 256 / 2)
    assert rep.dominant == "compute"
    assert rep.total_s == pytest.approx(1.0)
    assert rep.useful_flops_ratio == pytest.approx(0.5)
    row = rep.row()
    assert {"compute_s", "memory_s", "collective_s", "dominant"} <= set(row)
