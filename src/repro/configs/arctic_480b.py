"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base] —
128-expert top-2 MoE with a dense residual branch (dense-MoE hybrid)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    attention="gqa",
    norm="rmsnorm",
    activation="swiglu",
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        d_ff_expert=4864,
        dense_residual=True,
        d_ff_dense=4864,
        max_copies=4,
    ),
    source="hf:Snowflake/snowflake-arctic-base",
)
