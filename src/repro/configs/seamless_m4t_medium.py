"""SeamlessM4T-medium [arXiv:2308.11596] — enc-dec, multimodal.

Backbone only: the mel-spectrogram + conv feature extractor is a stub;
``input_specs`` supplies precomputed frame embeddings (B, T_src, d_model)
to the encoder. We model the text decoder (12L) over an equal-depth
speech encoder.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    attention="gqa",
    norm="rmsnorm",
    activation="gelu",
    input_mode="tokens",            # decoder consumes tokens; encoder consumes frames
    encoder=EncoderConfig(
        num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, max_source_len=4096),
    source="arXiv:2308.11596",
)
