"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427] — hybrid RG-LRU +
local attention at 1:2 ratio (pattern: recurrent, recurrent, local)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,              # ~1:2 -> pattern tiled over 26 layers
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attention="mixed",
    norm="rmsnorm",
    activation="gelu",
    block_pattern=("recurrent", "recurrent", "local"),
    rnn_width=2560,             # RG-LRU recurrence width
    local_window=2048,
    source="arXiv:2402.19427",
)
