"""DeepSeek-V2-Lite 16B [arXiv:2405.04434] — MLA (kv_lora=512) +
64 routed experts top-6 with 2 shared experts.

Assignment note: the primary spec line says "MoE 64e top-6"; the bracket
mentions "160 routed" which matches DeepSeek-V2 (236B), not Lite. We follow
the primary spec (64 routed, top-6, 2 shared), which is the real V2-Lite.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                 # routed-expert width; first layer dense in HF, we keep uniform
    vocab_size=102400,
    attention="mla",
    norm="rmsnorm",
    activation="swiglu",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,          # Lite uses full-rank Q
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared_experts=2,
        max_copies=4,
    ),
    source="arXiv:2405.04434",
)
