"""LLaMA-MoE 3.5B [Zhu et al., EMNLP 2024; hf:llama-moe/LLaMA-MoE-v1-3_5B]
— paper Appendix C generality model: LLaMA-7B FFNs split into 16 experts
(d_ff 11008 -> 16 x 688), top-4 routing, MHA (no GQA), SwiGLU."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama-moe-3.5b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,            # MHA
    d_ff=11008,
    vocab_size=32000,
    attention="gqa",
    activation="swiglu",
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=688),
    source="EMNLP 2024 llama-moe; appendix-C model of MoE-GPS",
)
