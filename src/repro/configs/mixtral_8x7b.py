"""Mixtral 8x7B [arXiv:2401.04088] — the paper's own model.

8 experts top-2, GQA (8 kv heads), SwiGLU, 4K sliding-window attention —
exactly the architecture MoE-GPS evaluates (Sec 3.4 / Fig 6).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attention="gqa",
    sliding_window=4096,
    norm="rmsnorm",
    activation="swiglu",
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_ff_expert=14336,
        max_copies=4,
    ),
    source="arXiv:2401.04088",
)
