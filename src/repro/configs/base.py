"""Config system for the repro framework.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
Configs are plain frozen dataclasses (hashable -> usable as jit static args).

``reduced()`` derives the CPU-smoke variant (<=2 layers, d_model<=512,
<=4 experts) of the same family, used by tests; full configs are only ever
lowered via ShapeDtypeStruct in the dry-run.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0          # always-on experts (deepseek-style)
    dense_residual: bool = False         # arctic: dense FFN in parallel with MoE
    d_ff_dense: int = 0                  # width of the dense residual branch
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    router_z_loss: float = 1e-3
    # Paper technique knobs -------------------------------------------------
    max_copies: int = 4                  # Algorithm 1 C_max
    duplication_slots: int = 0           # extra expert slots per EP rank (0 = E/ranks)
    # Dispatch hot path -----------------------------------------------------
    # "sort": argsort + cumsum-offset send-buffer packing (fast path);
    # "onehot": (N, S) one-hot cumsum + scatter (reference oracle).
    # Both produce bit-identical send buffers, stats and drop decisions.
    dispatch_impl: str = "sort"
    # Replica weight movement -----------------------------------------------
    # "store": engines keep persistent per-rank slot-weight buffers
    # (repro.runtime.ReplicaStore) and move weights only when the plan
    # changes; "gather": per-step all_gather replica pool (bit-exact
    # oracle, and the fallback whenever no store is threaded in).
    replica_impl: str = "store"
    # Overlapped (async-prefetch) migration: plan-diff fills are staged
    # per layer and issued during the forward pass instead of between
    # engine steps — forward() selects old-plan slots per layer until
    # that layer's fill commits (repro.runtime.LayerStagedExecutor).
    # False restores the synchronous drain-at-replan path.
    overlap_migration: bool = True
    # Token rescheduling (repro.schedule) ------------------------------------
    # Capacity fraction of the rescue round: tokens that overflow their
    # round-1 slot are re-dispatched to an alternate copy through a second,
    # smaller all-to-all with per-slot capacity
    # ``max(8, cap * resched_cap_frac)``. Only active when a reschedule
    # quota tensor is threaded into dispatch (lever = reschedule/both).
    resched_cap_frac: float = 0.5
    # Per-rank HBM budget (GB) for the replica store (which holds a second
    # copy of the home experts plus the replica slots). 0 = unlimited;
    # otherwise engines clamp duplication_slots down until the store fits
    # (core.placement.clamp_dup_slots) so the prefetcher cannot
    # over-replicate past device memory.
    store_hbm_budget_gb: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0                 # 0 = full-rank Q projection
    rope_head_dim: int = 64              # decoupled RoPE dims per head
    v_head_dim: int = 128
    nope_head_dim: int = 128


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (seamless-m4t) architectures."""
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    max_source_len: int = 4096


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // num_heads
    # attention ------------------------------------------------------------
    attention: str = "gqa"               # gqa | mla | none (ssm) | mixed (hybrid)
    qkv_bias: bool = False
    sliding_window: int = 0              # 0 = full attention
    rope_theta: float = 10000.0
    # Paged decode attention ------------------------------------------------
    # "fused": single Pallas pass walks block_tables and computes GQA
    # attention with an online-softmax accumulator straight from the shared
    # KV pool (interpret=True on CPU/test meshes); "gather": materialize
    # the (B, M*bs, K, hd) logical view first (bit-exact oracle — same
    # blockwise op sequence, so fp32 matches the kernel exactly).
    paged_attn_impl: str = "fused"
    # norms / activations ----------------------------------------------------
    norm: str = "rmsnorm"                # rmsnorm | nonparametric (olmo)
    activation: str = "swiglu"           # swiglu | gelu | relu | relu2 (rwkv)
    tie_embeddings: bool = False
    # family extensions ------------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid (recurrentgemma): block pattern repeated over layers
    block_pattern: Tuple[str, ...] = ()  # e.g. ("recurrent","recurrent","local")
    rnn_width: int = 0                   # RG-LRU recurrence width (griffin: ~4/3 d)
    local_window: int = 2048             # local-attention window (hybrid)
    # modality frontends (stubs): tokens | patches (vlm) | frames (audio)
    input_mode: str = "tokens"
    num_prefix_embeddings: int = 0       # patch/frame embeddings prepended
    # training --------------------------------------------------------------
    lr_schedule: str = "cosine"          # cosine | wsd (minicpm)
    # citation for the config ------------------------------------------------
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------ utils
    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def subquadratic(self) -> bool:
        """True if the arch supports O(window)/O(1)-state decode natively."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def num_params(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention
        hd = self.head_dim
        if self.attention == "mla" and self.mla is not None:
            m = self.mla
            per_layer += d * m.kv_lora_rank                       # kv down
            per_layer += m.kv_lora_rank * self.num_heads * (m.nope_head_dim + m.v_head_dim)
            per_layer += d * m.rope_head_dim                      # shared k_rope
            qd = m.q_lora_rank or d
            if m.q_lora_rank:
                per_layer += d * m.q_lora_rank
            per_layer += qd * self.num_heads * (m.nope_head_dim + m.rope_head_dim)
            per_layer += self.num_heads * m.v_head_dim * d        # out proj
        elif self.attention in ("gqa", "mixed"):
            per_layer += d * self.num_heads * hd                  # Q
            per_layer += 2 * d * self.num_kv_heads * hd           # K,V
            per_layer += self.num_heads * hd * d                  # O
        elif self.attention == "none" and self.family == "ssm":
            per_layer += 6 * d * d // 2                           # rwkv6 time-mix approx
        # ffn
        if self.moe is not None:
            e = self.moe
            ff_mult = 3 if self.activation == "swiglu" else 2
            per_layer += e.num_experts * ff_mult * d * e.d_ff_expert
            per_layer += e.num_shared_experts * ff_mult * d * e.d_ff_expert
            if e.dense_residual:
                per_layer += ff_mult * d * (e.d_ff_dense or self.d_ff)
            per_layer += d * e.num_experts                        # router
        else:
            ff_mult = 3 if self.activation == "swiglu" else 2
            per_layer += ff_mult * d * self.d_ff
        total = emb + L * per_layer
        if self.encoder is not None:
            enc = self.encoder
            enc_layer = 4 * enc.d_model * enc.num_heads * (enc.d_model // enc.num_heads)
            enc_layer += ff_mult * enc.d_model * enc.d_ff
            total += enc.num_layers * enc_layer
        return total

    def active_params(self) -> int:
        """Active (per-token) parameter count — MoE counts only top_k experts."""
        if self.moe is None:
            return self.num_params()
        e = self.moe
        ff_mult = 3 if self.activation == "swiglu" else 2
        inactive = (e.num_experts - e.top_k) * ff_mult * self.d_model * e.d_ff_expert
        return self.num_params() - self.num_layers * inactive

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant: same family/features, tiny dims."""
        changes = dict(
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            local_window=min(self.local_window, 32),
            rnn_width=min(self.rnn_width, 256) if self.rnn_width else 0,
            num_prefix_embeddings=min(self.num_prefix_embeddings, 8),
            name=self.name + "-smoke",
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_ff_dense=min(self.moe.d_ff_dense, 256) if self.moe.d_ff_dense else 0,
            )
        if self.mla is not None:
            changes["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=64, rope_head_dim=32,
                nope_head_dim=32, v_head_dim=32)
        if self.encoder is not None:
            changes["encoder"] = dataclasses.replace(
                self.encoder, num_layers=2, d_model=256, num_heads=4,
                num_kv_heads=2, d_ff=512, max_source_len=64)
        if self.block_pattern:
            changes["num_layers"] = len(self.block_pattern)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
