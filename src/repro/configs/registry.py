"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_MODULES = {
    "minicpm-2b": "repro.configs.minicpm_2b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "qwen1.5-0.5b": "repro.configs.qwen15_05b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "arctic-480b": "repro.configs.arctic_480b",
    "olmo-1b": "repro.configs.olmo_1b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    # paper Appendix C generality models (benchmarks only, not assigned)
    "llama-moe-3.5b": "repro.configs.llama_moe_3_5b",
    "switch-base-128": "repro.configs.switch_base_128",
}

_PAPER_ARCHS = ("mixtral-8x7b", "llama-moe-3.5b", "switch-base-128")
ASSIGNED_ARCHS = [k for k in _MODULES if k not in _PAPER_ARCHS]
ALL_ARCHS = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}
