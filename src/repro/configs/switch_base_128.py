"""Switch Transformer base-128 [Fedus et al., JMLR 2022] — paper Appendix C
generality model: T5-base geometry, 128 experts top-1, ReLU FFN, MHA."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="switch-base-128",
    family="moe",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,            # MHA (no GQA, paper Sec 5)
    d_ff=3072,
    vocab_size=32128,
    attention="gqa",
    activation="relu",
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=3072,
                  capacity_factor=1.25),
    source="JMLR 23(120) Switch Transformers; appendix-C model of MoE-GPS",
)
