"""RWKV-6 Finch 7B [arXiv:2404.05892] — attention-free SSM with
data-dependent decay time-mix and relu^2 channel-mix."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # time-mix heads (head_dim=64)
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    attention="none",
    norm="rmsnorm",
    activation="relu2",
    source="arXiv:2404.05892",
)
