"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6-mistral-7b-hf family] — VLM.

Transformer BACKBONE only: the ViT/SigLIP vision tower + projector is a
stub; ``input_specs`` supplies precomputed anyres patch embeddings
(num_prefix_embeddings per sequence) of shape (B, P, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    attention="gqa",
    norm="rmsnorm",
    activation="swiglu",
    input_mode="mixed",
    num_prefix_embeddings=2880,   # anyres tiling: 5 tiles x 576 patches
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
