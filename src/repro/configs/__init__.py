from repro.configs.base import (
    INPUT_SHAPES,
    EncoderConfig,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
)
from repro.configs.registry import ALL_ARCHS, ASSIGNED_ARCHS, all_configs, get_config

__all__ = [
    "INPUT_SHAPES", "EncoderConfig", "InputShape", "MLAConfig", "ModelConfig",
    "MoEConfig", "ALL_ARCHS", "ASSIGNED_ARCHS", "all_configs", "get_config",
]
