"""Synthetic data with *calibrated expert-routing skewness*.

The paper measures datasets (MMLU skew=1.39, Alpaca=1.40, SST2=1.99) on
Mixtral and studies how skewness affects (a) Distribution-Only estimation
error and (b) Token-to-Expert predictor accuracy/overhead. Offline we
reproduce those studies with generated corpora whose routing statistics we
control exactly:

* token ids follow a Zipf distribution (like natural text);
* each MoE layer has a ground-truth routing rule: with probability
  ``predictability`` a token's expert is a deterministic function of
  (token id, layer) — the part a Token-to-Expert predictor can learn —
  otherwise it is drawn from a base distribution with the target skewness
  (the irreducible part);
* the base distribution is constructed so that max/mean == ``skew``.

This gives datasets where BOTH paper knobs (skewness, achievable
prediction accuracy) are dials instead of accidents of a dataset.
"""

from __future__ import annotations

from typing import Iterator, NamedTuple

import numpy as np


def skewed_distribution(num_experts: int, skew: float,
                        rng: np.random.Generator | None = None) -> np.ndarray:
    """Expert distribution p with max(p)/mean(p) == skew (1 <= skew <= E).

    The hottest expert takes skew/E; the tail decays geometrically (more
    realistic than uniform-tail) subject to the max constraint.
    """
    E = num_experts
    skew = float(np.clip(skew, 1.0, E))
    p_max = skew / E
    rest = 1.0 - p_max
    if E == 1:
        return np.ones((1,))
    # geometric tail: q_i = r^i, scaled to sum to `rest`, with q_0 <= p_max
    lo, hi = 1e-6, 1.0
    for _ in range(60):
        r = 0.5 * (lo + hi)
        q = r ** np.arange(E - 1, dtype=np.float64)
        q = q / q.sum() * rest
        if q[0] > p_max:
            lo = r
        else:
            hi = r
    p = np.concatenate([[p_max], q])
    if rng is not None:
        p[1:] = rng.permutation(p[1:])
    return p / p.sum()


def measured_skewness(counts: np.ndarray) -> float:
    p = counts / max(counts.sum(), 1e-12)
    return float(p.max() * p.shape[-1])


class RoutingTrace(NamedTuple):
    """A routing dataset: tokens + per-layer ground-truth expert labels."""
    tokens: np.ndarray        # (N, S) int32
    experts: np.ndarray       # (L, N, S) int32  top-1 expert per token per layer
    dist: np.ndarray          # (L, E) ground-truth marginal expert distribution
    skew: float
    predictability: float


def make_routing_trace(
    *,
    num_sequences: int,
    seq_len: int,
    vocab: int,
    num_experts: int,
    num_layers: int,
    skew: float = 1.4,
    predictability: float = 0.8,
    zipf_alpha: float = 1.2,
    drift: float = 0.0,
    seed: int = 0,
) -> RoutingTrace:
    """``drift``: the paper's core premise is that expert distributions
    CHANGE OVER TIME (hence *dynamic* duplication). drift > 0 applies a
    progressive exponent tilt base^(1 + drift * i/N) over sequence index i,
    so a train/test split sees a systematic distribution shift (what
    Table 1 measures on real datasets — skewed datasets drift more)."""
    rng = np.random.default_rng(seed)
    # Zipf token stream
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    pz = ranks ** (-zipf_alpha)
    pz /= pz.sum()
    tokens = rng.choice(vocab, size=(num_sequences, seq_len), p=pz).astype(np.int32)

    base = np.stack([skewed_distribution(num_experts, skew, rng)
                     for _ in range(num_layers)])
    # deterministic token->expert rule per layer, biased by the base dist so
    # the marginal stays skewed even for the predictable part
    rule = np.stack([rng.choice(num_experts, size=vocab, p=base[l])
                     for l in range(num_layers)]).astype(np.int32)

    experts = np.empty((num_layers, num_sequences, seq_len), np.int32)
    for l in range(num_layers):
        det = rule[l][tokens]                                   # (N, S)
        if drift > 0:
            rnd = np.empty_like(tokens)
            for i in range(num_sequences):
                p_i = base[l] ** (1.0 + drift * i / max(num_sequences - 1, 1))
                p_i = p_i / p_i.sum()
                rnd[i] = rng.choice(num_experts, size=(seq_len,), p=p_i)
        else:
            rnd = rng.choice(num_experts, size=tokens.shape,
                             p=base[l]).astype(np.int32)
        use_det = rng.random(tokens.shape) < predictability
        experts[l] = np.where(use_det, det, rnd.astype(np.int32))

    # empirical marginal
    dist = np.stack([
        np.bincount(experts[l].reshape(-1), minlength=num_experts).astype(np.float64)
        for l in range(num_layers)])
    dist /= dist.sum(axis=1, keepdims=True)
    return RoutingTrace(tokens=tokens, experts=experts, dist=dist,
                        skew=skew, predictability=predictability)


def token_batches(key_seed: int, vocab: int, batch: int, seq_len: int,
                  zipf_alpha: float = 1.2) -> Iterator[dict]:
    """Infinite LM training batches (tokens + next-token labels)."""
    rng = np.random.default_rng(key_seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    pz = ranks ** (-zipf_alpha)
    pz /= pz.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq_len + 1), p=pz).astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
