from repro.data.synthetic import (RoutingTrace, make_routing_trace,
                                  skewed_distribution, token_batches)

__all__ = ["RoutingTrace", "make_routing_trace", "skewed_distribution",
           "token_batches"]
