"""Cross-model resource arbiter: moves quota toward SLO pressure.

Each evaluation window the arbiter scores every resident model with a
scalar **pressure** built from the three signals the serving stack
already measures:

  * SLO attainment shortfall — worst-tenant attainment (from the model's
    ``ServeMetrics`` timings judged per tenant class) below the target;
  * queue depth — eligible-but-unadmitted requests
    (``ContinuousScheduler.queue_depth``), the backpressure a starved
    slot/KV share produces;
  * window skew — the last closed metrics window's expert skew, which is
    what makes extra ``dup_slots`` worth having at all.

It then proposes moving quota from the lowest-pressure model to the
highest-pressure one, with two brakes:

  **Hysteresis** — the same (hot, cold) pair must win ``patience``
  consecutive windows before anything moves, so one bursty window
  cannot thrash capacity (mirrors `serve.controller`'s vote gate).

  **Cost gate** — a dup-slot grant makes the hot model's next re-plan
  migrate weights in (one slot entry per layer); the modeled stall must
  pass `runtime.cost.should_migrate` against the pressure gap expressed
  as step-seconds at stake over the coming window. KV-quota moves are
  ledger-only (no bytes move; handback is deferred via the allocator),
  so they carry no gate.

Dup-slot SHRINK on the cold model is free: its next re-plan strands the
vacated slots with zero transfer (`runtime.diff.vacated_slots`).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.simulator import A100_PCIE, HardwareConfig
from repro.fleet.budget import FleetBudget
from repro.runtime.cost import migration_stall_s, should_migrate


@dataclass
class ArbiterConfig:
    window_iters: int = 8          # fleet iterations per evaluation
    patience: int = 2              # consecutive windows before a move
    pressure_gap: float = 0.25     # min hot-cold gap to even vote
    attainment_target: float = 0.95
    queue_norm: float = 8.0        # queue depth saturating the queue term
    skew_weight: float = 0.25      # weight of the (capped) skew term
    dup_slots_per_move: int = 1
    kv_blocks_per_move: int = 4
    kv_floor_blocks: int = 4       # donor keeps at least this much KV
    max_moves: int = 0             # 0 = unlimited
    hardware: HardwareConfig = A100_PCIE


@dataclass
class ModelSignals:
    """One model's window inputs to the pressure score."""
    slo_attainment: float
    queue_depth: int
    window_skew: float
    step_s: float = 0.0            # recent per-step seconds (engine EMA)
    dup_entry_bytes: int = 0       # bytes one dup-slot grant migrates


@dataclass
class ArbiterMove:
    """One committed reallocation, with the inputs that justified it."""
    seq: int
    t: float
    src: str                       # cold model (quota shrinks)
    dst: str                       # hot model (quota grows)
    dup_slots: int
    kv_blocks: int
    pressure_src: float
    pressure_dst: float
    stall_s: float = 0.0           # modeled dup-grant migration stall
    gain_s: float = 0.0            # step-seconds at stake that paid it

    def explain(self) -> str:
        return (f"[{self.seq}] t={self.t:8.2f}s {self.src}->{self.dst} "
                f"dup+{self.dup_slots} kv+{self.kv_blocks} "
                f"pressure {self.pressure_src:.2f}->{self.pressure_dst:.2f} "
                f"stall={self.stall_s * 1e3:.2f}ms "
                f"gain={self.gain_s * 1e3:.2f}ms")


class FleetArbiter:
    """Windowed quota reallocation over a `FleetBudget`."""

    def __init__(self, cfg: Optional[ArbiterConfig], budget: FleetBudget):
        self.cfg = cfg if cfg is not None else ArbiterConfig()
        self.budget = budget
        self.moves: List[ArbiterMove] = []
        self.evaluations = 0
        self._pending: Optional[Tuple[str, str]] = None
        self._votes = 0
        self.last_pressure: Dict[str, float] = {}

    # -------------------------------------------------------------- pressure
    def pressure(self, s: ModelSignals) -> float:
        c = self.cfg
        slo_term = max(0.0, c.attainment_target - s.slo_attainment) \
            / max(c.attainment_target, 1e-9)
        queue_term = min(s.queue_depth / max(c.queue_norm, 1e-9), 1.0)
        # skew is max-share x E in [1, E]; cap the term at skew 2.0 so a
        # pathological histogram cannot drown the SLO/queue signals
        skew_term = min(max(s.window_skew - 1.0, 0.0), 1.0)
        return slo_term + queue_term + c.skew_weight * skew_term

    # --------------------------------------------------------------- observe
    def observe(self, t: float,
                signals: Dict[str, ModelSignals]) -> List[ArbiterMove]:
        """Score one closed window; returns the moves committed (possibly
        empty). The CALLER applies the returned moves to the engines
        (dup-slot quota + allocator quota) — the arbiter only mutates
        the ledger."""
        self.evaluations += 1
        c = self.cfg
        self.last_pressure = {n: self.pressure(s)
                              for n, s in signals.items()}
        if len(signals) < 2:
            return []
        hot = max(self.last_pressure, key=self.last_pressure.get)
        cold = min(self.last_pressure, key=self.last_pressure.get)
        gap = self.last_pressure[hot] - self.last_pressure[cold]
        if hot == cold or gap < c.pressure_gap:
            self._pending, self._votes = None, 0
            return []
        if self._pending != (hot, cold):
            self._pending, self._votes = (hot, cold), 1
        else:
            self._votes += 1
        if self._votes < c.patience:
            return []
        if c.max_moves and len(self.moves) >= c.max_moves:
            return []

        dup = 0
        stall_s = gain_s = 0.0
        want_dup = c.dup_slots_per_move
        if want_dup > 0 and self.budget.can_transfer(cold, hot,
                                                     dup_slots=want_dup):
            # the grant is worth taking iff the migration it triggers is
            # cheaper than the pressure gap expressed as hot-model step
            # time over the next window
            nbytes = signals[hot].dup_entry_bytes * want_dup
            stall_s = migration_stall_s(nbytes, c.hardware)
            gain_s = gap * signals[hot].step_s * c.window_iters
            if should_migrate(stall_s, gain_s):
                dup = want_dup
            else:
                stall_s = gain_s = 0.0
        kv = 0
        want_kv = c.kv_blocks_per_move
        cold_kv = self.budget.shares[cold].kv_block_quota
        if want_kv > 0 and cold_kv - want_kv >= c.kv_floor_blocks \
                and self.budget.can_transfer(cold, hot, kv_blocks=want_kv):
            kv = want_kv
        if dup == 0 and kv == 0:
            return []
        self.budget.transfer(cold, hot, dup_slots=dup, kv_blocks=kv)
        move = ArbiterMove(seq=len(self.moves), t=t, src=cold, dst=hot,
                           dup_slots=dup, kv_blocks=kv,
                           pressure_src=self.last_pressure[cold],
                           pressure_dst=self.last_pressure[hot],
                           stall_s=stall_s, gain_s=gain_s)
        self.moves.append(move)
        self._pending, self._votes = None, 0
        return [move]

    def explain(self) -> str:
        return "\n".join(m.explain() for m in self.moves)
