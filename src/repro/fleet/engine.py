"""FleetEngine: N ContinuousEngine-backed models on one device mesh.

One fleet = one HBM budget, carved by a `FleetBudget` ledger into
per-model shares of (weights + replica-store dup slots + paged KV
blocks), with a `FleetArbiter` moving dup-slot and KV-block quota
between models as per-tenant SLO attainment, queue depth, and window
skew shift. Every model instance keeps its own `OnlineGPSController`,
`ServeMetrics` (labeled series in a SHARED `MetricsRegistry`),
`SpanTracer` (merged per-process via `obs.trace.merge_traces`), and
`GPSAuditLog` — the paper's per-model GPS loop runs unchanged inside a
fleet that reallocates capacity above it.

Zero post-warmup recompiles hold fleet-wide: every arbiter move is a
LOGICAL quota change inside shapes the engines compiled at warmup
(`ContinuousEngine.set_dup_slot_quota`, `BlockAllocator.set_quota`).
The engines time-share the mesh: one fleet ``step()`` steps every
runnable engine once on a common virtual clock, which is what a
single-mesh multi-model deployment actually does.
"""

from __future__ import annotations

import dataclasses
import time as _time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.fleet.admission import FleetAdmission
from repro.fleet.arbiter import (ArbiterConfig, ArbiterMove, FleetArbiter,
                                 ModelSignals)
from repro.fleet.budget import (FleetBudget, ModelShare, kv_block_bytes,
                                params_bytes)
from repro.obs.audit import GPSAuditLog
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import SpanTracer, merge_traces
from repro.serve.engine import ContinuousConfig, ContinuousEngine, StepEvents
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import ServeRequest


@dataclass
class FleetModelSpec:
    """One resident model: config + params + its serving configuration.

    ``dup_slot_quota`` / ``kv_block_quota`` set the model's INITIAL
    active quota below its compiled ceiling (-1 = full) — how a static
    split carves the fleet, and the starting point the arbiter moves
    capacity from.
    """
    name: str
    cfg: ModelConfig
    params: Any
    ccfg: ContinuousConfig
    predictor: Any = None
    controller: Any = None       # OnlineGPSController (audit log attached)
    dup_slot_quota: int = -1
    kv_block_quota: int = -1


class FleetEngine:
    """Host N model instances against one budget, arbitrate between them.

    ``hbm_budget_bytes``: per-rank budget the ledger clamps/arbitrates
    within (0 = unlimited — ledger still tracks, never constrains).
    ``enable_arbiter=False`` freezes the post-clamp static split (the
    A/B baseline leg).
    """

    def __init__(self, specs: List[FleetModelSpec], *, mesh=None,
                 ep_ranks: int = 1, hbm_budget_bytes: float = 0.0,
                 admission: Optional[FleetAdmission] = None,
                 arbiter_cfg: Optional[ArbiterConfig] = None,
                 enable_arbiter: bool = True,
                 registry: Optional[MetricsRegistry] = None,
                 trace: bool = False):
        if not specs:
            raise ValueError("a fleet needs at least one model")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate model names: {names}")
        self.mesh = mesh
        self.ep_ranks = ep_ranks
        self.registry = registry if registry is not None else MetricsRegistry()
        self.admission = admission if admission is not None else \
            FleetAdmission(routes={}, default_model=specs[0].name)
        self.budget = FleetBudget(hbm_budget_bytes)

        # ----- ledger rows BEFORE engine construction: the global clamp
        # decides the dup_slots each engine COMPILES with
        for s in specs:
            cfg, ccfg = s.cfg, s.ccfg
            entry = 0
            if cfg.is_moe:
                from repro.runtime.cost import entry_bytes as _eb
                entry = _eb(s.params["layers"]["moe"]["experts"])
            self.budget.register(ModelShare(
                name=s.name,
                weights_bytes=params_bytes(s.params) // max(ep_ranks, 1),
                entry_bytes=entry,
                num_layers=cfg.num_layers,
                num_experts=cfg.moe.num_experts if cfg.is_moe else 0,
                ep_ranks=ep_ranks,
                dup_slots=ccfg.dup_slots if cfg.is_moe else 0,
                kv_blocks=ccfg.num_blocks - 1,
                kv_block_bytes=kv_block_bytes(
                    cfg.num_layers, ccfg.block_size, cfg.num_kv_heads,
                    cfg.head_dim),
                dup_slot_quota=s.dup_slot_quota if cfg.is_moe else 0,
                kv_block_quota=s.kv_block_quota))
        clamped = self.budget.clamp()

        self.engines: Dict[str, ContinuousEngine] = {}
        self.tracers: Dict[str, SpanTracer] = {}
        for i, s in enumerate(specs):
            share = self.budget.shares[s.name]
            ccfg = s.ccfg
            if s.cfg.is_moe and clamped[s.name] != ccfg.dup_slots:
                ccfg = dataclasses.replace(ccfg, dup_slots=clamped[s.name])
            slo = self.admission.strictest_slo(s.name)
            metrics = ServeMetrics(
                window_iters=ccfg.metrics_window, slo_ttft=slo.slo_ttft,
                slo_tpot=slo.slo_tpot, registry=self.registry, model=s.name)
            tracer = SpanTracer(process_name=s.name, pid=i + 1,
                                enabled=trace)
            eng = ContinuousEngine(
                s.cfg, s.params, ccfg, mesh=mesh, ep_ranks=ep_ranks,
                predictor=s.predictor, controller=s.controller,
                tracer=tracer, metrics=metrics, model=s.name)
            # the engine may have clamped its own dup_slots further
            # (store budget) — keep the ledger honest about the ceiling
            if eng.moe_cfg is not None:
                share.dup_slots = eng.moe_cfg.duplication_slots
                share.dup_slot_quota = min(share.dup_slot_quota,
                                           share.dup_slots)
            eng.set_dup_slot_quota(share.dup_slot_quota)
            eng.allocator.set_quota(share.kv_block_quota)
            self.engines[s.name] = eng
            self.tracers[s.name] = tracer

        self.arbiter = FleetArbiter(arbiter_cfg, self.budget) \
            if enable_arbiter else None
        self._acfg = arbiter_cfg if arbiter_cfg is not None \
            else ArbiterConfig()
        self.iterations = 0
        self._step_walls: List[float] = []
        # per-engine WALL step-time EMA: the engines' own _recent_step_s
        # tracks the virtual clock (zero under a frozen clock), but the
        # arbiter's cost gate weighs migration stall against real seconds
        self._eng_step_s: Dict[str, float] = {n: 0.0 for n in self.engines}
        self._warm = False

    # ---------------------------------------------------------------- warmup
    def warmup(self):
        """Warm every engine, then re-baseline each one's compile counts:
        under a mesh the compile counter is process-wide, so engine A's
        baseline taken before engine B warms up would blame B's warmup
        compiles on A's serving."""
        for eng in self.engines.values():
            eng.warmup()
        for eng in self.engines.values():
            eng._compile_baseline = eng.compile_counts()
        self._warm = True

    def compile_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for name, eng in self.engines.items():
            for k, v in eng.compile_counts().items():
                out[f"{name}.{k}"] = v
        return out

    def assert_no_recompiles(self):
        assert self._warm, "call warmup() first"
        for eng in self.engines.values():
            eng.assert_no_recompiles()

    # ------------------------------------------------------------ submission
    def submit(self, req: ServeRequest) -> str:
        model = self.admission.route(req.tenant)
        self.engines[model].submit(req)
        return model

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines.values())

    def _runnable(self, eng: ContinuousEngine, now: float) -> bool:
        return bool(eng.scheduler.active_slots) or any(
            r.arrival <= now for r in eng.scheduler.waiting)

    def next_arrival(self) -> Optional[float]:
        arrivals = [r.arrival for e in self.engines.values()
                    for r in e.scheduler.waiting]
        return min(arrivals) if arrivals else None

    # ------------------------------------------------------------------ step
    def step(self, now: float, clock=None) -> Dict[str, StepEvents]:
        """One fleet iteration: step every runnable engine once, then (at
        window boundaries) evaluate the arbiter and apply its moves."""
        t0 = _time.perf_counter()
        events: Dict[str, StepEvents] = {}
        for name, eng in self.engines.items():
            if self._runnable(eng, now):
                t1 = _time.perf_counter()
                events[name] = eng.step(now, clock=clock)
                d = _time.perf_counter() - t1
                prev = self._eng_step_s[name]
                self._eng_step_s[name] = d if prev <= 0 \
                    else 0.9 * prev + 0.1 * d
        self.iterations += 1
        self._step_walls.append(_time.perf_counter() - t0)
        if self.arbiter is not None \
                and self.iterations % self._acfg.window_iters == 0:
            self._arbitrate(now)
        return events

    def _signals(self, now: float) -> Dict[str, ModelSignals]:
        out = {}
        for name, eng in self.engines.items():
            share = self.budget.shares[name]
            skew = eng.metrics.windows[-1].skew if eng.metrics.windows \
                else 0.0
            out[name] = ModelSignals(
                slo_attainment=self.admission.model_attainment(
                    eng.metrics, name),
                queue_depth=eng.scheduler.queue_depth(now),
                window_skew=skew,
                step_s=self._eng_step_s[name] or eng._recent_step_s,
                dup_entry_bytes=share.dup_slot_entry_bytes)
        return out

    def _arbitrate(self, now: float) -> List[ArbiterMove]:
        moves = self.arbiter.observe(now, self._signals(now))
        for mv in moves:
            if mv.dup_slots:
                src, dst = self.engines[mv.src], self.engines[mv.dst]
                src.set_dup_slot_quota(
                    self.budget.shares[mv.src].dup_slot_quota)
                dst.set_dup_slot_quota(
                    self.budget.shares[mv.dst].dup_slot_quota)
            if mv.kv_blocks:
                self.engines[mv.src].allocator.set_quota(
                    self.budget.shares[mv.src].kv_block_quota)
                self.engines[mv.dst].allocator.set_quota(
                    self.budget.shares[mv.dst].kv_block_quota)
            self.tracers[mv.dst].instant(
                "fleet.arbiter_move", cat="fleet",
                args={"src": mv.src, "dst": mv.dst,
                      "dup_slots": mv.dup_slots, "kv_blocks": mv.kv_blocks})
        for name, p in (self.arbiter.last_pressure or {}).items():
            self.registry.gauge("fleet_pressure",
                                "Arbiter pressure score per model",
                                model=name).set(p)
        if moves:
            self.registry.counter(
                "fleet_arbiter_moves_total",
                "Committed cross-model quota moves").inc(len(moves))
        return moves

    # ------------------------------------------------------------ trace run
    def run_trace(self, requests: List[ServeRequest], *, max_iters: int = 0,
                  time_scale: float = 1.0) -> float:
        """Replay one trace across the fleet on a shared virtual clock
        (`ContinuousEngine.run_trace` semantics: iterations cost measured
        wall x ``time_scale``, fleet-wide idle gaps fast-forward)."""
        for r in sorted(requests, key=lambda r: r.arrival):
            self.submit(r)
        now = 0.0
        iters = 0
        while self.has_work():
            if not any(self._runnable(e, now)
                       for e in self.engines.values()):
                nxt = self.next_arrival()
                if nxt is None:
                    break
                now = max(now, nxt)
            t0 = _time.perf_counter()
            start = now
            self.step(start, clock=lambda: start + (
                _time.perf_counter() - t0) * time_scale)
            now = start + (_time.perf_counter() - t0) * time_scale
            iters += 1
            if max_iters and iters >= max_iters:
                break
        for eng in self.engines.values():
            eng.metrics.flush(
                eng._plan_stack, eng.ep_ranks,
                eng.moe_cfg.duplication_slots if eng.moe_cfg else 0)
        return now

    # --------------------------------------------------------------- summary
    def summary(self) -> Dict[str, float]:
        """Fleet-level columns + per-model ledger rows. Per-tenant SLO
        attainment is judged against each tenant's class and weighted by
        completions, so one starved hot tenant shows up even when a cold
        model's easy traffic all meets its SLO."""
        good = total = 0
        worst = 1.0
        for name, eng in self.engines.items():
            for tenant in (self.admission.tenants_for(name)
                           or [""]):
                slo = self.admission.slo_for(tenant)
                ts = [t for t in eng.metrics.timings
                      if not tenant or t.tenant == tenant]
                ok = sum(1 for t in ts if t.ttft <= slo.slo_ttft
                         and t.tpot <= slo.slo_tpot)
                good += ok
                total += len(ts)
                if ts:
                    worst = min(worst, ok / len(ts))
        attainment = good / total if total else 1.0
        walls = np.asarray(self._step_walls or [0.0], np.float64)
        out = {
            "fleet_models": float(len(self.engines)),
            "fleet_iterations": float(self.iterations),
            "fleet_completed": float(total),
            "fleet_slo_attainment": attainment,
            "fleet_slo_attainment_worst": worst,
            "fleet_arbiter_moves": float(len(self.arbiter.moves)
                                         if self.arbiter else 0),
            "fleet_step_p50_ms": float(np.percentile(walls, 50) * 1e3),
            "fleet_step_p99_ms": float(np.percentile(walls, 99) * 1e3),
            **self.budget.summary(),
        }
        for k, v in out.items():
            if isinstance(v, float):
                self.registry.gauge(f"fleet_{k}" if not k.startswith("fleet_")
                                    else k,
                                    f"Fleet summary column {k}").set(v)
        return out

    def merged_trace(self) -> Dict[str, Any]:
        """One Chrome trace document, one process row per model, plus
        each model's GPS audit log in ``otherData``."""
        docs, names = [], []
        for name, tracer in self.tracers.items():
            doc = tracer.to_chrome()
            ctrl = self.engines[name].controller
            audit = getattr(ctrl, "audit", None) if ctrl else None
            if isinstance(audit, GPSAuditLog):
                doc["otherData"]["gps_audit"] = audit.to_obj()
            docs.append(doc)
            names.append(name)
        return merge_traces(docs, names)
