"""Multi-tenant fleet serving: N models, one HBM budget, one arbiter.

  ``FleetBudget``    — per-rank byte ledger over (weights + replica-store
                       dup slots + paged KV blocks), with the global
                       clamp `core.placement.clamp_dup_slots` only ever
                       applied per model in isolation.
  ``FleetAdmission`` — tenant -> model routing + per-tenant SLO classes.
  ``FleetArbiter``   — windowed quota reallocation (hysteresis + the
                       `runtime.cost.should_migrate` cost gate).
  ``FleetEngine``    — N `ContinuousEngine` instances time-sharing one
                       mesh, each with its own online GPS loop; all
                       arbiter moves are logical quotas inside compiled
                       shapes, so zero post-warmup recompiles hold
                       fleet-wide.
"""

from repro.fleet.admission import (BATCH, INTERACTIVE, FleetAdmission,
                                   SLOClass)
from repro.fleet.arbiter import (ArbiterConfig, ArbiterMove, FleetArbiter,
                                 ModelSignals)
from repro.fleet.budget import (FleetBudget, ModelShare, kv_block_bytes,
                                params_bytes)
from repro.fleet.engine import FleetEngine, FleetModelSpec

__all__ = [
    "ArbiterConfig", "ArbiterMove", "BATCH", "FleetAdmission", "FleetArbiter",
    "FleetBudget", "FleetEngine", "FleetModelSpec", "INTERACTIVE",
    "ModelShare", "ModelSignals", "SLOClass", "kv_block_bytes",
    "params_bytes",
]
