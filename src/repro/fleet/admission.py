"""Per-tenant admission: SLO classes and tenant -> model routing.

The multi-tenant traces in `workloads.traces` stamp every request with a
tenant name; the fleet maps each tenant to one resident model instance
and one SLO class. The admission table is also where the arbiter reads
its primary signal: per-tenant SLO attainment, judged against the
TENANT's class (not the engine's default), from the per-model
`ServeMetrics` completion timings.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.serve.metrics import ServeMetrics


@dataclass(frozen=True)
class SLOClass:
    """A named latency contract: TTFT / TPOT ceilings in (virtual)
    seconds. ``inf`` disables a bound."""
    name: str
    slo_ttft: float = float("inf")
    slo_tpot: float = float("inf")


#: Two conventional classes: latency-sensitive chat traffic vs
#: throughput-oriented batch jobs that only bound per-token pace.
INTERACTIVE = SLOClass("interactive", slo_ttft=2.0, slo_tpot=0.25)
BATCH = SLOClass("batch", slo_tpot=1.0)


class FleetAdmission:
    """Routes requests to models and scores tenants against their SLOs.

    ``routes``: tenant name -> model name. ``slos``: tenant name ->
    SLOClass (missing tenants get ``default_slo``). Unknown tenants go to
    ``default_model`` when set, otherwise submission raises — a fleet
    serving paying tenants should not silently absorb unknown traffic.
    """

    def __init__(self, routes: Dict[str, str],
                 slos: Optional[Dict[str, SLOClass]] = None,
                 default_model: str = "",
                 default_slo: SLOClass = BATCH):
        self.routes = dict(routes)
        self.slos = dict(slos or {})
        self.default_model = default_model
        self.default_slo = default_slo

    def route(self, tenant: str) -> str:
        model = self.routes.get(tenant, self.default_model)
        if not model:
            raise KeyError(f"no model routed for tenant {tenant!r} and no "
                           "default_model configured")
        return model

    def slo_for(self, tenant: str) -> SLOClass:
        return self.slos.get(tenant, self.default_slo)

    def tenants_for(self, model: str) -> List[str]:
        return [t for t, m in self.routes.items() if m == model]

    # ----------------------------------------------------------- attainment
    def tenant_attainment(self, metrics: ServeMetrics, tenant: str) -> float:
        slo = self.slo_for(tenant)
        return metrics.slo_attainment(tenant=tenant, slo_ttft=slo.slo_ttft,
                                      slo_tpot=slo.slo_tpot)

    def model_attainment(self, metrics: ServeMetrics, model: str) -> float:
        """Worst tenant attainment on this model (1.0 with no routed
        tenants / no completions): the arbiter protects the worst-off
        tenant, not the average."""
        tenants = self.tenants_for(model)
        if not tenants:
            return 1.0
        return min(self.tenant_attainment(metrics, t) for t in tenants)

    def strictest_slo(self, model: str) -> SLOClass:
        """Tightest per-bound contract across a model's tenants — what
        the engine-level ``ServeMetrics`` goodput should judge against."""
        tenants = self.tenants_for(model)
        if not tenants:
            return self.default_slo
        classes = [self.slo_for(t) for t in tenants]
        return SLOClass(name=f"{model}-strictest",
                        slo_ttft=min(c.slo_ttft for c in classes),
                        slo_tpot=min(c.slo_tpot for c in classes))
