"""Fleet HBM budget ledger: one device-memory budget, N resident models.

Every co-resident model instance costs three kinds of bytes per EP rank:

  weights   — its sharded parameters (fixed while the model is resident);
  store     — the persistent replica store, ``L x (E_loc + dup_slots)``
              slot entries (`core.placement.store_bytes_per_rank`);
  KV        — its paged KV block pool, ``kv_blocks x kv_block_bytes``.

The ledger distinguishes **provisioned** bytes (what the compiled array
shapes pin down: full ``dup_slots`` store + full physical pool) from
**active** bytes (what the current *quotas* let the model actually use).
Compiled shapes never change at runtime — that is the serving stack's
zero-recompile guarantee — so the fleet arbiter moves capacity between
models purely as quota: a model's ``dup_slot_quota`` caps how many
replica slots its planner fills, its ``kv_block_quota`` caps how many
pool blocks its allocator hands out. ``clamp()`` is the fleet
generalization of `core.placement.clamp_dup_slots`: instead of each
model clamping against a private budget in isolation, the JOINT
provisioned footprint is shrunk (largest store first, then KV quotas)
until the fleet fits.

A quota transfer is instantaneous in the ledger; the physical handback
is deferred (a shrunk KV quota refuses growth until blocks drain back,
a shrunk dup-slot quota strands replica slots at the next re-plan with
zero transfer — see `runtime.diff.vacated_slots`). The transient where
the shrinking model still occupies bytes the growing model was just
granted is bounded by the shrinking model's drain rate, exactly like
memory ballooning between co-resident VMs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.core.placement import store_bytes_per_rank


def params_bytes(params) -> int:
    """Total bytes of a parameter pytree (host- or device-resident)."""
    import jax
    leaves = jax.tree_util.tree_leaves(params)
    return int(sum(np.asarray(a).size * np.asarray(a).dtype.itemsize
                   for a in leaves))


def kv_block_bytes(num_layers: int, block_size: int, num_kv_heads: int,
                   head_dim: int, dtype_bytes: int = 2) -> int:
    """Bytes one pool block pins across the layer stack (K and V)."""
    return int(num_layers) * int(block_size) * int(num_kv_heads) \
        * int(head_dim) * int(dtype_bytes) * 2


@dataclass
class ModelShare:
    """One resident model's row in the ledger (per EP rank)."""
    name: str
    weights_bytes: int
    entry_bytes: int            # one expert slot entry, per layer
    num_layers: int
    num_experts: int
    ep_ranks: int
    dup_slots: int              # compiled replica slots (physical ceiling)
    kv_blocks: int              # physical pool blocks (excl. null block)
    kv_block_bytes: int
    dup_slot_quota: int = -1    # -1 -> full dup_slots
    kv_block_quota: int = -1    # -1 -> full kv_blocks

    def __post_init__(self):
        if self.dup_slot_quota < 0:
            self.dup_slot_quota = self.dup_slots
        if self.kv_block_quota < 0:
            self.kv_block_quota = self.kv_blocks
        self.dup_slot_quota = min(self.dup_slot_quota, self.dup_slots)
        self.kv_block_quota = min(self.kv_block_quota, self.kv_blocks)

    def store_bytes(self, dup: int) -> int:
        if self.entry_bytes <= 0 or self.num_experts <= 0:
            return 0
        return store_bytes_per_rank(
            self.num_experts, self.ep_ranks, dup,
            entry_bytes=self.entry_bytes, num_layers=self.num_layers)

    @property
    def provisioned_bytes(self) -> int:
        return (self.weights_bytes + self.store_bytes(self.dup_slots)
                + self.kv_blocks * self.kv_block_bytes)

    @property
    def active_bytes(self) -> int:
        return (self.weights_bytes + self.store_bytes(self.dup_slot_quota)
                + self.kv_block_quota * self.kv_block_bytes)

    @property
    def dup_slot_entry_bytes(self) -> int:
        """Bytes one replica-slot quota unit moves: a slot per layer."""
        return self.num_layers * self.entry_bytes


class FleetBudget:
    """Per-rank HBM ledger over every registered model share."""

    def __init__(self, total_bytes: float = 0.0):
        self.total_bytes = float(total_bytes)   # 0 = unlimited
        self.shares: Dict[str, ModelShare] = {}

    def register(self, share: ModelShare) -> ModelShare:
        if share.name in self.shares:
            raise ValueError(f"model {share.name!r} already registered")
        self.shares[share.name] = share
        return share

    def provisioned_bytes(self) -> int:
        return sum(s.provisioned_bytes for s in self.shares.values())

    def active_bytes(self) -> int:
        return sum(s.active_bytes for s in self.shares.values())

    # ------------------------------------------------------------- build time
    def clamp(self) -> Dict[str, int]:
        """Shrink the fleet until its PROVISIONED footprint fits the
        budget: first replica slots (largest store loses a slot per
        round — the fleet form of ``clamp_dup_slots``), then KV quotas
        (proportionally, leaving the physical pools compiled as-is but
        capping what each model may use). Returns the final dup_slots
        per model. Raises if weights + homes + one-block pools alone
        exceed the budget — no quota can fix over-subscribed residency.
        """
        if self.total_bytes <= 0:
            return {n: s.dup_slots for n, s in self.shares.items()}
        while self.provisioned_bytes() > self.total_bytes:
            candidates = [s for s in self.shares.values() if s.dup_slots > 0]
            if not candidates:
                break
            victim = max(candidates, key=lambda s: s.store_bytes(s.dup_slots))
            victim.dup_slots -= 1
            victim.dup_slot_quota = min(victim.dup_slot_quota,
                                        victim.dup_slots)
        over = self.provisioned_bytes() - self.total_bytes
        if over > 0:
            kv_total = sum(s.kv_blocks * s.kv_block_bytes
                           for s in self.shares.values())
            if kv_total <= 0 or over >= kv_total:
                raise ValueError(
                    f"fleet cannot fit: {self.provisioned_bytes() / 1e9:.2f} "
                    f"GB provisioned vs {self.total_bytes / 1e9:.2f} GB "
                    "budget even with zero replica slots")
            keep = 1.0 - over / kv_total
            for s in self.shares.values():
                s.kv_block_quota = max(1, int(s.kv_blocks * keep))
        return {n: s.dup_slots for n, s in self.shares.items()}

    # --------------------------------------------------------------- runtime
    def can_transfer(self, src: str, dst: str, *, dup_slots: int = 0,
                     kv_blocks: int = 0) -> bool:
        s, d = self.shares[src], self.shares[dst]
        if dup_slots > 0 and (s.dup_slot_quota < dup_slots
                              or d.dup_slot_quota + dup_slots > d.dup_slots):
            return False
        if kv_blocks > 0 and (s.kv_block_quota < kv_blocks
                              or d.kv_block_quota + kv_blocks > d.kv_blocks):
            return False
        if self.total_bytes > 0:
            delta = 0
            if dup_slots:
                delta += (d.store_bytes(d.dup_slot_quota + dup_slots)
                          - d.store_bytes(d.dup_slot_quota))
                delta -= (s.store_bytes(s.dup_slot_quota)
                          - s.store_bytes(s.dup_slot_quota - dup_slots))
            if kv_blocks:
                delta += kv_blocks * (d.kv_block_bytes - s.kv_block_bytes)
            if self.active_bytes() + delta > self.total_bytes:
                return False
        return True

    def transfer(self, src: str, dst: str, *, dup_slots: int = 0,
                 kv_blocks: int = 0) -> None:
        if not self.can_transfer(src, dst, dup_slots=dup_slots,
                                 kv_blocks=kv_blocks):
            raise ValueError(
                f"transfer {src}->{dst} (dup={dup_slots}, kv={kv_blocks}) "
                "violates quota bounds or the fleet budget")
        s, d = self.shares[src], self.shares[dst]
        s.dup_slot_quota -= dup_slots
        d.dup_slot_quota += dup_slots
        s.kv_block_quota -= kv_blocks
        d.kv_block_quota += kv_blocks

    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "budget_total_bytes": self.total_bytes,
            "budget_provisioned_bytes": float(self.provisioned_bytes()),
            "budget_active_bytes": float(self.active_bytes()),
        }
        for name, s in self.shares.items():
            out[f"{name}_weights_bytes"] = float(s.weights_bytes)
            out[f"{name}_store_bytes"] = float(s.store_bytes(s.dup_slot_quota))
            out[f"{name}_kv_bytes"] = float(s.kv_block_quota
                                            * s.kv_block_bytes)
            out[f"{name}_dup_slot_quota"] = float(s.dup_slot_quota)
            out[f"{name}_kv_block_quota"] = float(s.kv_block_quota)
        return out
