"""Algorithm 1 from MoE-GPS: greedy expert duplication for load balance.

Given a token->expert map (or just a predicted expert *distribution* — the
Distribution-Only strategy needs nothing more), iteratively copy the
hottest expert from the most-loaded rank to the least-loaded rank, moving
half the load gap, until ranks are balanced or constraints bind
(max copies per expert C_max, per-rank replica-slot memory M, one pool
contribution per source rank — see `repro.core.placement`).

Two implementations:

* ``duplicate_experts_host`` — numpy, host-side, used by the serving loop
  at every prediction interval (placement is a host decision in real
  deployments: it changes collective *contents*, not shapes).
* ``balanced_loads`` / ``bottleneck_load`` — analytical helpers used by the
  simulator (`repro.core.simulator`) to score a plan.

There is also a jittable fixed-iteration variant ``duplicate_experts_jax``
for fully in-graph planning (used by the in-graph serve step so the whole
predict->plan->dispatch pipeline lowers into one XLA program).
"""

from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementPlan, plan_from_assignments, plan_dims


class DuplicationResult(NamedTuple):
    plan: PlacementPlan
    rank_loads: np.ndarray          # fraction of tokens per rank after balancing
    assignments: List[Tuple[int, int]]


def _rank_loads(dist: np.ndarray, ep_ranks: int, n_rep: np.ndarray,
                copy_ranks: List[List[int]]) -> np.ndarray:
    """Per-rank load fraction given per-expert distribution and replica sets.

    Tokens of expert e are split evenly (round-robin dispatch) across its
    replicas, so each hosting rank carries dist[e] / n_rep[e].
    """
    loads = np.zeros((ep_ranks,), np.float64)
    for e, ranks in enumerate(copy_ranks):
        share = dist[e] / len(ranks)
        for r in ranks:
            loads[r] += share
    return loads


def duplicate_experts_host(
    dist: Sequence[float],
    ep_ranks: int,
    dup_slots: int,
    max_copies: int = 4,
    max_iters: int = 64,
    tol: float = 1e-3,
) -> DuplicationResult:
    """Algorithm 1, host-side. ``dist``: per-expert token fraction
    (predicted or observed), sums to 1."""
    dist = np.asarray(dist, np.float64)
    E = dist.shape[0]
    e_loc, n_slots = plan_dims(E, ep_ranks, dup_slots)

    copy_ranks: List[List[int]] = [[e // e_loc] for e in range(E)]
    n_rep = np.ones((E,), np.int64)
    rank_extra = np.zeros((ep_ranks,), np.int64)
    pool_expert = -np.ones((ep_ranks,), np.int64)     # one contribution per src
    assignments: List[Tuple[int, int]] = []

    for _ in range(max_iters):
        loads = _rank_loads(dist, ep_ranks, n_rep, copy_ranks)
        g_hot, g_cold = int(np.argmax(loads)), int(np.argmin(loads))
        if loads[g_hot] - loads[g_cold] <= tol:
            break
        # hottest per-replica load among experts hosted on g_hot
        cand, cand_share = -1, -1.0
        for e in range(E):
            if g_hot in copy_ranks[e]:
                share = dist[e] / n_rep[e]
                if share > cand_share:
                    cand, cand_share = e, share
        if cand < 0:
            break
        src = cand // e_loc
        feasible = (
            n_rep[cand] < max_copies
            and rank_extra[g_cold] < dup_slots
            and g_cold not in copy_ranks[cand]
            and (pool_expert[src] in (-1, cand))
        )
        if not feasible:
            # try the next-hottest feasible expert on g_hot
            order = sorted(
                (e for e in range(E) if g_hot in copy_ranks[e]),
                key=lambda e: dist[e] / n_rep[e], reverse=True)
            placed = False
            for e in order:
                src_e = e // e_loc
                if (n_rep[e] < max_copies and rank_extra[g_cold] < dup_slots
                        and g_cold not in copy_ranks[e]
                        and pool_expert[src_e] in (-1, e)):
                    cand, src = e, src_e
                    placed = True
                    break
            if not placed:
                break
        # accept only if the move improves the bottleneck (greedy with
        # lookahead — the even round-robin split can otherwise overload
        # the cold rank when E/R is small)
        trial_ranks = [list(r) for r in copy_ranks]
        trial_ranks[cand] = trial_ranks[cand] + [g_cold]
        trial_rep = n_rep.copy()
        trial_rep[cand] += 1
        trial_loads = _rank_loads(dist, ep_ranks, trial_rep, trial_ranks)
        if trial_loads.max() >= loads.max() - tol:
            break
        copy_ranks[cand].append(g_cold)
        n_rep[cand] += 1
        rank_extra[g_cold] += 1
        pool_expert[src] = cand
        assignments.append((int(cand), int(g_cold)))

    plan = plan_from_assignments(assignments, E, ep_ranks, dup_slots, max_copies)
    loads = _rank_loads(dist, ep_ranks, n_rep, copy_ranks)
    return DuplicationResult(plan=plan, rank_loads=loads, assignments=assignments)


# ---------------------------------------------------------------------------
# Jittable fixed-iteration variant (in-graph planning)
# ---------------------------------------------------------------------------

def duplicate_experts_jax(dist: jnp.ndarray, ep_ranks: int, dup_slots: int,
                          max_copies: int = 4):
    """In-graph Algorithm 1 producing PlacementPlan arrays.

    Runs exactly ``ep_ranks * dup_slots`` greedy iterations (static bound)
    with masking for infeasible moves — fully jit/pjit compatible so the
    predict->plan->dispatch pipeline is a single XLA program.
    """
    E = dist.shape[0]
    e_loc, n_slots = plan_dims(E, ep_ranks, dup_slots)
    dist = dist.astype(jnp.float32) / jnp.maximum(dist.sum(), 1e-9)
    home_rank = jnp.arange(E, dtype=jnp.int32) // e_loc
    home = home_rank * n_slots + (jnp.arange(E, dtype=jnp.int32) % e_loc)

    # state arrays
    n_rep0 = jnp.ones((E,), jnp.int32)
    # hosted[e, r] = expert e has a copy on rank r
    hosted0 = jax.nn.one_hot(home_rank, ep_ranks, dtype=jnp.bool_)
    table0 = jnp.tile(home[:, None], (1, max_copies))
    pool_expert0 = jnp.full((ep_ranks,), -1, jnp.int32)
    pool_sel0 = jnp.zeros((ep_ranks, max(dup_slots, 1)), jnp.int32)
    rank_extra0 = jnp.zeros((ep_ranks,), jnp.int32)

    def body(state, _):
        n_rep, hosted, table, pool_expert, pool_sel, rank_extra = state
        share = dist / n_rep.astype(jnp.float32)               # per-copy load
        loads = jnp.einsum("e,er->r", share, hosted.astype(jnp.float32))
        g_hot = jnp.argmax(loads).astype(jnp.int32)
        g_cold = jnp.argmin(loads).astype(jnp.int32)

        src = home_rank
        feasible = (
            hosted[:, g_hot]
            & (n_rep < max_copies)
            & ~hosted[:, g_cold]
            & (rank_extra[g_cold] < dup_slots)
            & ((pool_expert[src] == -1) | (pool_expert[src] == jnp.arange(E)))
        )
        score = jnp.where(feasible, share, -1.0)
        e_star = jnp.argmax(score).astype(jnp.int32)
        do = (score[e_star] > 0.0) & (loads[g_hot] - loads[g_cold] > 1e-3)

        slot_j = rank_extra[g_cold]
        gslot = g_cold * n_slots + e_loc + slot_j
        src_star = home_rank[e_star]
        copy_idx = jnp.minimum(n_rep[e_star], max_copies - 1)  # index of new copy

        table = jnp.where(do, table.at[e_star, copy_idx].set(gslot), table)
        n_rep = jnp.where(do, n_rep.at[e_star].add(1), n_rep)
        hosted = jnp.where(do, hosted.at[e_star, g_cold].set(True), hosted)
        pool_expert = jnp.where(do, pool_expert.at[src_star].set(e_star), pool_expert)
        pool_sel = jnp.where(
            do, pool_sel.at[g_cold, jnp.minimum(slot_j, pool_sel.shape[1] - 1)]
            .set(src_star), pool_sel)
        rank_extra = jnp.where(do, rank_extra.at[g_cold].add(1), rank_extra)
        return (n_rep, hosted, table, pool_expert, pool_sel, rank_extra), loads

    state0 = (n_rep0, hosted0, table0, pool_expert0, pool_sel0, rank_extra0)
    (n_rep, hosted, table, pool_expert, pool_sel, rank_extra), _ = jax.lax.scan(
        body, state0, None, length=ep_ranks * max(dup_slots, 1))

    return PlacementPlan(
        n_replicas=n_rep,
        replica_table=table,
        pool_expert=jnp.maximum(pool_expert, 0),
        pool_sel=pool_sel,
    )


def bottleneck_load(dist: np.ndarray, ep_ranks: int) -> float:
    """Max per-rank load fraction with NO duplication (home placement)."""
    E = dist.shape[0]
    e_loc = E // ep_ranks
    loads = np.asarray(dist, np.float64).reshape(ep_ranks, e_loc).sum(-1)
    return float(loads.max())


def skewness(dist: np.ndarray) -> float:
    """Paper Sec 2: max expert share / mean expert share."""
    dist = np.asarray(dist, np.float64)
    dist = dist / max(dist.sum(), 1e-12)
    return float(dist.max() / (1.0 / dist.shape[0]))
