"""Imbalance metrics and the paper's prediction-error -> load models (Sec 3.3)."""

from __future__ import annotations

import numpy as np


def skewness(dist) -> float:
    """max expert share / mean expert share (paper Sec 2)."""
    dist = np.asarray(dist, np.float64)
    dist = dist / max(dist.sum(), 1e-12)
    return float(dist.max() * dist.shape[-1])


def error_rate(p_hat, p) -> float:
    """Distribution estimation error (paper Sec 3.2.1):
    mean |p_hat - p| normalised by the uniform share 1/E."""
    p_hat = np.asarray(p_hat, np.float64)
    p = np.asarray(p, np.float64)
    E = p.shape[-1]
    return float(np.mean(np.abs(p_hat - p)) * E)


def bottleneck_factor(eps: float, num_devices: int, scenario: str = "typical"
                      ) -> float:
    """Multiplier on the perfectly-balanced per-device load given prediction
    error rate ``eps`` (Sec 3.3 / Fig 5).

    optimistic  — errors cancel: still perfectly balanced.
    typical     — errors uniform across devices: (1 + eps).
    pessimistic — all errors land on one device: N * (1 + eps) upper bound.
    """
    if scenario == "optimistic":
        return 1.0
    if scenario == "typical":
        return 1.0 + eps
    if scenario == "pessimistic":
        return num_devices * (1.0 + eps)
    raise ValueError(scenario)


def comm_factor(eps: float, scenario: str = "typical") -> float:
    """Communication never enjoys an optimistic case (Sec 3.3): misrouted
    tokens always pay an extra hop."""
    return 1.0 + max(eps, 0.0)
