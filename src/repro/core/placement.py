"""Expert placement plans — the interface between the duplication planner
(Algorithm 1, `repro.core.duplication`) and the EP dispatch runtime
(`repro.moe.dispatch`).

A plan describes, for one MoE layer, which expert occupies each *slot*:

* every EP rank owns ``E_loc = E / R`` fixed slots (its home experts);
* every rank additionally has ``D`` *replica* slots, filled from a global
  pool of up to ``R`` duplicated experts (one contributed per source rank
  via all_gather — matching the paper's "one expert sent/received per GPU
  per layer" transfer model, Sec 5);
* tokens routed to expert ``e`` are split round-robin across its
  ``n_replicas[e]`` copies (home slot + replica slots).

All arrays are replicated (identical on every rank) and dynamically valued
(recomputed per prediction interval) but statically shaped.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class PlacementPlan(NamedTuple):
    """Slot layout for one MoE layer. Shapes are static given (E, R, D, C_max)."""
    n_replicas: jnp.ndarray     # (E,)   int32, >= 1
    replica_table: jnp.ndarray  # (E, C_max) int32 global slot ids; [:,0] = home
    pool_expert: jnp.ndarray    # (R,)   int32 expert contributed by each source rank
    pool_sel: jnp.ndarray       # (R, D) int32 pool index filling each replica slot

    @property
    def num_experts(self) -> int:
        return self.n_replicas.shape[0]

    @property
    def max_copies(self) -> int:
        return self.replica_table.shape[1]


def plan_dims(num_experts: int, ep_ranks: int, dup_slots: int):
    assert num_experts % ep_ranks == 0, (num_experts, ep_ranks)
    e_loc = num_experts // ep_ranks
    return e_loc, e_loc + dup_slots


def home_slot(expert: np.ndarray, e_loc: int, n_slots: int):
    """Global slot id of an expert's home copy."""
    return (expert // e_loc) * n_slots + (expert % e_loc)


def identity_plan(num_experts: int, ep_ranks: int, dup_slots: int,
                  max_copies: int) -> PlacementPlan:
    """No duplication: every expert lives only in its home slot."""
    e_loc, n_slots = plan_dims(num_experts, ep_ranks, dup_slots)
    e = np.arange(num_experts)
    home = home_slot(e, e_loc, n_slots)
    table = np.tile(home[:, None], (1, max_copies))
    return PlacementPlan(
        n_replicas=jnp.ones((num_experts,), jnp.int32),
        replica_table=jnp.asarray(table, jnp.int32),
        pool_expert=jnp.zeros((ep_ranks,), jnp.int32),
        pool_sel=jnp.zeros((ep_ranks, max(dup_slots, 1)), jnp.int32),
    )


def stack_plans(plans) -> PlacementPlan:
    """Stack per-layer plans into (L, ...) arrays for the scanned forward."""
    import jax
    return jax.tree.map(lambda *xs: jnp.stack(xs), *plans)


def slot_expert_map(plan: PlacementPlan, ep_ranks: int,
                    dup_slots: int) -> np.ndarray:
    """(S,) expert id occupying each global slot; -1 = unused replica slot.

    Home slots are fixed by construction; replica slots are read off the
    plan's ``replica_table`` rows (entries ``1..n_replicas-1`` are live
    extra copies). This is the host-side view the replica-weight runtime
    diffs between plans — a slot's *contents* only matter while some
    expert's replica set points at it.
    """
    E = int(np.asarray(plan.n_replicas).shape[-1])
    e_loc, n_slots = plan_dims(E, ep_ranks, dup_slots)
    se = -np.ones((ep_ranks * n_slots,), np.int64)
    e = np.arange(E)
    se[home_slot(e, e_loc, n_slots)] = e
    n_rep = np.asarray(plan.n_replicas)
    table = np.asarray(plan.replica_table)
    for ei in range(E):
        for c in range(1, int(n_rep[ei])):
            se[int(table[ei, c])] = ei
    return se


def store_bytes_per_rank(num_experts: int, ep_ranks: int, dup_slots: int, *,
                         entry_bytes: int, num_layers: int) -> int:
    """Device memory one EP rank spends on a persistent replica store:
    ``L x n_slots`` slot entries. The store is a SECOND copy of the home
    experts plus the replica slots (the home stacks stay resident for
    migration sourcing), so this is pure overhead on top of the sharded
    expert weights."""
    _, n_slots = plan_dims(num_experts, ep_ranks, dup_slots)
    return int(num_layers) * n_slots * int(entry_bytes)


def clamp_dup_slots(num_experts: int, ep_ranks: int, dup_slots: int, *,
                    entry_bytes: int, num_layers: int,
                    hbm_budget_bytes: float) -> int:
    """Largest ``d <= dup_slots`` whose replica store fits the per-rank
    HBM budget (``MoEConfig.store_hbm_budget_gb``). 0 disables the clamp.
    Can return 0 (no replica slots fit — duplication off): the home second
    copy alone may exhaust the budget, in which case the engine falls back
    to plain EP rather than over-replicating past device memory."""
    if hbm_budget_bytes <= 0 or dup_slots <= 0:
        return dup_slots
    d = int(dup_slots)
    while d > 0 and store_bytes_per_rank(
            num_experts, ep_ranks, d, entry_bytes=entry_bytes,
            num_layers=num_layers) > hbm_budget_bytes:
        d -= 1
    return d


def quota_limited_plan(assignments, num_experts: int, ep_ranks: int,
                       dup_slots: int, max_copies: int, *,
                       quota: int) -> PlacementPlan:
    """Plan at the FULL compiled replica-slot geometry, using at most
    ``quota`` replica slots per rank.

    The fleet arbiter moves duplication capacity between co-resident
    models as a *logical* quota: every engine keeps the ``dup_slots`` it
    compiled with (so no jit signature ever changes), but the planner's
    extra-copy assignments are truncated to the first ``quota`` per
    destination rank. ``quota=0`` degenerates to the identity plan at
    full geometry; ``quota>=dup_slots`` is the unrestricted plan.
    """
    q = max(0, min(int(quota), int(dup_slots)))
    if q < dup_slots:
        taken = np.zeros((ep_ranks,), np.int64)
        kept = []
        for expert, dest in assignments:
            if taken[dest] >= q:
                continue
            taken[dest] += 1
            kept.append((expert, dest))
        assignments = kept
    return plan_from_assignments(assignments, num_experts, ep_ranks,
                                 dup_slots, max_copies)


def plan_from_assignments(assignments, num_experts: int, ep_ranks: int,
                          dup_slots: int, max_copies: int) -> PlacementPlan:
    """Build a PlacementPlan from a host-side list of extra copies.

    assignments: list of (expert, dest_rank) pairs — the duplication
    decisions from Algorithm 1. Constraints enforced here:
      * <= dup_slots extra copies hosted per rank,
      * <= max_copies total copies per expert,
      * one pool contribution per source (home) rank.
    Violations are skipped (planner should already respect them).
    """
    e_loc, n_slots = plan_dims(num_experts, ep_ranks, dup_slots)
    n_rep = np.ones((num_experts,), np.int64)
    table = np.tile(home_slot(np.arange(num_experts), e_loc, n_slots)[:, None],
                    (1, max_copies))
    pool_expert = np.zeros((ep_ranks,), np.int64)
    pool_used = np.zeros((ep_ranks,), bool)
    pool_sel = np.zeros((ep_ranks, max(dup_slots, 1)), np.int64)
    rank_extra = np.zeros((ep_ranks,), np.int64)

    for expert, dest in assignments:
        src = expert // e_loc
        if n_rep[expert] >= max_copies or rank_extra[dest] >= dup_slots:
            continue
        if pool_used[src] and pool_expert[src] != expert:
            continue                      # source already ships a different expert
        pool_expert[src] = expert
        pool_used[src] = True
        slot_j = rank_extra[dest]
        pool_sel[dest, slot_j] = src
        gslot = dest * n_slots + e_loc + slot_j
        table[expert, n_rep[expert]] = gslot
        n_rep[expert] += 1
        rank_extra[dest] += 1

    return PlacementPlan(
        n_replicas=jnp.asarray(n_rep, jnp.int32),
        replica_table=jnp.asarray(table, jnp.int32),
        pool_expert=jnp.asarray(pool_expert, jnp.int32),
        pool_sel=jnp.asarray(pool_sel, jnp.int32),
    )
