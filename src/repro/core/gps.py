"""MoE-GPS: select the prediction strategy that minimises end-to-end latency.

Sweeps {no prediction, Distribution-Only, Token-to-Expert x accuracy ladder}
through the simulator for a (model, hardware, skewness) point and returns
the argmin plus the Fig-1-style guideline decision.

Inputs that come from *measurement* (benchmarks/bench_fig4.py measures them
on synthetic corpora with our real predictor ladder):
  * ``dist_eps(skew)``      — Distribution-Only estimation error vs skew
                              (paper Table 1).
  * ``t2e_curve(skew)``     — list of (accuracy, overhead_frac) points for
                              the Token-to-Expert ladder (paper Fig 4); the
                              paper fits an exponential overhead(accuracy).

Defaults below are calibrated to the paper's reported numbers so the
simulator reproduces Fig 6/7 without re-measuring.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.simulator import (HardwareConfig, LatencyBreakdown,
                                  layer_latency)


# ---------------------------------------------------------------------------
# measured-input defaults (paper-calibrated)
# ---------------------------------------------------------------------------

# Paper Table 1: (skew, error_rate). Error grows superlinearly with skew
# because cold experts see few tokens (Sec 3.2.1).
_TABLE1 = [(1.39, 0.018), (1.40, 0.0098), (1.99, 0.16)]


def default_dist_eps(skew: float) -> float:
    """Piecewise-linear interpolation of Table 1 (clamped outside)."""
    pts = sorted(_TABLE1)
    xs = [p[0] for p in pts]
    ys = [p[1] for p in pts]
    return float(np.interp(skew, xs, ys))


# Paper Fig 4: the predictor ladder. Accuracy rises with skew (hot experts
# are easy targets); overhead_frac is overhead / model runtime measured on
# the same device. Exponential fit overhead(acc) = a * exp(b * acc) with
# skew-dependent ease: at higher skew the same accuracy costs less.
@dataclass(frozen=True)
class T2EPoint:
    name: str
    accuracy: float
    overhead_frac: float


def default_t2e_curve(skew: float) -> List[T2EPoint]:
    """Predictor ladder calibrated to Fig 4 (Mixtral; skew in [1.4, 2.0]).

    Baseline accuracy floor = probability model ~= skew/E by construction
    (always guess the hottest expert); neural predictors climb toward ~0.9
    with exponentially growing overhead, discounted by skew (Sec 4:
    "higher skewness makes prediction easier").
    """
    e_floor = min(0.95, skew / 8.0)           # hottest-expert hit rate
    ease = 1.0 / max(skew, 1.0) ** 2          # overhead discount at high skew
    ladder = [
        ("probability", max(0.18, e_floor), 0.001),
        ("conditional", min(0.55, e_floor + 0.25), 0.01),
        ("ffn", 0.75, 0.08 * ease * 4),
        ("ffn-wide", 0.85, 0.20 * ease * 4),
        ("lstm", 0.92, 0.45 * ease * 4),
        ("lstm-large", 0.97, 0.90 * ease * 4),
    ]
    return [T2EPoint(n, a, o) for n, a, o in ladder]


def fit_overhead_curve(points: Sequence[T2EPoint]) -> Callable[[float], float]:
    """Paper Sec 3.2.2: exponential fit overhead(acc) = a * exp(b * acc).
    Least squares in log space over points with positive overhead."""
    xs = np.array([p.accuracy for p in points if p.overhead_frac > 0])
    ys = np.array([p.overhead_frac for p in points if p.overhead_frac > 0])
    if len(xs) < 2:
        return lambda a: float(ys[0]) if len(ys) else 0.0
    b, log_a = np.polyfit(xs, np.log(ys), 1)
    return lambda acc: float(math.exp(log_a) * math.exp(b * acc))


# ---------------------------------------------------------------------------
# strategy selection
# ---------------------------------------------------------------------------

LEVERS = ("duplicate", "reschedule", "both")


class StrategyVerdict(str):
    """Verdict over the combined strategy space (prediction x lever).

    Subclasses ``str`` so it compares, hashes and serialises as the
    prediction-mode name ("none" | "dist_only" | "token_to_expert") —
    pre-lever callers that do ``name == "dist_only"`` keep working — while
    carrying which balancing *lever* the prediction should drive:
    ``duplicate`` (move weights), ``reschedule`` (move tokens) or ``both``.
    """
    lever: str

    def __new__(cls, prediction: str, lever: str = "duplicate"):
        self = super().__new__(cls, prediction)
        self.lever = "none" if prediction == "none" else lever
        return self

    @property
    def prediction(self) -> str:
        return str(self)

    @property
    def combined(self) -> str:
        """Render for audit logs: e.g. ``dist_only+reschedule``."""
        if str(self) == "none":
            return "none"
        return f"{str(self)}+{self.lever}"


@dataclass
class StrategyResult:
    strategy: str                     # none | dist_only | token_to_expert
    accuracy: float
    latency: LatencyBreakdown
    predictor: str = ""
    lever: str = "duplicate"

    @property
    def total(self) -> float:
        return self.latency.total


@dataclass
class GPSReport:
    model: str
    hardware: str
    skew: float
    baseline: StrategyResult
    dist_only: StrategyResult
    t2e_points: List[StrategyResult]
    comm_model: str = "paper"
    # lever-costed grid {dist_only, t2e ladder} x levers (run_gps(levers=...));
    # empty when only the paper's duplicate lever was evaluated pre-lever-API.
    combos: List[StrategyResult] = field(default_factory=list)

    @property
    def best_t2e(self) -> StrategyResult:
        return min(self.t2e_points, key=lambda r: r.total)

    @property
    def best(self) -> StrategyResult:
        return min([self.dist_only, self.best_t2e], key=lambda r: r.total)

    @property
    def dist_only_saving(self) -> float:
        return 1.0 - self.dist_only.total / self.baseline.total

    @property
    def t2e_saving(self) -> float:
        return 1.0 - self.best_t2e.total / self.baseline.total

    @property
    def saving_difference(self) -> float:
        """Fig 7: dist_only saving - best t2e saving ( >0 => dist_only wins)."""
        return self.dist_only_saving - self.t2e_saving

    @property
    def best_combo(self) -> StrategyResult:
        """Argmin over the lever-costed grid (falls back to the duplicate
        lever's legacy results when no combos were evaluated)."""
        pool = self.combos or ([self.dist_only] + self.t2e_points)
        return min(pool, key=lambda r: r.total)

    def best_for_lever(self, lever: str) -> Optional[StrategyResult]:
        pool = [r for r in self.combos if r.lever == lever]
        return min(pool, key=lambda r: r.total) if pool else None

    def saving_of(self, r: StrategyResult) -> float:
        return 1.0 - r.total / self.baseline.total

    @property
    def reschedule_saving(self) -> float:
        """Best reschedule-lever saving vs no balancing (0 if not costed)."""
        best = self.best_for_lever("reschedule")
        return self.saving_of(best) if best is not None else 0.0

    @property
    def dist_only_speedup_over_t2e(self) -> float:
        """Headline metric: how much faster dist-only is than the best T2E
        point (paper: >23% on Mixtral/MMLU/NVLink)."""
        return self.best_t2e.total / self.dist_only.total - 1.0

    def guideline(self) -> str:
        """Fig 1 decision, phrased as the paper's guidance."""
        comm_frac = ((self.baseline.latency.dispatch
                      + self.baseline.latency.combine
                      + self.baseline.latency.allreduce)
                     / self.baseline.latency.total)
        who = ("Distribution-Only" if self.best is self.dist_only
               else f"Token-to-Expert (acc={self.best.accuracy:.2f})")
        why = []
        why.append(f"communication is {comm_frac:.0%} of baseline latency"
                   + (" (not a bottleneck)" if comm_frac < 0.3 else
                      " (a bottleneck)"))
        why.append(f"skewness {self.skew:.2f} is "
                   + ("low: accurate token-level prediction is expensive"
                      if self.skew < 1.7 else
                      "high: accurate token-level prediction is cheap"))
        return f"use {who} — " + "; ".join(why)

    def summary_rows(self) -> List[Dict]:
        rows = [
            dict(strategy="none", accuracy=0.0, predictor="-",
                 **self.baseline.latency.as_dict()),
            dict(strategy="dist_only", accuracy=self.dist_only.accuracy,
                 predictor="mle", **self.dist_only.latency.as_dict()),
        ]
        for r in self.t2e_points:
            rows.append(dict(strategy="token_to_expert", accuracy=r.accuracy,
                             predictor=r.predictor, **r.latency.as_dict()))
        return rows


def run_gps(
    cfg: ModelConfig,
    hw: HardwareConfig,
    *,
    batch: int = 1,
    seq: int = 512,
    skew: float = 1.4,
    dist_eps: Optional[Callable[[float], float]] = None,
    t2e_curve: Optional[Sequence[T2EPoint]] = None,
    scenario: str = "typical",
    comm_model: str = "paper",
    migration_stall_s: float = 0.0,
    migration_hidden_frac: float = 0.0,
    levers: Sequence[str] = ("duplicate",),
    resched_residual: float = 0.05,
    resched_extra_frac: float = 0.10,
    dup_hbm_bytes: float = 0.0,
) -> GPSReport:
    """Evaluate all strategies for one (model, hardware, skew) point.

    ``migration_stall_s``: per-layer-per-step replica-weight migration
    stall (the plan-churn cost of the persistent-store runtime,
    ``repro.runtime.cost.amortized_layer_stall_s``). Charged as overhead
    to every DUPLICATING strategy, so a strategy whose predicted balance
    gain is smaller than its weight movement loses to the baseline.

    ``migration_hidden_frac``: fraction of that stall the deployment's
    async prefetcher hides under forward compute (layer-staged overlapped
    fills, ``repro.runtime.LayerStagedExecutor``) — only the EXPOSED
    remainder ``(1 - frac) * stall`` is charged, so the verdict reflects
    overlapped-transfer economics: duplication that was too churn-heavy
    for synchronous migration can win once the transfer rides for free.

    Combined strategy space (``report.combos``): every prediction mode is
    additionally costed per balancing *lever* in ``levers``. The lever
    changes which costs apply in the same roofline:

      duplicate    migration stall + ``dup_hbm_bytes`` replica-weight reads.
      reschedule   no migration (the plan stays put); instead the rescue
                   round ships ``resched_extra_frac`` more dispatch bytes
                   and FFN balance only reaches ``resched_residual``.
      both         pays both costs; FFN load is the finer of the two.

    ``resched_residual``: rank-imbalance the token scheduler could not
    remove (measured: ``RescheduleResult.imbalance_sched - 1``).
    ``resched_extra_frac``: rescue-round a2a bytes / primary dispatch
    bytes (measured from ``MoEStats.overflow``).
    ``dup_hbm_bytes``: per-device replica-slot weight bytes read per step
    (0 keeps the legacy duplicate costing; engines pass the real size).
    """
    if cfg.moe is None:
        raise ValueError(f"{cfg.name} has no MoE FFN: the paper's technique "
                         "is inapplicable (see DESIGN.md Arch-applicability)")
    import dataclasses as _dc
    dist_eps = dist_eps or default_dist_eps
    curve = list(t2e_curve) if t2e_curve is not None else default_t2e_curve(skew)
    lat = lambda **kw: layer_latency(cfg, hw, batch=batch, seq=seq, skew=skew,
                                     scenario=scenario, comm_model=comm_model,
                                     **kw)

    exposed_stall_s = migration_stall_s * (
        1.0 - min(max(migration_hidden_frac, 0.0), 1.0))

    def charge_migration(r: StrategyResult) -> StrategyResult:
        if exposed_stall_s <= 0.0:
            return r
        lb = _dc.replace(r.latency,
                         overhead=r.latency.overhead + exposed_stall_s)
        return _dc.replace(r, latency=lb)

    baseline = StrategyResult("none", 0.0, lat(strategy="none"))
    eps_d = dist_eps(skew)
    dist_only = charge_migration(
        StrategyResult("dist_only", 1.0 - eps_d,
                       lat(strategy="dist_only", eps=eps_d)))
    t2e_points = [
        charge_migration(StrategyResult(
            "token_to_expert", p.accuracy,
            lat(strategy="token_to_expert", eps=1.0 - p.accuracy,
                overhead_frac=p.overhead_frac),
            predictor=p.name))
        for p in curve
    ]

    combos: List[StrategyResult] = []
    for lever in levers:
        if lever not in LEVERS:
            raise ValueError(f"unknown lever {lever!r}; want one of {LEVERS}")
        duplicating = lever in ("duplicate", "both")
        lkw = dict(lever=lever,
                   resched_residual=resched_residual,
                   resched_extra_frac=resched_extra_frac,
                   dup_hbm_bytes=dup_hbm_bytes if duplicating else 0.0)
        price = charge_migration if duplicating else (lambda r: r)
        combos.append(price(StrategyResult(
            "dist_only", 1.0 - eps_d,
            lat(strategy="dist_only", eps=eps_d, **lkw), lever=lever)))
        for p in curve:
            combos.append(price(StrategyResult(
                "token_to_expert", p.accuracy,
                lat(strategy="token_to_expert", eps=1.0 - p.accuracy,
                    overhead_frac=p.overhead_frac, **lkw),
                predictor=p.name, lever=lever)))

    return GPSReport(model=cfg.name, hardware=hw.name, skew=skew,
                     baseline=baseline, dist_only=dist_only,
                     t2e_points=t2e_points, comm_model=comm_model,
                     combos=combos)


def sweep(
    cfg: ModelConfig,
    hardwares: Sequence[HardwareConfig],
    skews: Sequence[float],
    **kw,
) -> List[GPSReport]:
    """Fig 6/7 sweep: every (hardware, skew) point."""
    return [run_gps(cfg, hw, skew=s, **kw) for hw in hardwares for s in skews]


# ---------------------------------------------------------------------------
# online (serving-loop) entry point
# ---------------------------------------------------------------------------

def recommend_strategy(
    cfg: ModelConfig,
    hw: HardwareConfig,
    *,
    skew: float,
    batch: int = 8,
    seq: int = 256,
    allow_t2e: bool = True,
    min_saving: float = 0.02,
    levers: Sequence[str] = ("duplicate",),
    **kw,
) -> Tuple[StrategyVerdict, GPSReport]:
    """One-shot guideline for the ONLINE controller: given the skew the
    serving loop just *measured* (instead of an offline dataset estimate),
    return the (prediction, lever) verdict to run with next. The verdict
    compares as the prediction-mode string (``StrategyVerdict`` subclasses
    ``str``) and carries ``.lever``.

    ``allow_t2e`` — False when no Token-to-Expert predictor is loaded in
    the engine (the controller must not pick an unrunnable strategy).
    ``min_saving`` — below this predicted end-to-end saving, balancing
    is not worth its churn: run plain EP (verdict "none"/"none").
    ``levers`` — which balancing levers the engine can actually drive;
    the default keeps the pre-lever duplicate-only arbitration.
    ``migration_stall_s`` (kw) — measured replica-migration stall per
    layer-step; duplicating levers carry it, so heavy plan churn tips
    the verdict toward "reschedule" or "none" (see ``run_gps``).
    ``migration_hidden_frac`` (kw) — the fraction of that stall the
    engine's overlapped prefetcher measured as hidden under compute;
    only the exposed remainder is charged.
    ``resched_residual`` / ``resched_extra_frac`` / ``dup_hbm_bytes``
    (kw) — measured lever costs, see ``run_gps``.
    """
    report = run_gps(cfg, hw, batch=batch, seq=seq,
                     skew=max(float(skew), 1.0), levers=tuple(levers), **kw)
    pool = [r for r in report.combos
            if allow_t2e or r.strategy != "token_to_expert"]
    best = min(pool, key=lambda r: r.total)
    saving = report.saving_of(best)
    if saving < min_saving:
        return StrategyVerdict("none"), report
    return StrategyVerdict(best.strategy, best.lever), report
