"""MoE-GPS performance simulator (TPU-adapted LLMCompass analogue).

The paper builds its end-to-end latency model on LLMCompass (GPU,
SM-occupancy op model). We adapt the op model to a throughput roofline —
``time(op) = max(flops / (peak_flops * util), bytes / hbm_bw)`` — which is
the TPU-native analytical model (MXU is a systolic array: once tiles are
128-aligned, utilisation is a flat factor, not an occupancy curve).
Collectives cost ``bytes / link_bw`` with a topology term.

What it models (paper Sec 3.4): one MoE transformer layer, prefill,
TP-attention + EP-FFN, broken into
  attention  — QKV/score/output GEMMs + softmax, tensor-parallel over N
  allreduce  — ring all-reduce after TP attention: 2(N-1)/N bytes/device
  dispatch   — post-routing all-to-all scatter, bottlenecked by the most
               loaded device: (N-1) * load / N^2 of all routed tokens
  ffn        — expert GEMMs, bottlenecked by the most loaded device
  combine    — the reverse all-to-all
  overhead   — prediction cost (Token-to-Expert only)

Load factors (paper Sec 3.3, Fig 5):
  no prediction      compute load = skewness     comm load = skewness
  Distribution-Only  compute load = 1 + eps      comm load = skewness
                     (duplication balances compute; "communication time
                      remains unchanged" — paper Sec 4)
  Token-to-Expert    compute load = 1 + eps      dispatch ~ eps only
                     (correct tokens are pre-routed during attention; only
                      mispredicted tokens pay the extra hop — Sec 3.3)

Hardware presets cover the paper's 4xA100 NVLink/PCIe validation points
and the TPU v5e production target (DESIGN.md Sec 3).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig
from repro.core.balance import bottleneck_factor, comm_factor


# ---------------------------------------------------------------------------
# hardware
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareConfig:
    name: str
    num_devices: int
    peak_flops: float            # per device, bf16/fp16 FLOP/s
    hbm_bw: float                # per device, bytes/s
    link_bw: float               # per device interconnect bandwidth, bytes/s
    mxu_util: float = 0.7        # achievable fraction of peak on big GEMMs
    topology: str = "fully_connected"   # fully_connected | torus2d
    torus_links_per_axis: int = 2

    def with_(self, **kw) -> "HardwareConfig":
        return dataclasses.replace(self, **kw)


# Paper validation points: 4x A100 (312 TF/s bf16, 2.0 TB/s HBM) fully
# connected over NVLink 3.0 (600 GB/s/GPU) or PCIe 4.0 (Fig 7 uses 64 GB/s).
A100_NVLINK = HardwareConfig("4xA100-NVLink", 4, 312e12, 2.0e12, 600e9)
A100_PCIE = HardwareConfig("4xA100-PCIe", 4, 312e12, 2.0e12, 64e9)

# Production target: TPU v5e pod slice. 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI, 2 links per torus axis usable for a collective.
TPU_V5E_16 = HardwareConfig("16xTPUv5e", 16, 197e12, 819e9, 2 * 45e9,
                            topology="torus2d")
TPU_V5E_POD = HardwareConfig("256xTPUv5e", 256, 197e12, 819e9, 2 * 45e9,
                             topology="torus2d")
# Inter-pod DCN-limited setting (the paper's "PCIe" analogue at pod scale).
TPU_V5E_DCN = TPU_V5E_POD.with_(name="256xTPUv5e-DCN", link_bw=6e9)

PRESETS: Dict[str, HardwareConfig] = {
    h.name: h for h in
    (A100_NVLINK, A100_PCIE, TPU_V5E_16, TPU_V5E_POD, TPU_V5E_DCN)
}


# ---------------------------------------------------------------------------
# op model
# ---------------------------------------------------------------------------

BYTES = 2  # bf16 / fp16 everywhere


def gemm_time(hw: HardwareConfig, flops: float, bytes_moved: float) -> float:
    """Roofline: compute-bound or HBM-bound, whichever dominates."""
    return max(flops / (hw.peak_flops * hw.mxu_util),
               bytes_moved / hw.hbm_bw)


def elementwise_time(hw: HardwareConfig, bytes_moved: float) -> float:
    return bytes_moved / hw.hbm_bw


def allreduce_time(hw: HardwareConfig, bytes_per_device: float) -> float:
    """Ring all-reduce: each device sends/receives 2(N-1)/N of its shard."""
    n = hw.num_devices
    return 2 * (n - 1) / n * bytes_per_device / hw.link_bw


def alltoall_time(hw: HardwareConfig, bottleneck_bytes: float) -> float:
    """All-to-all bottlenecked by the busiest device. On a torus the
    effective per-device bandwidth is shared across fewer direct paths;
    we model it with the per-device injection bandwidth (bisection-safe
    for the (N-1)/N^2-scale transfers this simulator sees)."""
    return bottleneck_bytes / hw.link_bw


# ---------------------------------------------------------------------------
# per-layer workload terms
# ---------------------------------------------------------------------------

def _ffn_mult(activation: str) -> int:
    return 3 if activation == "swiglu" else 2


def attention_flops(cfg: ModelConfig, tokens: int, seq: int,
                    causal: bool = True) -> float:
    """One layer of attention (projections + scores + values + output).
    ``causal=False`` for decode (each query sees the whole context)."""
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    s_eff = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    disc = 0.5 if (causal and s_eff == seq) else 1.0   # window keeps full width
    if cfg.attention == "mla" and cfg.mla is not None:
        m = cfg.mla
        proj = 2 * tokens * d * (m.kv_lora_rank + m.rope_head_dim)       # down
        proj += 2 * tokens * m.kv_lora_rank * H * (m.nope_head_dim + m.v_head_dim)
        qd = m.q_lora_rank or d
        proj += 2 * tokens * qd * H * (m.nope_head_dim + m.rope_head_dim)
        proj += 2 * tokens * H * m.v_head_dim * d                        # out
        hd_eff = m.nope_head_dim + m.rope_head_dim
        score = 2 * tokens * s_eff * H * hd_eff * disc
        value = 2 * tokens * s_eff * H * m.v_head_dim * disc
        return proj + 2 * (score + value)
    proj = 2 * tokens * d * (H + 2 * KV) * hd
    out = 2 * tokens * H * hd * d
    sv = 2 * 2 * tokens * s_eff * H * hd * disc
    return proj + out + sv


def ffn_flops_per_token(cfg: ModelConfig) -> float:
    """Routed-expert FLOPs per token (top-k experts)."""
    if cfg.moe is None:
        return 2 * _ffn_mult(cfg.activation) * cfg.d_model * cfg.d_ff
    e = cfg.moe
    return 2 * _ffn_mult(cfg.activation) * cfg.d_model * e.d_ff_expert * e.top_k


def dense_ffn_flops_per_token(cfg: ModelConfig) -> float:
    """Always-on FFN FLOPs per token (shared experts + dense residual)."""
    if cfg.moe is None:
        return 0.0
    e = cfg.moe
    f = 2 * _ffn_mult(cfg.activation) * cfg.d_model
    total = e.num_shared_experts * f * e.d_ff_expert
    if e.dense_residual:
        total += f * (e.d_ff_dense or cfg.d_ff)
    return total


def expert_bytes(cfg: ModelConfig) -> float:
    """Weight bytes of ONE expert (the unit moved by duplication)."""
    if cfg.moe is None:
        return 0.0
    return _ffn_mult(cfg.activation) * cfg.d_model * cfg.moe.d_ff_expert * BYTES


# ---------------------------------------------------------------------------
# latency model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LatencyBreakdown:
    attention: float
    allreduce: float
    dispatch: float
    ffn: float
    combine: float
    overhead: float
    strategy: str = ""
    accuracy: float = 0.0

    @property
    def total(self) -> float:
        return (self.attention + self.allreduce + self.dispatch + self.ffn
                + self.combine + self.overhead)

    def as_dict(self) -> Dict[str, float]:
        return {"attention": self.attention, "allreduce": self.allreduce,
                "dispatch": self.dispatch, "ffn": self.ffn,
                "combine": self.combine, "overhead": self.overhead,
                "total": self.total}


def layer_latency(
    cfg: ModelConfig,
    hw: HardwareConfig,
    *,
    batch: int,
    seq: int,
    skew: float,
    strategy: str = "none",          # none | dist_only | token_to_expert
    eps: float = 0.0,                # prediction error rate of the strategy
    overhead_frac: float = 0.0,      # T2E predictor cost / no-overhead runtime
    scenario: str = "typical",
    comm_model: str = "paper",       # paper | balanced (see DESIGN.md)
    lever: str = "duplicate",        # duplicate | reschedule | both
    resched_residual: float = 0.0,   # rank imbalance left after token sched
    resched_extra_frac: float = 0.0, # rescue-round a2a bytes / dispatch bytes
    dup_hbm_bytes: float = 0.0,      # replica-slot weight bytes read per step
) -> LatencyBreakdown:
    """Single-layer MoE prefill latency under a prediction strategy.

    ``comm_model="paper"`` reproduces the paper's accounting (Distribution-
    Only leaves communication at the skew-scaled baseline). ``"balanced"``
    additionally credits dispatch balance to duplication (the physically
    tighter model; kept separate so the paper reproduction stays faithful).

    The *lever* axis (ROADMAP combined strategy space) selects which
    balancing mechanism the prediction feeds — the defaults reproduce the
    paper's duplication-only accounting bit for bit:

      duplicate   FFN load = 1 + f(eps); pays migration (charged by the
                  caller as overhead) and replica HBM reads
                  (``dup_hbm_bytes`` folded into the FFN roofline bytes).
      reschedule  no weight movement: the plan stays put and token
                  scheduling levels ranks to ``resched_residual``; pays
                  ``resched_extra_frac`` more dispatch/combine bytes (the
                  overflow rescue round). Never worse than no balancing.
      both        duplication sets the coarse balance, token scheduling
                  grinds the residual: load = 1 + f(min(eps, residual)),
                  pays both the comm surcharge and the duplicate costs.
    """
    n = hw.num_devices
    tokens = batch * seq
    d = cfg.d_model

    # --- attention (TP over n devices) + ring all-reduce ------------------
    att_f = attention_flops(cfg, tokens, seq) / n
    att_bytes = (3 * tokens * d * BYTES) / n + tokens * d * BYTES
    t_attn = gemm_time(hw, att_f, att_bytes) \
        + elementwise_time(hw, 4 * tokens * d * BYTES / n)
    t_ar = allreduce_time(hw, tokens * d * BYTES)

    # --- FFN (EP over n devices) ------------------------------------------
    routed_f = ffn_flops_per_token(cfg) * tokens
    balanced_share = routed_f / n
    if strategy == "none":
        load = skew
    elif lever == "reschedule":
        load = min(skew, bottleneck_factor(resched_residual, n, scenario))
    elif lever == "both":
        load = bottleneck_factor(min(eps, resched_residual), n, scenario)
    else:   # duplicate (the paper's lever)
        load = bottleneck_factor(eps, n, scenario)
    ffn_bytes = expert_bytes(cfg) * _experts_per_device(cfg, n) \
        + dup_hbm_bytes + 2 * tokens * d * BYTES / n
    t_ffn = gemm_time(hw, balanced_share * load, ffn_bytes)
    # always-on branch (shared experts / dense residual), TP over n
    dense_f = dense_ffn_flops_per_token(cfg) * tokens / n
    if dense_f:
        t_ffn += gemm_time(hw, dense_f, ffn_bytes * 0.1)

    # --- dispatch / combine all-to-all -------------------------------------
    k = cfg.moe.top_k if cfg.moe else 1
    routed_bytes = tokens * k * d * BYTES
    base_move = routed_bytes * (n - 1) / (n * n)    # balanced bottleneck bytes
    if strategy == "token_to_expert":
        # correct tokens pre-routed (overlapped with attention); mispredicted
        # pairs pay the extra hop. Communication has no optimistic case.
        t_disp = alltoall_time(hw, base_move * comm_factor(eps, scenario) * eps)
        t_comb = alltoall_time(hw, base_move)
    elif strategy == "dist_only" and comm_model == "balanced":
        t_disp = alltoall_time(hw, base_move)
        t_comb = alltoall_time(hw, base_move)
    else:   # none, or dist_only under the paper's accounting
        t_disp = alltoall_time(hw, base_move * skew)
        t_comb = alltoall_time(hw, base_move * skew)

    if lever in ("reschedule", "both") and strategy != "none":
        # overflow tokens take a second hop to their rescue slot and back
        surcharge = 1.0 + max(float(resched_extra_frac), 0.0)
        t_disp *= surcharge
        t_comb *= surcharge

    # --- prediction overhead ------------------------------------------------
    base_total = t_attn + t_ar + t_disp + t_ffn + t_comb
    t_over = overhead_frac * base_total if strategy == "token_to_expert" else 0.0

    return LatencyBreakdown(attention=t_attn, allreduce=t_ar, dispatch=t_disp,
                            ffn=t_ffn, combine=t_comb, overhead=t_over,
                            strategy=strategy, accuracy=1.0 - eps)


def _experts_per_device(cfg: ModelConfig, n: int) -> int:
    if cfg.moe is None:
        return 1
    return max(1, cfg.moe.num_experts // n)


def duplication_move_time(cfg: ModelConfig, hw: HardwareConfig,
                          experts_moved_per_device: int = 1) -> float:
    """Paper Sec 5: weight-transfer cost of moving duplicated experts.
    One expert sent + received per device per layer by default."""
    return expert_bytes(cfg) * experts_moved_per_device / hw.link_bw


def duplication_is_hideable(cfg: ModelConfig, hw: HardwareConfig, *,
                            batch: int, seq: int) -> bool:
    """Can the expert move be overlapped with the attention layer?"""
    lb = layer_latency(cfg, hw, batch=batch, seq=seq, skew=1.0)
    return duplication_move_time(cfg, hw) <= lb.attention
