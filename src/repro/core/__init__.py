"""The paper's primary contribution: expert-duplication load balancing with
prediction-strategy selection (MoE-GPS)."""
from repro.core.duplication import (DuplicationResult, bottleneck_load,
                                    duplicate_experts_host,
                                    duplicate_experts_jax, skewness)
from repro.core.placement import (PlacementPlan, identity_plan,
                                  plan_from_assignments, quota_limited_plan)
from repro.core.simulator import (A100_NVLINK, A100_PCIE, TPU_V5E_16,
                                  TPU_V5E_DCN, TPU_V5E_POD, HardwareConfig,
                                  LatencyBreakdown, layer_latency)
from repro.core.gps import (LEVERS, GPSReport, StrategyVerdict, T2EPoint,
                            recommend_strategy, run_gps, sweep)

__all__ = [
    "A100_NVLINK", "A100_PCIE", "DuplicationResult", "GPSReport",
    "HardwareConfig", "LEVERS", "LatencyBreakdown", "PlacementPlan",
    "StrategyVerdict", "T2EPoint", "TPU_V5E_16", "TPU_V5E_DCN",
    "TPU_V5E_POD", "bottleneck_load", "duplicate_experts_host",
    "duplicate_experts_jax", "identity_plan", "layer_latency",
    "plan_from_assignments", "quota_limited_plan", "recommend_strategy",
    "run_gps", "skewness", "sweep",
]
