"""The predictor ladder (paper Sec 3.2 / Appendix B), in pure JAX.

Distribution-Only:
  * ``DistributionEstimator`` — multinomial MLE with a moving average over
    batches (Eq. 1 / Appendix A). Zero inference-time cost.

Token-to-Expert (increasing accuracy and overhead):
  * ``ProbabilityModel``            — global most-frequent expert per layer.
  * ``ConditionalProbabilityModel`` — most-frequent expert per token id (or
    per position) per layer.
  * ``FFNPredictor``   — embed -> 128 MLP -> ReLU -> 128 -> per-layer heads.
  * ``LSTMPredictor``  — embed -> 128 -> 2-layer LSTM(64) -> windowed
    ("sparse") attention -> residual MLP -> per-layer heads.

Adaptation note (DESIGN.md): the paper feeds the LLM's own 4096-d token
embeddings; offline we learn a small token embedding jointly with the
predictor — same information source (token identity + context), honestly
counted in the overhead FLOPs.

Every predictor exposes ``flops_per_token(num_layers)`` so the simulator
can convert accuracy into runtime overhead analytically.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import truncated_normal_init
from repro.optim.adamw import adamw_init, adamw_update


# ---------------------------------------------------------------------------
# Distribution-Only (multinomial MLE with moving average)
# ---------------------------------------------------------------------------

class DistributionEstimator:
    """EMA multinomial MLE over per-layer expert histograms."""

    def __init__(self, num_layers: int, num_experts: int, ema: float = 0.9):
        self.counts = np.zeros((num_layers, num_experts), np.float64)
        self.ema = ema
        self._initialized = False

    def update(self, batch_counts: np.ndarray):
        """batch_counts: (L, E) token counts from one batch."""
        bc = np.asarray(batch_counts, np.float64)
        if not self._initialized:
            self.counts = bc.copy()
            self._initialized = True
        else:
            self.counts = self.ema * self.counts + (1 - self.ema) * bc

    def predict(self) -> np.ndarray:
        tot = np.maximum(self.counts.sum(axis=1, keepdims=True), 1e-9)
        return self.counts / tot

    @staticmethod
    def flops_per_token(num_layers: int) -> float:
        return 0.0      # estimation is offline / a histogram side-effect


# ---------------------------------------------------------------------------
# Frequency models
# ---------------------------------------------------------------------------

class ProbabilityModel:
    """argmax of the global expert frequency per layer (Appendix B Eq. 7-8)."""

    def __init__(self, num_layers: int, num_experts: int):
        self.counts = np.zeros((num_layers, num_experts), np.int64)

    def fit(self, experts: np.ndarray, tokens=None):
        """experts: (L, N, S) top-1 expert labels."""
        L, E = self.counts.shape
        for l in range(L):
            self.counts[l] += np.bincount(experts[l].reshape(-1), minlength=E)
        return self

    def predict(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (N, S) -> (L, N, S) predicted experts."""
        top = self.counts.argmax(axis=1)                       # (L,)
        L = top.shape[0]
        return np.broadcast_to(top[:, None, None],
                               (L,) + tokens.shape).astype(np.int32)

    @staticmethod
    def flops_per_token(num_layers: int) -> float:
        return 1.0      # a lookup


class ConditionalProbabilityModel:
    """argmax expert conditioned on token id or position (Appendix B Eq. 9-10)."""

    def __init__(self, num_layers: int, num_experts: int, vocab: int,
                 condition: str = "token"):
        self.condition = condition
        self.vocab = vocab
        self.num_experts = num_experts
        self.num_layers = num_layers
        self.table = None          # (L, vocab_or_positions) best expert
        self._counts: Dict = {}

    def fit(self, experts: np.ndarray, tokens: np.ndarray):
        L, N, S = experts.shape
        E = self.num_experts
        if self.condition == "token":
            dim = self.vocab
            idx = np.broadcast_to(tokens[None], (L, N, S))
        else:
            dim = S
            idx = np.broadcast_to(np.arange(S)[None, None, :], (L, N, S))
        table = np.zeros((L, dim), np.int32)
        for l in range(L):
            flat_idx = idx[l].reshape(-1)
            flat_e = experts[l].reshape(-1)
            cnt = np.zeros((dim, E), np.int64)
            np.add.at(cnt, (flat_idx, flat_e), 1)
            table[l] = cnt.argmax(axis=1)
        self.table = table
        return self

    def predict(self, tokens: np.ndarray) -> np.ndarray:
        N, S = tokens.shape
        L = self.num_layers
        if self.condition == "token":
            return np.stack([self.table[l][tokens] for l in range(L)])
        return np.broadcast_to(self.table[:, None, :S], (L, N, S)).astype(np.int32)

    @staticmethod
    def flops_per_token(num_layers: int) -> float:
        return float(num_layers)   # one lookup per layer


# ---------------------------------------------------------------------------
# Neural predictors
# ---------------------------------------------------------------------------

HID = 128
LSTM_HID = 64


def _init_heads(key, num_layers, hid, num_experts):
    return truncated_normal_init(key, (num_layers, hid, num_experts),
                                 1 / math.sqrt(hid))


class FFNPredictor:
    """Two-layer MLP over token embeddings with per-MoE-layer heads."""

    def __init__(self, num_layers: int, num_experts: int, vocab: int, seed=0):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.params = {
            "embed": truncated_normal_init(ks[0], (vocab, HID), 0.02),
            "w1": truncated_normal_init(ks[1], (HID, HID), 1 / math.sqrt(HID)),
            "w2": truncated_normal_init(ks[2], (HID, HID), 1 / math.sqrt(HID)),
            "heads": _init_heads(ks[3], num_layers, HID, num_experts),
        }

    def apply(self, params, tokens):
        """tokens: (B, S) -> logits (L, B, S, E)."""
        x = params["embed"][tokens]
        h = jax.nn.relu(x @ params["w1"])
        h = h @ params["w2"]
        return jnp.einsum("bsh,lhe->lbse", h, params["heads"])

    def flops_per_token(self, num_layers: int) -> float:
        return 2 * HID * HID * 2 + 2 * HID * self.num_experts * num_layers

    # shared training loop ---------------------------------------------------
    def fit(self, experts: np.ndarray, tokens: np.ndarray, *, steps=300,
            batch=64, lr=3e-3, seed=0):
        return _fit_neural(self, experts, tokens, steps=steps, batch=batch,
                           lr=lr, seed=seed)

    def predict(self, tokens: np.ndarray) -> np.ndarray:
        logits = jax.jit(self.apply)(self.params, jnp.asarray(tokens))
        return np.asarray(logits.argmax(-1), np.int32)


class LSTMPredictor:
    """2-layer LSTM(64) with windowed attention + residual MLP (Appendix B)."""

    WINDOW = 16     # "sparse attention" = local window over LSTM outputs

    def __init__(self, num_layers: int, num_experts: int, vocab: int, seed=0):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 8)
        self.num_layers = num_layers
        self.num_experts = num_experts
        H = LSTM_HID
        def lstm_params(k, d_in):
            k1, k2 = jax.random.split(k)
            return {
                "wx": truncated_normal_init(k1, (d_in, 4 * H), 1 / math.sqrt(d_in)),
                "wh": truncated_normal_init(k2, (H, 4 * H), 1 / math.sqrt(H)),
                "b": jnp.zeros((4 * H,), jnp.float32),
            }
        self.params = {
            "embed": truncated_normal_init(ks[0], (vocab, HID), 0.02),
            "compress": truncated_normal_init(ks[1], (HID, HID), 1 / math.sqrt(HID)),
            "lstm1": lstm_params(ks[2], HID),
            "lstm2": lstm_params(ks[3], H),
            "attn_scale": jnp.ones(()),
            "res_mlp": truncated_normal_init(ks[4], (HID, H), 1 / math.sqrt(HID)),
            "heads": _init_heads(ks[5], num_layers, H, num_experts),
        }

    @staticmethod
    def _lstm(p, xs):
        H = LSTM_HID
        def step(carry, x):
            h, c = carry
            z = x @ p["wx"] + h @ p["wh"] + p["b"]
            i, f, g, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h
        B = xs.shape[0]
        init = (jnp.zeros((B, H)), jnp.zeros((B, H)))
        _, hs = jax.lax.scan(step, init, jnp.swapaxes(xs, 0, 1))
        return jnp.swapaxes(hs, 0, 1)

    def apply(self, params, tokens):
        x = params["embed"][tokens]                       # (B, S, HID)
        x = jax.nn.relu(x @ params["compress"])
        h = self._lstm(params["lstm1"], x)
        h = self._lstm(params["lstm2"], h)
        # windowed self-attention over LSTM outputs (q = k = v = h)
        B, S, H = h.shape
        W = min(self.WINDOW, S)
        scores = jnp.einsum("bsh,bth->bst", h, h) * params["attn_scale"] / math.sqrt(H)
        pos = jnp.arange(S)
        mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - W)
        scores = jnp.where(mask[None], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1) @ h
        out = attn + x @ params["res_mlp"]                # residual feedforward
        return jnp.einsum("bsh,lhe->lbse", out, params["heads"])

    def flops_per_token(self, num_layers: int) -> float:
        H = LSTM_HID
        lstm = 2 * (HID * 4 * H + H * 4 * H) + 2 * (H * 4 * H + H * 4 * H)
        attnf = 2 * 2 * self.WINDOW * H
        return (2 * HID * HID + lstm + attnf + 2 * HID * H
                + 2 * H * self.num_experts * num_layers)

    def fit(self, experts, tokens, *, steps=300, batch=32, lr=3e-3, seed=0):
        return _fit_neural(self, experts, tokens, steps=steps, batch=batch,
                           lr=lr, seed=seed)

    def predict(self, tokens: np.ndarray) -> np.ndarray:
        logits = jax.jit(self.apply)(self.params, jnp.asarray(tokens))
        return np.asarray(logits.argmax(-1), np.int32)


def _fit_neural(model, experts: np.ndarray, tokens: np.ndarray, *, steps,
                batch, lr, seed):
    """Cross-entropy training over (tokens -> per-layer expert labels)."""
    rng = np.random.default_rng(seed)
    N = tokens.shape[0]
    params = model.params
    opt = adamw_init(params)

    def loss_fn(p, tok, lab):
        logits = model.apply(p, tok)                      # (L, B, S, E)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        return nll.mean()

    @jax.jit
    def step_fn(p, o, tok, lab):
        loss, grads = jax.value_and_grad(loss_fn)(p, tok, lab)
        p, o, _ = adamw_update(p, grads, o, lr, weight_decay=0.0)
        return p, o, loss

    for i in range(steps):
        idx = rng.choice(N, size=min(batch, N), replace=False)
        tok = jnp.asarray(tokens[idx])
        lab = jnp.asarray(experts[:, idx])
        params, opt, loss = step_fn(params, opt, tok, lab)
    model.params = params
    return model


def accuracy(pred: np.ndarray, truth: np.ndarray) -> float:
    """pred/truth: (L, N, S) -> mean token-level top-1 accuracy."""
    return float((pred == truth).mean())
