"""Migration cost model: bytes moved per plan switch and the stall they
cost on the deployment's interconnect (the roofline's collective term).

Two consumers:

* ``core.gps.run_gps`` — an amortized per-layer-per-step migration stall
  is added to the *duplicating* strategies' overhead, so the guideline
  rejects a strategy whose plan churn costs more than its balance gain.
* the serving engines — ``should_migrate`` gates an individual re-plan:
  serving stays on the old plan when the predicted stall exceeds the
  predicted imbalance gain until the next re-plan.
"""

from __future__ import annotations

import numpy as np


def entry_bytes(weights: dict) -> int:
    """Bytes one slot entry (one expert's weights) occupies, from the
    actual stacked weight arrays {name: (L, E_or_S, ...)}."""
    total = 0
    for w in weights.values():
        per = 1
        for d in w.shape[2:]:
            per *= int(d)
        total += per * int(np.dtype(w.dtype).itemsize)
    return total


def plan_migration_bytes(diff, weights: dict) -> int:
    """Logical bytes a diff moves: one send + receive per changed entry
    (the paper's Sec 5 transfer accounting, per entry instead of per
    rank)."""
    return diff.bytes_moved(entry_bytes(weights))


def migration_stall_s(nbytes: float, hw) -> float:
    """Serialized wire time of a migration on ``hw``
    (`repro.core.simulator.HardwareConfig`). The executor overlaps chunks
    with serving steps, so this is the worst-case stall, matching the
    roofline's collective term bytes / link_bw."""
    return float(nbytes) / max(float(hw.link_bw), 1.0)


def amortized_layer_stall_s(window_bytes: float, hw, *, num_layers: int,
                            window_steps: int) -> float:
    """Measured migration traffic of a serving window -> the per-layer
    per-step stall `run_gps` should charge duplicating strategies.

    ``window_bytes`` spans all layers and all steps of the window, while
    ``layer_latency`` models one layer of one step — divide accordingly.
    """
    steps = max(int(window_steps), 1) * max(int(num_layers), 1)
    return migration_stall_s(window_bytes, hw) / steps


def should_migrate(stall_s: float, gain_s: float) -> bool:
    """Accept a re-plan iff the one-off migration stall is repaid by the
    predicted imbalance gain accrued before the next re-plan."""
    return float(stall_s) <= float(gain_s)
