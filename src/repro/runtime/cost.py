"""Migration cost model: bytes moved per plan switch and the stall they
cost on the deployment's interconnect (the roofline's collective term).

Three consumers:

* ``core.gps.run_gps`` — an amortized per-layer-per-step migration stall
  is added to the *duplicating* strategies' overhead, so the guideline
  rejects a strategy whose plan churn costs more than its balance gain.
  With overlapped (async-prefetch) migration only the EXPOSED fraction of
  the stall is charged (``migration_hidden_frac``).
* the serving engines — ``should_migrate`` gates an individual re-plan:
  serving stays on the old plan when the predicted *exposed* stall exceeds
  the predicted imbalance gain until the next re-plan. The hidden portion
  (transfer time overlapped with forward compute) is free by construction.
* the overlap scheduler — ``overlap_chunk_budget`` converts the measured
  non-migration step time (the overlap window) into a per-step chunk
  budget, replacing the fixed ``migrate_chunks_per_step`` knob.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def entry_bytes(weights: dict) -> int:
    """Bytes one slot entry (one expert's weights) occupies, from the
    actual stacked weight arrays {name: (L, E_or_S, ...)}."""
    total = 0
    for w in weights.values():
        per = 1
        for d in w.shape[2:]:
            per *= int(d)
        total += per * int(np.dtype(w.dtype).itemsize)
    return total


def plan_migration_bytes(diff, weights: dict) -> int:
    """Logical bytes a diff moves: one send + receive per changed entry
    (the paper's Sec 5 transfer accounting, per entry instead of per
    rank)."""
    return diff.bytes_moved(entry_bytes(weights))


def migration_stall_s(nbytes: float, hw) -> float:
    """Serialized wire time of a migration on ``hw``
    (`repro.core.simulator.HardwareConfig`). With synchronous adoption the
    whole figure lands between engine steps; with the overlapped executor
    it is an upper bound split by ``split_hidden_exposed``."""
    return float(nbytes) / max(float(hw.link_bw), 1.0)


def amortized_layer_stall_s(window_bytes: float, hw, *, num_layers: int,
                            window_steps: int) -> float:
    """Measured migration traffic of a serving window -> the per-layer
    per-step stall `run_gps` should charge duplicating strategies.

    ``window_bytes`` spans all layers and all steps of the window, while
    ``layer_latency`` models one layer of one step — divide accordingly.
    """
    steps = max(int(window_steps), 1) * max(int(num_layers), 1)
    return migration_stall_s(window_bytes, hw) / steps


# ---------------------------------------------------------------------------
# overlap scheduling (async predicted-hot prefetch)
# ---------------------------------------------------------------------------

class KindWindowEMA:
    """Per-iteration-kind EMA of the migration-free step wall time.

    The overlap chunk budget is sized against the compute window of the
    step the fills ride under — but prefill-bearing iterations run orders
    of magnitude longer than decode-only ones, so one mixed EMA
    overestimates the window during decode phases (overdriving the chunk
    budget onto the serving path) and underestimates it during prefill
    bursts (starving the drain). One EMA per kind ("prefill" / "decode")
    sizes the budget to the step actually being shadowed; an unseeded
    kind falls back to whatever kind has been measured (the only estimate
    available until the first step of its own kind lands)."""

    def __init__(self, beta: float = 0.9):
        self.beta = float(beta)
        self._v: dict = {}

    def update(self, kind: str, dt: float) -> float:
        prev = self._v.get(kind, 0.0)
        self._v[kind] = (float(dt) if prev <= 0
                         else self.beta * prev + (1 - self.beta) * float(dt))
        return self._v[kind]

    def window(self, kind: str) -> float:
        w = self._v.get(kind, 0.0)
        if w > 0:
            return w
        return max(self._v.values(), default=0.0)

    def kinds(self) -> dict:
        return dict(self._v)


def overlap_chunk_budget(window_s: float, *, chunk_entries: int,
                         entry_bytes: int, hw, min_chunks: int = 1,
                         max_chunks: int = 1024) -> int:
    """Chunk-steps per engine iteration that fit inside one step's compute
    window (the measured non-migration step time). The wire time of one
    fixed-shape chunk is ``chunk_entries * entry_bytes / link_bw``; issuing
    at most ``window / chunk_wire`` chunks per step keeps the transfer
    inside the forward's shadow. At least ``min_chunks`` per step so a
    migration always drains even when the window estimate collapses."""
    wire = migration_stall_s(max(int(chunk_entries), 1)
                             * max(int(entry_bytes), 1), hw)
    if wire <= 0.0:
        return int(max_chunks)
    budget = int(max(float(window_s), 0.0) / wire)
    return int(np.clip(budget, min_chunks, max_chunks))


def split_hidden_exposed(stall_s: float, window_s: float
                         ) -> Tuple[float, float]:
    """Split a migration stall into the portion HIDDEN under an overlap
    window (transfer concurrent with forward compute) and the EXPOSED
    remainder that still lands on the serving critical path. Returns
    ``(hidden_s, exposed_s)`` with ``hidden + exposed == stall``."""
    stall = max(float(stall_s), 0.0)
    hidden = min(stall, max(float(window_s), 0.0))
    return hidden, stall - hidden


def should_migrate(stall_s: float, gain_s: float,
                   hidden_s: float = 0.0) -> bool:
    """Accept a re-plan iff the EXPOSED migration stall (total minus the
    portion hidden under forward compute) is repaid by the predicted
    imbalance gain accrued before the next re-plan."""
    exposed = max(float(stall_s) - max(float(hidden_s), 0.0), 0.0)
    return exposed <= float(gain_s)
