"""Replica-weight migration runtime.

The paper's transfer model (Sec 5) charges duplication ONE weight movement
per re-plan; the per-step ``gather_replica_pool`` collective in
``repro.moe.dispatch`` pays it every forward step of every MoE layer. This
package makes replica weights *persistent* so the serving engines pay
weight movement only when the plan actually changes:

  ``ReplicaStore``      — per-rank ``(L, S, ...)`` slot-weight buffers
                          (home experts + replica slots) kept in device
                          memory across steps, versioned per layer.
  ``plan_diff``         — exactly which (layer, slot) entries change
                          expert assignment between two stacked plans.
  ``MigrationExecutor`` — serve -> diff -> chunked fill -> swap: fills
                          only changed slots with a fixed-shape collective
                          step, chunked to a per-step budget and
                          double-buffered so engines keep serving on the
                          old plan until the swap commits (zero
                          recompiles).
  ``LayerStagedExecutor``— the async-prefetch variant: fills in layer
                          order and exposes a per-layer ready vector so
                          the forward pass adopts each layer the moment
                          its fill lands (transfer hidden under compute).
  ``cost``              — bytes-moved / stall model (now with a
                          hidden-vs-exposed overlap split) fed into the
                          GPS guideline and the controller hysteresis.
"""

from repro.runtime.cost import (KindWindowEMA, entry_bytes,
                                migration_stall_s, overlap_chunk_budget,
                                plan_migration_bytes, should_migrate,
                                split_hidden_exposed)
from repro.runtime.diff import (PlanDiff, apply_diff, plan_diff, plans_equal,
                                stacked_slot_experts, vacated_slots)
from repro.runtime.migrate import (LayerStagedExecutor, MigrationExecutor,
                                   make_migrate_step, migrate_all)
from repro.runtime.store import ReplicaStore

__all__ = [
    "KindWindowEMA", "LayerStagedExecutor", "MigrationExecutor", "PlanDiff",
    "ReplicaStore",
    "apply_diff", "entry_bytes", "make_migrate_step", "migrate_all",
    "migration_stall_s", "overlap_chunk_budget", "plan_diff",
    "plan_migration_bytes", "plans_equal", "should_migrate",
    "split_hidden_exposed", "stacked_slot_experts", "vacated_slots",
]
