"""Plan diffing: which slot-weight entries a plan switch must move.

Plans are compared through their slot->expert maps
(`repro.core.placement.slot_expert_map`). Only *replica* slots can ever
differ — home slots are fixed by construction — so a diff is bounded by
``L * ep_ranks * dup_slots`` entries. Slots that become UNUSED under the
new plan (expert -1) need no transfer: round-robin dispatch never routes
tokens to a slot outside some expert's live replica set, so stale weights
there are unreachable.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.placement import PlacementPlan, slot_expert_map


class PlanDiff(NamedTuple):
    """Host-side migration work list for one plan switch.

    Entry arrays have shape (n_entries,). ``dst_slot`` is a GLOBAL slot id
    (rank = dst_slot // n_slots); ``src_expert`` is the expert whose home
    rank sources the weights. ``target_slot_experts`` is the (L, S) slot
    map of the TARGET plan — carried here so the executor/store commit
    does not recompute the per-expert scan ``plan_diff`` already did.
    """
    layer: np.ndarray
    dst_slot: np.ndarray
    src_expert: np.ndarray
    target_slot_experts: np.ndarray

    @property
    def num_entries(self) -> int:
        return int(self.layer.shape[0])

    def bytes_moved(self, entry_bytes: int) -> int:
        return self.num_entries * int(entry_bytes)


def _layer_plan(plan_stack: PlacementPlan, l: int) -> PlacementPlan:
    return PlacementPlan(*(np.asarray(a)[l] for a in plan_stack))


def stacked_slot_experts(plan_stack: PlacementPlan, ep_ranks: int,
                         dup_slots: int) -> np.ndarray:
    """(L, S) slot->expert maps for a stacked (L, ...) plan."""
    L = int(np.asarray(plan_stack.n_replicas).shape[0])
    return np.stack([slot_expert_map(_layer_plan(plan_stack, l), ep_ranks,
                                     dup_slots) for l in range(L)])


def plan_diff(old_stack: PlacementPlan, new_stack: PlacementPlan,
              ep_ranks: int, dup_slots: int) -> PlanDiff:
    """Entries whose expert assignment changes old -> new and is LIVE under
    the new plan. ``plan_diff(p, p)`` is empty; applying the diff to the
    old slot map reproduces the new one on every used slot
    (see ``apply_diff``)."""
    se_old = stacked_slot_experts(old_stack, ep_ranks, dup_slots)
    se_new = stacked_slot_experts(new_stack, ep_ranks, dup_slots)
    layer, slot = np.nonzero((se_new != se_old) & (se_new >= 0))
    return PlanDiff(layer=layer.astype(np.int32),
                    dst_slot=slot.astype(np.int32),
                    src_expert=se_new[layer, slot].astype(np.int32),
                    target_slot_experts=se_new)


def vacated_slots(old_stack: PlacementPlan, new_stack: PlacementPlan,
                  ep_ranks: int, dup_slots: int) -> int:
    """Slot-entries LIVE under the old plan but UNUSED under the new one.

    This is the fleet arbiter's shrink accounting: when a cold model's
    dup-slot quota drops, the next re-plan leaves replica slots with
    ``expert == -1`` — those entries move ZERO bytes (round-robin dispatch
    never reads an unused slot, see the module docstring), so shrinking a
    replica set is free and only growth pays migration stall. The count
    times ``entry_bytes`` is the HBM the budget ledger hands back."""
    se_old = stacked_slot_experts(old_stack, ep_ranks, dup_slots)
    se_new = stacked_slot_experts(new_stack, ep_ranks, dup_slots)
    return int(np.count_nonzero((se_old >= 0) & (se_new < 0)))


def plans_equal(a: PlacementPlan, b: PlacementPlan) -> bool:
    """True iff two stacked plans are identical in EVERY array (slot map
    AND replica counts/tables — two plans can share a slot map yet split
    tokens differently). The prefetch controller uses this to detect a
    misprediction: a pre-begun migration whose target differs from the
    boundary re-plan is cancelled, not committed."""
    if (a is None) != (b is None):
        return False
    if a is None:
        return True
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


def apply_diff(se_old: np.ndarray, diff: PlanDiff) -> np.ndarray:
    """Apply a diff to an (L, S) slot map (the host-side model of what the
    MigrationExecutor does to the device buffers)."""
    se = np.array(se_old, copy=True)
    se[diff.layer, diff.dst_slot] = diff.src_expert
    return se
