"""Chunked, fixed-shape replica-weight migration.

``make_migrate_step`` builds ONE jitted step that fills up to ``chunk``
changed slots: every rank contributes the entries whose source expert
lives in its home shard, a psum broadcasts them (the only collective —
bytes proportional to the chunk, not to the rank count), and each rank
scatters the entries destined for its slot block into its store shard.
All shapes are static, so a migration of any size is a sequence of
identical step calls — zero recompiles, asserted by the engines'
compile-count checks.

``MigrationExecutor`` runs that sequence against a *copy* of the live
buffers (double-buffering is free: jax arrays are immutable) under a
per-engine-step chunk budget; the engine keeps serving on the old plan +
old store until ``tick`` reports the commit payload.

``LayerStagedExecutor`` is the async-prefetch variant: it sorts the diff
by LAYER and tracks a per-layer ready-version vector. Because the forward
pass scans layers in order, a layer whose fill already completed can be
consumed from the back buffer (with the target plan row) while later
layers are still in flight — ``forward(..., slot_weights_back, slot_ready,
target_plan)`` selects per layer, so dispatch reads old-plan slots until
the fill for that layer commits and the result is bit-exact with the
synchronous path at every intermediate state.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementPlan, plan_dims
from repro.obs.trace import NULL_TRACER
from repro.runtime.diff import PlanDiff


def make_migrate_step(mesh, *, num_experts: int, ep_ranks: int,
                      dup_slots: int, ep_axis: str = "model"):
    """Returns jitted ``step(weights, experts, layer, dst_slot, src_expert,
    valid) -> weights`` filling the described slots.

    weights: {name: (L, S, ...)} store buffers (sharded over ``ep_axis``
    when ``mesh`` is given); experts: {name: (L, E, ...)} the home expert
    stacks; descriptor arrays: (chunk,) replicated.
    ``mesh=None`` builds the single-device variant (tests / profiling).
    """
    e_loc, n_slots = plan_dims(num_experts, ep_ranks, dup_slots)

    if mesh is None:
        def step(weights, experts, layer, dst_slot, src_expert, valid):
            out = {}
            for k, w in experts.items():
                full = w[layer, src_expert]
                li = jnp.where(valid, layer, w.shape[0])    # invalid -> drop
                out[k] = weights[k].at[li, dst_slot].set(full, mode="drop")
            return out
        return jax.jit(step)

    from jax.sharding import PartitionSpec as P
    from repro.models.transformer import shard_map

    def inner(weights, experts, layer, dst_slot, src_expert, valid):
        rank = jax.lax.axis_index(ep_axis)
        src_rank = src_expert // e_loc
        local_e = src_expert % e_loc
        out = {}
        for k, w in experts.items():                 # w: (L, e_loc, ...)
            mask = (src_rank == rank).reshape((-1,) + (1,) * (w.ndim - 2))
            contrib = jnp.where(mask, w[layer, local_e], 0)
            full = jax.lax.psum(contrib, ep_axis)    # chunk-sized broadcast
            mine = (dst_slot // n_slots == rank) & valid
            li = jnp.where(mine, layer, w.shape[0])  # not mine -> drop
            out[k] = weights[k].at[li, dst_slot % n_slots].set(
                full, mode="drop")
        return out

    blk = P(None, ep_axis)             # prefix spec: dim 1 = slots/experts
    fn = shard_map(inner, mesh=mesh,
                   in_specs=(blk, blk, P(), P(), P(), P()),
                   out_specs=blk, check_vma=False)
    return jax.jit(fn)


class MigrationExecutor:
    """serve -> diff -> chunked fill -> swap state machine."""

    def __init__(self, step_fn, experts: Dict[str, jnp.ndarray],
                 entry_bytes: int, *, chunk: int = 8,
                 chunks_per_tick: int = 0, tracer=None):
        """``chunks_per_tick``: migration step calls per engine iteration
        (the per-step budget); 0 = drain the whole diff in one tick.
        ``tracer``: optional ``repro.obs.SpanTracer`` — begin/cancel/commit
        instants plus one ``migration.tick`` span per active tick land on
        a dedicated "migration" track."""
        self.step_fn = step_fn
        self.experts = experts
        self.entry_bytes = int(entry_bytes)
        self.chunk = max(int(chunk), 1)
        self.chunks_per_tick = int(chunks_per_tick)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._diff: Optional[PlanDiff] = None
        self._back: Optional[Dict[str, jnp.ndarray]] = None
        self._target_plan: Optional[PlacementPlan] = None
        self._target_se: Optional[np.ndarray] = None
        self._cursor = 0

    @property
    def active(self) -> bool:
        return self._diff is not None

    def begin(self, weights: Dict[str, jnp.ndarray], diff: PlanDiff,
              target_plan: PlacementPlan) -> None:
        """Stage a migration from the LIVE buffers toward ``target_plan``.
        Restarting while active abandons the partial back buffer (the live
        buffers were never touched, so no state is lost)."""
        self._back = dict(weights)
        self._diff = diff
        self._target_plan = target_plan
        self._target_se = np.asarray(diff.target_slot_experts)
        self._cursor = 0
        self.tracer.instant(
            "migration.begin", cat="migration", track="migration",
            args={"entries": int(diff.num_entries),
                  "bytes": int(diff.num_entries) * self.entry_bytes})

    def cancel(self) -> None:
        """Abandon an in-flight migration (the target plan was superseded
        by a later adoption). The live buffers were never touched."""
        if self._diff is not None:
            self.tracer.instant(
                "migration.cancel", cat="migration", track="migration",
                args={"filled_entries": int(self._cursor)})
        self._clear()

    def _clear(self) -> None:
        self._diff = self._back = self._target_plan = self._target_se = None
        self._cursor = 0

    def _run_chunk(self) -> int:
        d, c = self._diff, self._cursor
        n = min(self.chunk, d.num_entries - c)
        pad = self.chunk - n
        sl = slice(c, c + n)
        layer = jnp.asarray(np.pad(d.layer[sl], (0, pad)), jnp.int32)
        dst = jnp.asarray(np.pad(d.dst_slot[sl], (0, pad)), jnp.int32)
        src = jnp.asarray(np.pad(d.src_expert[sl], (0, pad)), jnp.int32)
        valid = jnp.asarray(np.arange(self.chunk) < n)
        self._back = self.step_fn(self._back, self.experts, layer, dst,
                                  src, valid)
        self._cursor += n
        return n

    def tick(self, budget: Optional[int] = None) -> Tuple[Optional[tuple], int]:
        """Run up to the per-step chunk budget (``budget`` overrides the
        constructor's ``chunks_per_tick`` — the overlap scheduler passes a
        compute-time-aware figure per step). Returns
        ``(commit, bytes_moved)`` — ``commit`` is
        ``(weights, target_plan, target_slot_experts)`` once the fill
        completes (the engine swaps plan + store atomically), else None."""
        if not self.active:
            return None, 0
        cap = self.chunks_per_tick if budget is None else int(budget)
        with self.tracer.span("migration.tick", cat="migration",
                              track="migration") as sp:
            moved = 0
            chunks = 0
            while self._cursor < self._diff.num_entries:
                moved += self._run_chunk()
                chunks += 1
                if cap and chunks >= cap:
                    break
            done = self._cursor >= self._diff.num_entries
            sp.set_args(chunks=chunks, moved_bytes=moved * self.entry_bytes,
                        remaining=int(self._diff.num_entries - self._cursor))
        if not done:
            return None, moved * self.entry_bytes
        commit = (self._back, self._target_plan, self._target_se)
        self.tracer.instant(
            "migration.commit", cat="migration", track="migration",
            args={"entries": int(self._diff.num_entries),
                  "bytes": int(self._diff.num_entries) * self.entry_bytes})
        self._clear()
        return commit, moved * self.entry_bytes


class LayerStagedExecutor(MigrationExecutor):
    """Layer-ordered chunked fill with a per-layer ready-version vector.

    Entries are filled in forward-scan order, so at any point the back
    buffer holds the COMPLETE target contents for a prefix of layers.
    ``ready_mask()`` reports which layers those are; the engine threads it
    (with the back buffer and target plan) into ``forward``, whose
    per-layer select adopts each layer the moment its fill lands — the
    transfer rides under the compute of the layers still being served on
    the old plan. Layers whose diff is empty are ready immediately: every
    live slot already holds the target expert, so adopting the target
    plan row there moves no weights.
    """

    def __init__(self, step_fn, experts: Dict[str, jnp.ndarray],
                 entry_bytes: int, *, num_layers: int, chunk: int = 8,
                 chunks_per_tick: int = 0, tracer=None):
        super().__init__(step_fn, experts, entry_bytes, chunk=chunk,
                         chunks_per_tick=chunks_per_tick, tracer=tracer)
        self.num_layers = int(num_layers)
        self._layer_end: Optional[np.ndarray] = None   # (L,) cum entry count

    def begin(self, weights: Dict[str, jnp.ndarray], diff: PlanDiff,
              target_plan: PlacementPlan) -> None:
        order = np.argsort(np.asarray(diff.layer), kind="stable")
        staged = PlanDiff(layer=np.asarray(diff.layer)[order],
                          dst_slot=np.asarray(diff.dst_slot)[order],
                          src_expert=np.asarray(diff.src_expert)[order],
                          target_slot_experts=diff.target_slot_experts)
        super().begin(weights, staged, target_plan)
        counts = np.bincount(staged.layer, minlength=self.num_layers)
        self._layer_end = np.cumsum(counts)

    def _clear(self) -> None:
        super()._clear()
        self._layer_end = None

    def ready_mask(self) -> np.ndarray:
        """(L,) bool: layers whose back-buffer fill is complete (safe to
        dispatch from the back buffer under the target plan). All-False
        when idle — the engine's select then reads the live pair."""
        if not self.active or self._layer_end is None:
            return np.zeros((self.num_layers,), bool)
        return self._layer_end <= self._cursor

    @property
    def back_weights(self) -> Optional[Dict[str, jnp.ndarray]]:
        """The in-flight double buffer (None when idle)."""
        return self._back

    @property
    def target_plan(self) -> Optional[PlacementPlan]:
        return self._target_plan

    @property
    def remaining_entries(self) -> int:
        if not self.active:
            return 0
        return self._diff.num_entries - self._cursor


def migrate_all(step_fn, weights: Dict[str, jnp.ndarray], experts: Dict,
                diff: PlanDiff, *, chunk: int = 8) -> Dict[str, jnp.ndarray]:
    """Synchronous helper: apply a whole diff and return the new buffers
    (the batch-engine path, where re-plans sit between batches anyway)."""
    ex = MigrationExecutor(step_fn, experts, 0, chunk=chunk)
    ex.begin(weights, diff, None)
    (new_weights, _, _), _ = ex.tick()
    return new_weights
