"""Persistent per-rank replica slot-weight buffers.

The store materializes every MoE layer's slot layout as stacked
``(L, S, ...)`` weight arrays (S = ep_ranks * n_slots), sharded over the
EP mesh axis so each rank holds exactly its ``(n_slots, ...)`` block in
device memory ACROSS steps. The forward pass consumes the store through
``shard_map`` — no weight collective at all — and the
``MigrationExecutor`` refreshes only the slots a plan switch changes.

Memory: the store holds a second copy of the home experts (slots are a
superset of the home layout), i.e. ``n_slots / e_loc`` x the expert
weights per rank — the price of serving steps that never re-gather.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PlacementPlan, plan_dims
from repro.runtime import cost as _cost
from repro.runtime.diff import stacked_slot_experts


def store_sharding(mesh, ndim: int, ep_axis: str = "model"):
    """NamedSharding pinning dim 1 (the slot dim) to the EP axis."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P(None, ep_axis, *([None] * (ndim - 2))))


class ReplicaStore:
    """Slot-weight buffers + host-side bookkeeping (slot map, versions)."""

    def __init__(self, weights: Dict[str, jnp.ndarray],
                 slot_experts: np.ndarray, *, num_experts: int,
                 ep_ranks: int, dup_slots: int):
        self.weights = weights                    # {name: (L, S, ...)}
        self.slot_experts = np.asarray(slot_experts)      # (L, S) host view
        self.num_experts = num_experts
        self.ep_ranks = ep_ranks
        self.dup_slots = dup_slots
        L = self.slot_experts.shape[0]
        self.version = np.zeros((L,), np.int64)   # bumped per layer on commit

    # ------------------------------------------------------------------ init
    @classmethod
    def from_params(cls, experts: Dict[str, jnp.ndarray],
                    plan_stack: PlacementPlan, *, num_experts: int,
                    ep_ranks: int, dup_slots: int, mesh=None,
                    ep_axis: str = "model") -> "ReplicaStore":
        """Build the store for a stacked plan from the stacked expert
        weights {name: (L, E, ...)}.

        Unused replica slots (no live replica points at them) are filled
        with their rank's first home expert — their contents are
        unreachable by dispatch, the fill just keeps shapes total.
        """
        e_loc, n_slots = plan_dims(num_experts, ep_ranks, dup_slots)
        se = stacked_slot_experts(plan_stack, ep_ranks, dup_slots)   # (L, S)
        rank_of_slot = np.arange(se.shape[1]) // n_slots
        fill = np.where(se >= 0, se, rank_of_slot[None, :] * e_loc)
        fill_j = jnp.asarray(fill, jnp.int32)
        weights = {k: jax.vmap(lambda w, s: w[s])(jnp.asarray(w), fill_j)
                   for k, w in experts.items()}
        if mesh is not None:
            weights = {k: jax.device_put(
                w, store_sharding(mesh, w.ndim, ep_axis))
                for k, w in weights.items()}
        return cls(weights, se, num_experts=num_experts, ep_ranks=ep_ranks,
                   dup_slots=dup_slots)

    # ---------------------------------------------------------------- commit
    def adopt(self, weights: Dict[str, jnp.ndarray],
              slot_experts: np.ndarray) -> None:
        """Swap in a migrated buffer set (the double-buffer commit)."""
        changed = np.any(np.asarray(slot_experts) != self.slot_experts, axis=1)
        self.version += changed.astype(np.int64)
        self.weights = weights
        self.slot_experts = np.asarray(slot_experts)

    # ------------------------------------------------------------------ info
    @property
    def entry_bytes(self) -> int:
        return _cost.entry_bytes(self.weights)

    @property
    def hbm_bytes_per_rank(self) -> int:
        """Device memory one EP rank spends on its store shard: L layers x
        n_slots local slot entries (home second copy + replica slots) —
        the figure the ``store_hbm_budget_gb`` clamp and the roofline's
        duplication memory term account for."""
        L = int(self.slot_experts.shape[0])
        _, n_slots = plan_dims(self.num_experts, self.ep_ranks,
                               self.dup_slots)
        return L * n_slots * self.entry_bytes
