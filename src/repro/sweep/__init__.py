"""Declarative (config x mesh x workload x strategy) sweep harness.

ReFrame-style regression tracking for the serving stack: a sweep spec
expands into jobs (``matrix``), each job runs a ContinuousEngine
deployment in a subprocess EP mesh (``job``/``runner``) or is emitted as
a k8s Job manifest for cluster runs (``k8s``), per-job metrics land in a
trend database (``history``) and gate against committed per-metric
reference bands (``references``), rendered as a markdown trend table
(``report``).

  PYTHONPATH=src python -m repro.sweep run --smoke
  PYTHONPATH=src python -m repro.sweep report
  PYTHONPATH=src python -m repro.sweep manifests --out-dir k8s/
"""

from repro.sweep.history import (append_entry, bench_history_entry,
                                 load_history, series, sweep_history_entry,
                                 trend)
from repro.sweep.k8s import job_manifest, manifest_name, validate_manifest
from repro.sweep.matrix import (FULL_SPEC, SMOKE_SPEC, MeshShape, SweepPoint,
                                SweepSpec, parse_mesh)
from repro.sweep.references import (check_metric, gate_document,
                                    refresh_references)
from repro.sweep.report import render_report, trend_table

__all__ = [
    "FULL_SPEC", "MeshShape", "SMOKE_SPEC", "SweepPoint", "SweepSpec",
    "append_entry", "bench_history_entry", "check_metric", "gate_document",
    "job_manifest", "load_history", "manifest_name", "parse_mesh",
    "refresh_references", "render_report", "series", "sweep_history_entry",
    "trend", "trend_table", "validate_manifest",
]
