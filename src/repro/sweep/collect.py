"""Cluster result collector: per-point job docs -> the trend database.

The k8s leg of the sweep runs every point as its own Job; each pod
writes (or uploads) one ``sweep.job`` result document. This module
closes the loop: point it at a directory of those per-point JSON docs
and it appends one ``kind: "sweep"`` line per NEW result to
``benchmarks/history.jsonl`` through the existing `history` API.

Robustness rules, in the same spirit as `history.load_history`:

  * a torn / truncated / non-JSON file is SKIPPED, never fatal — a pod
    killed mid-write must not poison the gate;
  * a doc that is not a ``kind: "sweep-job"`` dict with a ``key`` is
    skipped (the directory may hold reports, traces, partial uploads);
  * duplicates are skipped: a (key, git_sha) pair already present in
    the history file — or seen earlier in the same batch — is not
    appended twice, so re-running the collector over a bucket that
    still holds old results is idempotent.
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass, field
from typing import List, Optional

from repro.sweep.history import (append_entry, load_history,
                                 sweep_history_entry)


@dataclass
class CollectReport:
    """What one collector pass did, file by file."""
    appended: List[str] = field(default_factory=list)   # files ingested
    duplicates: List[str] = field(default_factory=list)
    torn: List[str] = field(default_factory=list)       # unparseable JSON
    skipped: List[str] = field(default_factory=list)    # not a job doc

    @property
    def total(self) -> int:
        return (len(self.appended) + len(self.duplicates)
                + len(self.torn) + len(self.skipped))

    def summarize(self) -> str:
        return (f"collected {len(self.appended)}/{self.total} docs "
                f"({len(self.duplicates)} duplicate, {len(self.torn)} torn, "
                f"{len(self.skipped)} non-job)")


def _load_doc(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.loads(f.read())
    except (OSError, json.JSONDecodeError):
        return None


def _is_job_doc(doc) -> bool:
    return (isinstance(doc, dict) and doc.get("kind") == "sweep-job"
            and isinstance(doc.get("key"), str) and doc.get("key"))


def collect_results(results_dir: str, history_path: str,
                    meta: Optional[dict] = None,
                    pattern: str = "*.json") -> CollectReport:
    """Ingest every job doc under ``results_dir`` into ``history_path``.

    ``meta`` supplies ``git_sha`` / ``timestamp_utc`` for docs that do
    not carry their own ``meta`` block (the per-job default); pass
    `runner.sweep_meta()` for a live stamp. Returns a `CollectReport`
    — nothing raises for bad individual files.
    """
    meta = meta or {}
    seen = {(e.get("key"), e.get("git_sha"))
            for e in load_history(history_path) if e.get("kind") == "sweep"}
    report = CollectReport()
    for path in sorted(glob.glob(os.path.join(results_dir, pattern))):
        doc = _load_doc(path)
        if doc is None:
            report.torn.append(path)
            continue
        if not _is_job_doc(doc):
            report.skipped.append(path)
            continue
        doc_meta = {**meta, **doc.get("meta", {})}
        entry = sweep_history_entry(doc, doc_meta)
        dedup = (entry["key"], entry["git_sha"])
        if dedup in seen:
            report.duplicates.append(path)
            continue
        append_entry(history_path, entry)
        seen.add(dedup)
        report.appended.append(path)
    return report
