"""Local sweep execution: one subprocess EP mesh per point.

Each point runs ``python -m repro.sweep.job`` with
``--xla_force_host_platform_device_count`` sized to its mesh (set before
the subprocess first imports jax — the reason points are processes, not
threads). Results are collected into one sweep report document, the
per-job Perfetto traces into one merged trace, and one history line per
job is appended to the trend database.
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
import time
from datetime import datetime, timezone
from typing import Sequence

from repro.sweep.history import append_entry, sweep_history_entry
from repro.sweep.matrix import SweepPoint

JOB_TIMEOUT_S = 1800


def sweep_meta() -> dict:
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    return {"git_sha": sha,
            "timestamp_utc": datetime.now(timezone.utc).isoformat(
                timespec="seconds"),
            "python": platform.python_version()}


def _src_root() -> str:
    import repro
    return os.path.dirname(os.path.abspath(list(repro.__path__)[0]))


def run_job(point: SweepPoint, *, smoke: bool, trace_out: str = "",
            max_iters: int = 0, verbose: bool = True) -> dict:
    """One point in a subprocess; never raises — failures come back as an
    ``ok: false`` job document so one broken point doesn't kill the sweep."""
    cmd = [sys.executable, "-m", "repro.sweep.job",
           "--point", json.dumps(point.to_obj())]
    if smoke:
        cmd.append("--smoke")
    if trace_out:
        cmd += ["--trace-out", trace_out]
    if max_iters:
        cmd += ["--max-iters", str(max_iters)]
    env = dict(
        os.environ,
        PYTHONPATH=_src_root() + (
            os.pathsep + os.environ["PYTHONPATH"]
            if os.environ.get("PYTHONPATH") else ""),
        XLA_FLAGS="--xla_force_host_platform_device_count="
                  f"{max(point.mesh.devices, 1)}")
    t0 = time.perf_counter()
    try:
        out = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=JOB_TIMEOUT_S, env=env)
        stdout_lines = out.stdout.strip().splitlines()
        doc = json.loads(stdout_lines[-1]) if stdout_lines else {}
        if not isinstance(doc, dict) or doc.get("kind") != "sweep-job":
            raise ValueError(
                f"job printed no result document (exit {out.returncode}): "
                f"{out.stderr.strip().splitlines()[-3:]}")
    except Exception as e:          # noqa: BLE001 - sweep must keep going
        doc = {"schema": 1, "kind": "sweep-job", "key": point.key,
               "config": {**point.to_obj(), "smoke": smoke},
               "ok": False, "wall_s": time.perf_counter() - t0,
               "metrics": {}, "error": f"{type(e).__name__}: {e}"}
    if verbose:
        m = doc.get("metrics", {})
        status = "ok" if doc.get("ok") else \
            f"FAILED ({doc.get('error', 'job reported not ok')})"
        print(f"  {point.key}: {status}  wall={doc.get('wall_s', 0):.1f}s "
              f"p50={m.get('step_p50_ms', float('nan')):.0f}ms "
              f"completed={m.get('completed', 0):.0f}"
              f"/{m.get('submitted', 0):.0f}")
        sys.stdout.flush()
    return doc


def run_sweep(points: Sequence[SweepPoint], *, smoke: bool = True,
              out_path: str = "", history_path: str = "",
              trace_dir: str = "", merged_trace_path: str = "",
              max_iters: int = 0, verbose: bool = True) -> dict:
    meta = sweep_meta()
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
    if verbose:
        print(f"sweep: {len(points)} points "
              f"({'smoke' if smoke else 'full'} tier)")
    jobs, trace_docs, trace_names = {}, [], []
    t0 = time.perf_counter()
    for point in points:
        trace_out = os.path.join(
            trace_dir, f"trace_{point.key.replace('/', '_')}.json") \
            if trace_dir else ""
        doc = run_job(point, smoke=smoke, trace_out=trace_out,
                      max_iters=max_iters, verbose=verbose)
        jobs[point.key] = doc
        if history_path:
            append_entry(history_path, sweep_history_entry(doc, meta))
        if trace_out and os.path.exists(trace_out):
            with open(trace_out) as f:
                trace_docs.append(json.load(f))
            trace_names.append(f"sweep:{point.key}")
    report = {
        "schema": 1,
        "kind": "sweep",
        "smoke": smoke,
        "meta": meta,
        "total_wall_s": time.perf_counter() - t0,
        "points": len(points),
        "failed": sum(1 for d in jobs.values() if not d.get("ok")),
        "jobs": jobs,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
        if verbose:
            print(f"wrote {out_path}")
    if merged_trace_path and trace_docs:
        from repro.obs import merge_traces
        merged = merge_traces(trace_docs, names=trace_names)
        merged.setdefault("otherData", {})["sweep_meta"] = meta
        with open(merged_trace_path, "w") as f:
            json.dump(merged, f)
        if verbose:
            print(f"wrote {merged_trace_path} "
                  f"({len(merged['traceEvents'])} events)")
    if history_path and verbose:
        print(f"appended {len(jobs)} history entries to {history_path}")
    return report


def summarize(report: dict) -> str:
    """One-paragraph text summary (the CLI's exit message)."""
    jobs = report.get("jobs", {})
    ok = sum(1 for d in jobs.values() if d.get("ok"))
    lines = [f"sweep: {ok}/{len(jobs)} points ok in "
             f"{report.get('total_wall_s', 0.0):.1f}s"]
    for key, doc in sorted(jobs.items()):
        m = doc.get("metrics", {})
        mark = "ok " if doc.get("ok") else "ERR"
        lines.append(
            f"  [{mark}] {key}: p50={m.get('step_p50_ms', float('nan')):.0f}"
            f"ms tok/s={m.get('throughput_tok_s', float('nan')):.1f}")
    return "\n".join(lines)
