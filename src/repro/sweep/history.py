"""Trend database: an append-only JSONL of measurements, read back as
per-(bench, metric, config-key) series.

Two line kinds share ``benchmarks/history.jsonl``:

  * ``kind: "bench"`` — one ``benchmarks/run.py --history`` document per
    commit (per-bench wall/ok plus every summary metric);
  * ``kind: "sweep"`` — one line per sweep job, filed under the job's
    config-key so the same metric tracks separately per (mesh x workload
    x strategy) point.

Legacy lines (pre-sweep, no ``kind`` field) are read as bench entries so
the existing trajectory keeps counting.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Tuple

SeriesKey = Tuple[str, str, str]          # (bench, metric, config_key)

# Relative first->last change over the trend window before a metric is
# flagged as drifting (only when the window moves monotonically — noise
# wobbles both ways, drift doesn't).
DRIFT_REL = 0.10
DRIFT_MIN_POINTS = 4


def append_entry(path: str, entry: dict) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")


def load_history(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    entries = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                entries.append(json.loads(line))
            except json.JSONDecodeError:
                continue              # a torn write must not kill the gate
    return entries


def bench_history_entry(doc: dict) -> dict:
    """History line for a ``run.py --json`` schema-2 document."""
    meta = doc.get("meta", {})
    return {
        "kind": "bench",
        "git_sha": meta.get("git_sha", "unknown"),
        "timestamp_utc": meta.get("timestamp_utc", ""),
        "smoke": doc.get("smoke", False),
        "total_wall_s": doc.get("total_wall_s", 0.0),
        "benches": {
            name: {"wall_us": rec.get("wall_us", 0.0),
                   "ok": bool(rec.get("ok")),
                   "summary": rec.get("summary") or {}}
            for name, rec in doc.get("benches", {}).items()},
    }


def sweep_history_entry(job_doc: dict, meta: dict) -> dict:
    """History line for one sweep job document (``sweep.job``)."""
    return {
        "kind": "sweep",
        "git_sha": meta.get("git_sha", "unknown"),
        "timestamp_utc": meta.get("timestamp_utc", ""),
        "smoke": bool(job_doc.get("config", {}).get("smoke")),
        "key": job_doc["key"],
        "ok": bool(job_doc.get("ok")),
        "wall_s": job_doc.get("wall_s", 0.0),
        "metrics": job_doc.get("metrics", {}),
    }


def _numeric(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def series(entries: Iterable[dict]) -> Dict[SeriesKey, List[Tuple[str, float]]]:
    """(bench, metric, config_key) -> [(timestamp, value)] in file order."""
    out: Dict[SeriesKey, List[Tuple[str, float]]] = {}

    def add(key: SeriesKey, ts: str, value) -> None:
        if _numeric(value):
            out.setdefault(key, []).append((ts, float(value)))
        elif isinstance(value, bool):
            out.setdefault(key, []).append((ts, 1.0 if value else 0.0))

    for e in entries:
        ts = e.get("timestamp_utc", "")
        kind = e.get("kind", "bench")
        if kind == "sweep":
            cfg = e.get("key", "unknown")
            add(("sweep", "wall_s", cfg), ts, e.get("wall_s"))
            add(("sweep", "ok", cfg), ts, e.get("ok"))
            for m, v in (e.get("metrics") or {}).items():
                add(("sweep", m, cfg), ts, v)
        else:
            add(("run", "total_wall_s", "default"), ts, e.get("total_wall_s"))
            for name, rec in (e.get("benches") or {}).items():
                add((name, "wall_us", "default"), ts, rec.get("wall_us"))
                add((name, "ok", "default"), ts, rec.get("ok"))
                for m, v in (rec.get("summary") or {}).items():
                    add((name, m, "default"), ts, v)
    return out


def trend(values: List[float], last_n: int = 8) -> dict:
    """Summary of the last ``last_n`` points of one series, with a drift
    flag: monotonic AND moved more than DRIFT_REL relative overall."""
    window = [v for v in values[-last_n:]]
    n = len(window)
    if n == 0:
        return {"n": 0, "first": float("nan"), "last": float("nan"),
                "mean": float("nan"), "rel_change": 0.0, "drifting": False}
    first, last = window[0], window[-1]
    denom = abs(first) if first else 1.0
    rel = (last - first) / denom
    diffs = [b - a for a, b in zip(window, window[1:])]
    monotonic = n >= DRIFT_MIN_POINTS and (
        all(d >= 0 for d in diffs) or all(d <= 0 for d in diffs))
    return {
        "n": n,
        "first": first,
        "last": last,
        "mean": sum(window) / n,
        "rel_change": rel,
        "drifting": bool(monotonic and abs(rel) > DRIFT_REL),
    }
