"""Markdown trend rendering for the history database (the table CI
appends to the GitHub Actions job summary)."""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

from repro.sweep.history import (SeriesKey, load_history, series, trend)
from repro.sweep.references import bounds

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values: List[float], width: int = 12) -> str:
    if not values:
        return ""
    vals = values[-width:]
    lo, hi = min(vals), max(vals)
    if hi <= lo:
        return _SPARK[0] * len(vals)
    return "".join(
        _SPARK[int((v - lo) / (hi - lo) * (len(_SPARK) - 1))] for v in vals)


def _fmt(v: float) -> str:
    if v != v:
        return "-"
    if abs(v) >= 1e6:
        return f"{v:.3g}"
    if abs(v) >= 100:
        return f"{v:.0f}"
    return f"{v:.3g}"


def _ref_cell(refs: Optional[dict], key: SeriesKey) -> str:
    if not refs:
        return ""
    bench, metric, _ = key
    if bench == "run" and metric == "total_wall_s":
        tup = refs.get("total_wall_s")
    else:
        tup = (refs.get("benches") or {}).get(bench, {}).get(metric)
    if not tup:
        return "-"
    lo, hi = bounds(float(tup[0]), tup[1], tup[2])
    lo_s = "-inf" if lo is None else _fmt(lo)
    hi_s = "inf" if hi is None else _fmt(hi)
    return f"[{lo_s}, {hi_s}]"


def trend_table(series_map: Dict[SeriesKey, List[Tuple[str, float]]],
                *, last_n: int = 8, refs: Optional[dict] = None,
                benches: Optional[List[str]] = None) -> str:
    """One markdown row per (bench, metric, config-key) series."""
    header = "| bench | metric | config | n | latest | mean | Δ | trend |"
    sep = "|---|---|---|---|---|---|---|---|"
    if refs is not None:
        header = header[:-1] + " ref band |"
        sep += "---|"
    rows = [header, sep]
    for key in sorted(series_map):
        bench, metric, cfg = key
        if benches is not None and bench not in benches:
            continue
        values = [v for _, v in series_map[key]]
        t = trend(values, last_n)
        delta = f"{100 * t['rel_change']:+.0f}%"
        if t["drifting"]:
            delta += " ⚠"
        row = (f"| {bench} | {metric} | {cfg} | {t['n']} "
               f"| {_fmt(t['last'])} | {_fmt(t['mean'])} | {delta} "
               f"| {sparkline(values)} |")
        if refs is not None:
            row += f" {_ref_cell(refs, key)} |"
        rows.append(row)
    return "\n".join(rows)


def drift_warnings(series_map: Dict[SeriesKey, List[Tuple[str, float]]],
                   *, last_n: int = 8) -> List[str]:
    out = []
    for (bench, metric, cfg), points in sorted(series_map.items()):
        t = trend([v for _, v in points], last_n)
        if t["drifting"]:
            out.append(
                f"{bench}.{metric} [{cfg}] drifted "
                f"{100 * t['rel_change']:+.0f}% monotonically over the "
                f"last {t['n']} entries ({_fmt(t['first'])} -> "
                f"{_fmt(t['last'])})")
    return out


def render_report(history_path: str, references_path: str = "",
                  *, last_n: int = 8, title: str = "Perf trend") -> str:
    refs = None
    if references_path and os.path.exists(references_path):
        with open(references_path) as f:
            refs = json.load(f)
    entries = load_history(history_path)
    smap = series(entries)
    lines = [f"## {title}",
             f"_{len(entries)} history entries, {len(smap)} series "
             f"(window: last {last_n})_", ""]
    if not smap:
        lines.append("_history is empty — run a sweep or "
                     "`benchmarks/run.py --history` first_")
        return "\n".join(lines)
    lines.append(trend_table(smap, last_n=last_n, refs=refs))
    warns = drift_warnings(smap, last_n=last_n)
    if warns:
        lines += ["", "### Drift warnings", ""]
        lines += [f"- ⚠ {w}" for w in warns]
    return "\n".join(lines)
