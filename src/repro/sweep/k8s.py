"""k8s Job manifest emission for cluster-tier sweeps.

Each sweep point becomes one ``batch/v1`` Job running ``repro.sweep.job``
inside the provided image — the ReFrame-k8s-launcher shape: the local
runner and the cluster run share the exact per-point entrypoint and JSON
result contract, so a collector can feed cluster results into the same
trend database.

Manifests are written as YAML when PyYAML is importable and as JSON
otherwise (kubectl accepts both); nothing here imports kubernetes.
"""

from __future__ import annotations

import json
import os
import re
from typing import List, Sequence

from repro.sweep.matrix import SweepPoint

try:                                      # optional, never required
    import yaml as _yaml
except ImportError:                       # pragma: no cover - env specific
    _yaml = None

_DNS1123 = re.compile(r"^[a-z0-9]([-a-z0-9]*[a-z0-9])?$")


def manifest_name(point: SweepPoint, prefix: str = "sweep") -> str:
    """DNS-1123 label for the Job: lowercase alphanumerics and '-',
    <= 63 chars, deterministic per point."""
    raw = f"{prefix}-{point.key}"
    name = re.sub(r"[^a-z0-9]+", "-", raw.lower()).strip("-")
    return name[:63].rstrip("-")


def job_manifest(point: SweepPoint, *, image: str,
                 namespace: str = "default", smoke: bool = True,
                 cpu: str = "4", memory: str = "8Gi",
                 backoff_limit: int = 0) -> dict:
    args = ["--point", json.dumps(point.to_obj())]
    if smoke:
        args.append("--smoke")
    return {
        "apiVersion": "batch/v1",
        "kind": "Job",
        "metadata": {
            "name": manifest_name(point),
            "namespace": namespace,
            "labels": {
                "app": "repro-sweep",
                "sweep-mesh": point.mesh.key,
                "sweep-workload": point.workload,
                "sweep-strategy": point.strategy,
            },
        },
        "spec": {
            "backoffLimit": backoff_limit,
            "template": {
                "metadata": {"labels": {"app": "repro-sweep"}},
                "spec": {
                    "restartPolicy": "Never",
                    "containers": [{
                        "name": "sweep-job",
                        "image": image,
                        "command": ["python", "-m", "repro.sweep.job"],
                        "args": args,
                        "env": [
                            {"name": "XLA_FLAGS",
                             "value": "--xla_force_host_platform_device_"
                                      f"count={point.mesh.devices}"},
                            {"name": "PYTHONPATH", "value": "/app/src"},
                        ],
                        "resources": {
                            "requests": {"cpu": cpu, "memory": memory},
                            "limits": {"cpu": cpu, "memory": memory},
                        },
                    }],
                },
            },
        },
    }


def validate_manifest(manifest: dict) -> List[str]:
    """Schema sanity for a Job manifest (what the tests gate): required
    fields, DNS-1123 name, container command/image presence."""
    errors = []
    if manifest.get("apiVersion") != "batch/v1":
        errors.append(f"apiVersion must be batch/v1, "
                      f"got {manifest.get('apiVersion')!r}")
    if manifest.get("kind") != "Job":
        errors.append(f"kind must be Job, got {manifest.get('kind')!r}")
    name = (manifest.get("metadata") or {}).get("name", "")
    if not name or len(name) > 63 or not _DNS1123.match(name):
        errors.append(f"metadata.name {name!r} is not a DNS-1123 label")
    tmpl = ((manifest.get("spec") or {}).get("template") or {})
    pod = tmpl.get("spec") or {}
    if pod.get("restartPolicy") not in ("Never", "OnFailure"):
        errors.append("Job pods need restartPolicy Never/OnFailure, got "
                      f"{pod.get('restartPolicy')!r}")
    containers = pod.get("containers") or []
    if not containers:
        errors.append("spec.template.spec.containers is empty")
    for i, c in enumerate(containers):
        for field in ("name", "image", "command"):
            if not c.get(field):
                errors.append(f"containers[{i}].{field} missing")
    return errors


def write_manifests(points: Sequence[SweepPoint], out_dir: str, *,
                    image: str, namespace: str = "default",
                    smoke: bool = True) -> List[str]:
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for point in points:
        m = job_manifest(point, image=image, namespace=namespace,
                         smoke=smoke)
        errors = validate_manifest(m)
        if errors:
            raise ValueError(f"generated invalid manifest for "
                             f"{point.key}: {errors}")
        name = m["metadata"]["name"]
        if _yaml is not None:
            path = os.path.join(out_dir, f"{name}.yaml")
            with open(path, "w") as f:
                _yaml.safe_dump(m, f, sort_keys=False)
        else:
            path = os.path.join(out_dir, f"{name}.json")
            with open(path, "w") as f:
                json.dump(m, f, indent=2)
        paths.append(path)
    return paths
