"""Sweep specification and deterministic matrix expansion.

A ``SweepSpec`` names axes (model config x mesh shape x workload trace x
GPS strategy x seed); ``expand()`` takes the cartesian product in a fixed
axis order so the job list — and every job's ``key`` — is stable across
runs and machines. The key is the config-key under which the trend
database files the job's metrics, so determinism here is what makes
history comparable across commits.
"""

from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class MeshShape:
    """(data, model) axis sizes; ``model`` carries expert parallelism."""
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.data * self.model

    @property
    def key(self) -> str:
        return f"{self.data}x{self.model}"


def parse_mesh(text: str) -> MeshShape:
    """'2x4' -> MeshShape(2, 4)."""
    try:
        data, model = (int(p) for p in text.lower().split("x"))
    except ValueError:
        raise ValueError(f"mesh shape must look like '2x4', got {text!r}")
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got {text!r}")
    return MeshShape(data, model)


@dataclass(frozen=True)
class SweepPoint:
    """One fully-bound job of the matrix."""
    arch: str
    mesh: MeshShape
    workload: str
    strategy: str
    seed: int = 0
    reduced: bool = True

    @property
    def key(self) -> str:
        """Stable config-key: the trend-database series identifier."""
        return (f"{self.arch}@{self.mesh.key}/{self.workload}"
                f"/{self.strategy}/s{self.seed}")

    def to_obj(self) -> dict:
        d = dataclasses.asdict(self)
        d["mesh"] = self.mesh.key
        d["key"] = self.key
        return d

    @classmethod
    def from_obj(cls, obj: dict) -> "SweepPoint":
        return cls(arch=obj["arch"], mesh=parse_mesh(obj["mesh"]),
                   workload=obj["workload"], strategy=obj["strategy"],
                   seed=int(obj.get("seed", 0)),
                   reduced=bool(obj.get("reduced", True)))


@dataclass(frozen=True)
class SweepSpec:
    """Axes of the sweep; ``expand`` is their deterministic product."""
    archs: Tuple[str, ...] = ("mixtral-8x7b",)
    meshes: Tuple[MeshShape, ...] = (MeshShape(1, 4),)
    workloads: Tuple[str, ...] = ("skew_shift",)
    strategies: Tuple[str, ...] = ("dist_only",)
    seeds: Tuple[int, ...] = (0,)
    reduced: bool = True

    def expand(self) -> Tuple[SweepPoint, ...]:
        return tuple(
            SweepPoint(arch=a, mesh=m, workload=w, strategy=s, seed=seed,
                       reduced=self.reduced)
            for a, m, w, s, seed in itertools.product(
                self.archs, self.meshes, self.workloads, self.strategies,
                self.seeds))

    def restrict(self, *, meshes=None, workloads=None, strategies=None,
                 archs=None) -> "SweepSpec":
        """Filter axes (CI matrix legs pass ``--mesh`` to split the sweep
        across runners); unknown values raise so a typo'd leg fails fast."""
        def pick(have, want, label):
            if want is None:
                return have
            want = tuple(want)
            unknown = [w for w in want if w not in have]
            if unknown:
                raise ValueError(f"unknown {label}: {unknown} "
                                 f"(spec has {list(have)})")
            return want
        return dataclasses.replace(
            self,
            archs=pick(self.archs, archs, "arch"),
            meshes=pick(self.meshes, meshes, "mesh"),
            workloads=pick(self.workloads, workloads, "workload"),
            strategies=pick(self.strategies, strategies, "strategy"))


# Strategy axis values that are really balancing LEVERS: the job keeps
# the dist_only prediction mode and drives the token-rescheduling lever
# instead (repro.schedule). Kept on the same axis so the trend database
# files duplicate-vs-reschedule runs as sibling series of one sweep.
LEVER_STRATEGIES = ("reschedule", "both")

# The CI smoke tier: 2 meshes x 3 workloads (the acceptance floor), one
# EP-only mesh and one data x EP mesh so the topology term in step time
# is exercised, against a steady trace, a skew-shifting trace, and the
# decode-heavy trace (long steady decode tail — the fused paged-
# attention fast path's regime, feeding the decode_toks_per_s trend
# series) — each point also run with the reschedule /
# duplicate+reschedule levers so the combined strategy space has trend
# series from day one.
SMOKE_SPEC = SweepSpec(
    archs=("mixtral-8x7b",),
    meshes=(MeshShape(1, 4), MeshShape(2, 4)),
    workloads=("steady", "skew_shift", "decode_heavy"),
    strategies=("dist_only",) + LEVER_STRATEGIES,
)

# The cluster tier (k8s manifests / nightly): wider meshes, every
# workload dynamic, both prediction strategies — the configuration
# regimes across which the paper says the optimal strategy flips — plus
# the combined-lever legs.
FULL_SPEC = SweepSpec(
    archs=("mixtral-8x7b",),
    meshes=(MeshShape(1, 4), MeshShape(2, 2), MeshShape(2, 4),
            MeshShape(2, 8)),
    workloads=("steady", "skew_shift", "diurnal", "multi_tenant",
               "decode_heavy", "fleet_shift"),
    strategies=("dist_only", "token_to_expert") + LEVER_STRATEGIES,
)
