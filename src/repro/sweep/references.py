"""ReFrame-style per-metric performance references.

A references document (committed as ``benchmarks/references.json``)
declares, per bench, ``{metric: [ref, lower_tol, upper_tol]}``: the
expected value plus relative tolerances on each side (``null`` = that
side unbounded) — the same convention as ReFrame's
``reference = {metric: (value, lower, upper)}`` performance tuples.
``gate_document`` checks a fresh ``run.py --json`` schema-2 document
against every declared band; ``refresh_references`` rewrites the document
from a fresh measurement using per-metric-class default tolerances.

For a reference value of 0 the tolerances are absolute deviations
(a relative band around zero is always empty).
"""

from __future__ import annotations

import re
from typing import List, Optional, Tuple

# (pattern, (lower_tol, upper_tol)) — first match wins; metrics matching
# no rule are NOT given a reference on refresh (trend-tracked only), so
# noisy columns don't flap the gate. None = that side unbounded.
TOLERANCE_RULES: Tuple[Tuple[str, Tuple[Optional[float],
                                        Optional[float]]], ...] = (
    # correctness flags must not move at all
    (r"^ok$", (0.0, 0.0)),
    (r"(_ok$|^recompiled$|_recompiled$|bitexact)", (0.0, 0.0)),
    # quality ratios: bounded below (regression), unbounded above
    (r"speedup", (0.5, None)),
    (r"hidden_fraction", (0.5, None)),
    # dist-predictor accuracy is judged over however many windows the
    # controller happened to spend in dist_only — a 3-11 window sample
    # whose count is wall-clock sensitive, so the rate swings ~2x run to
    # run at the same sha. Band it loosely: only a collapse toward zero
    # (the predictor stops landing at all) should gate.
    (r"^pred_dist_hit_rate$", (0.8, None)),
    (r"hit_rate", (0.5, None)),
    (r"^throughput_", (0.8, None)),
    # fleet A/B: SLO attainment on both legs is bounded below (the
    # static leg's under-attainment is the experiment's premise, so it
    # too must not collapse — a static leg that stops starving means
    # the A/B no longer demonstrates anything); the arbiter leg must
    # keep committing at least one quota move (ref 1, floor at 0.1
    # catches the lever silently disengaging). fleet_step_p50_ms rides
    # the generic step_p rule below; fleet_recompiled the recompile
    # rule above.
    (r"fleet_.*attainment", (0.5, None)),
    (r"^fleet_arbiter_moves$", (0.9, None)),
    # token rescheduling: the realized absorbed fraction (1 - drops /
    # capacity overflow) must stay >= 0.5x its reference; rescue-round
    # a2a traffic must not silently vanish (that would mean the lever
    # stopped engaging) but may grow with trace shape
    (r"overflow_absorbed_frac$", (0.5, None)),
    (r"resched_a2a_bytes$", (0.9, 3.0)),
    # the reschedule leg must stay dropless (ref 0 -> absolute band)
    (r"resched_dropped_tokens$", (0.0, 0.0)),
    # decode fast path: wall-clock decode throughput must not collapse
    # (bounded below like other throughput columns); the decode-shaped
    # attention phase timing is bounded above like step timings. The
    # fused-vs-gather roofline ratio is caught by the "speedup" rule
    # above; raw attn_fused_us/attn_gather_us walls and the interpret-
    # mode A/B ratio (decode_ab_ratio) deliberately match no rule —
    # interpret-mode kernel walls are not meaningful perf references.
    (r"^decode_toks_per_s$", (0.8, None)),
    (r"^attn_phase_decode_us$", (None, 1.5)),
    # timings: bounded above (CI machines are ~2x noisy, so the band is
    # wide; order-of-magnitude regressions are what it must catch)
    (r"^wall_us$", (None, 1.0)),
    (r"step_p(50|99)_ms$", (None, 1.5)),
)

TOTAL_WALL_TOL: Tuple[Optional[float], Optional[float]] = (None, 0.5)


def classify_metric(name: str) -> Optional[Tuple[Optional[float],
                                                 Optional[float]]]:
    for pattern, tols in TOLERANCE_RULES:
        if re.search(pattern, name):
            return tols
    return None


def bounds(ref: float, lower_tol: Optional[float],
           upper_tol: Optional[float]) -> Tuple[Optional[float],
                                                Optional[float]]:
    """Concrete (lo, hi) band; relative to |ref|, absolute when ref==0."""
    scale = abs(ref) if ref else 1.0
    lo = None if lower_tol is None else ref - scale * lower_tol
    hi = None if upper_tol is None else ref + scale * upper_tol
    return lo, hi


def check_metric(name: str, value, ref_tuple) -> Optional[str]:
    """None if ``value`` sits inside the reference band, else a failure
    message. A missing value (None) is itself a failure: a metric that
    silently disappears is a regression of the measurement, not a pass."""
    if (not isinstance(ref_tuple, (list, tuple)) or len(ref_tuple) != 3
            or not isinstance(ref_tuple[0], (int, float))
            or isinstance(ref_tuple[0], bool)):
        return f"{name}: malformed reference {ref_tuple!r}"
    ref, lower_tol, upper_tol = ref_tuple
    if value is None:
        return f"{name}: metric missing from the current document " \
               f"(reference {ref:g})"
    if not isinstance(value, (int, float)) or isinstance(value, bool):
        return f"{name}: non-numeric value {value!r}"
    lo, hi = bounds(float(ref), lower_tol, upper_tol)
    if lo is not None and value < lo:
        return (f"{name}: {value:g} below reference band "
                f"[{lo:g}, {'inf' if hi is None else f'{hi:g}'}] "
                f"(ref {ref:g}, -{lower_tol:g})")
    if hi is not None and value > hi:
        return (f"{name}: {value:g} above reference band "
                f"[{'-inf' if lo is None else f'{lo:g}'}, {hi:g}] "
                f"(ref {ref:g}, +{upper_tol:g})")
    return None


def structural_failures(doc: dict) -> List[str]:
    """A truncated/failed run must never slip through as a pass."""
    failures = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    benches = doc.get("benches")
    if not isinstance(benches, dict) or not benches:
        failures.append("document has no benches (empty or missing "
                        "'benches' — truncated or failed run)")
    total = doc.get("total_wall_s")
    if not isinstance(total, (int, float)) or total <= 0:
        failures.append(f"document has no positive total_wall_s "
                        f"(got {total!r})")
    return failures


def _metric_value(rec: dict, metric: str):
    if metric == "ok":
        return 1.0 if rec.get("ok") else 0.0
    if metric == "wall_us":
        return rec.get("wall_us")
    return (rec.get("summary") or {}).get(metric)


def gate_document(doc: dict, refs: dict) -> Tuple[List[str], int]:
    """All reference-band violations of ``doc`` plus how many metric
    bands were checked (so an accidentally-empty references file is
    visible to the caller)."""
    failures = list(structural_failures(doc))
    checked = 0
    total_ref = refs.get("total_wall_s")
    if total_ref is not None:
        checked += 1
        msg = check_metric("total_wall_s", doc.get("total_wall_s"),
                           total_ref)
        if msg:
            failures.append(msg)
    benches = doc.get("benches") or {}
    for bench, metric_refs in (refs.get("benches") or {}).items():
        rec = benches.get(bench)
        if rec is None:
            failures.append(f"{bench}: bench disappeared from the suite "
                            f"({len(metric_refs)} referenced metrics)")
            checked += len(metric_refs)
            continue
        for metric, ref_tuple in metric_refs.items():
            checked += 1
            msg = check_metric(f"{bench}.{metric}",
                               _metric_value(rec, metric), ref_tuple)
            if msg:
                failures.append(msg)
    return failures, checked


def refresh_references(doc: dict, *, meta: Optional[dict] = None) -> dict:
    """Build a references document from a fresh measurement. Refuses
    structurally empty documents — refreshing from a truncated run would
    commit an empty gate."""
    empty = structural_failures(doc)
    if empty:
        raise ValueError("refusing to refresh references from a broken "
                         "document: " + "; ".join(empty))
    refs = {"schema": 1,
            "meta": dict(meta or doc.get("meta") or {}),
            "total_wall_s": [float(doc["total_wall_s"]), *TOTAL_WALL_TOL],
            "benches": {}}
    for bench, rec in doc["benches"].items():
        out = {"ok": [1.0 if rec.get("ok") else 0.0, 0.0, 0.0]}
        wall = rec.get("wall_us")
        if isinstance(wall, (int, float)):
            out["wall_us"] = [float(wall), *classify_metric("wall_us")]
        for metric, value in (rec.get("summary") or {}).items():
            tols = classify_metric(metric)
            if tols is None or metric in ("ok", "wall_us"):
                continue
            if isinstance(value, (int, float)) \
                    and not isinstance(value, bool):
                out[metric] = [float(value), tols[0], tols[1]]
        refs["benches"][bench] = out
    return refs
