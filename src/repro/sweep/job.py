"""One sweep point, run in-process: build the engine on the requested
mesh, replay the workload trace, print a single JSON result line.

Invoked by the runner as a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=<devices>`` already in
the environment (it must be set before jax first initialises, which is
why this module is never imported by the runner):

  XLA_FLAGS=... PYTHONPATH=src python -m repro.sweep.job \
      --point '{"arch": "mixtral-8x7b", "mesh": "1x4", ...}' --smoke

The last stdout line is the job document the runner collects; everything
else (engine chatter, XLA warnings) goes to stderr or earlier lines.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.sweep.matrix import SweepPoint

# Engine shape for sweep deployments (static: the same compiled program
# serves every workload of a point, so cross-point step times compare).
SMOKE_ENGINE = dict(max_slots=4, prefill_len=32, block_size=16, max_len=48,
                    predict_interval=4, dup_slots=1, metrics_window=4)
FULL_ENGINE = dict(max_slots=8, prefill_len=64, block_size=16, max_len=96,
                   predict_interval=4, dup_slots=1, metrics_window=8)

# Virtual-clock trace horizon per tier (seconds) and replay compression.
SMOKE_TRACE = dict(horizon=10.0, rate=1.5, time_scale=20.0, max_iters=40)
FULL_TRACE = dict(horizon=45.0, rate=1.5, time_scale=20.0, max_iters=400)

# Summary columns copied into the job's metric set (flat scalars only —
# these are the per-(metric, config-key) trend series).
SUMMARY_METRICS = (
    "completed", "preemptions", "throughput_tok_s", "throughput_req_s",
    "ttft_p50", "ttft_p99", "tpot_mean", "tpot_p99", "latency_p50",
    "latency_p99", "migration_replans", "migration_bytes_moved",
    "migration_stall_us", "migration_rejected",
    "dropped_tokens", "overflow_tokens", "overflow_absorbed_frac",
    "resched_a2a_bytes", "resched_plans",
    # decode fast path: wall-clock decode throughput and the
    # fused-vs-gather attention-compute roofline (alloc/live KV blocks)
    "decode_toks_per_s", "fused_vs_gather_speedup",
)


def run_point(point: SweepPoint, *, smoke: bool = True, trace_out: str = "",
              max_iters: int = 0, time_scale: float = 0.0) -> dict:
    from repro.configs.registry import get_config
    from repro.launch.mesh import make_dev_mesh
    from repro.models.transformer import init_model
    from repro.obs import SpanTracer
    from repro.serve import ContinuousConfig, ContinuousEngine
    from repro.sweep.workloads import build_workload
    from repro.workloads import to_serve_requests

    cfg = get_config(point.arch)
    if point.reduced:
        cfg = cfg.reduced()

    # lever legs of the strategy axis: keep dist_only prediction, drive
    # the token-rescheduling lever (matrix.LEVER_STRATEGIES)
    strategy, lever = point.strategy, "duplicate"
    if strategy in ("reschedule", "both"):
        strategy, lever = "dist_only", point.strategy

    mesh, ep_ranks = None, point.mesh.model
    if point.mesh.devices > 1:
        if jax.device_count() < point.mesh.devices:
            raise RuntimeError(
                f"point {point.key} needs {point.mesh.devices} devices, "
                f"have {jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={point.mesh.devices}"
                " before jax initialises)")
        mesh = make_dev_mesh(point.mesh.data, point.mesh.model)

    predictor = None
    if strategy == "token_to_expert":
        from repro.core.predictors import ConditionalProbabilityModel
        from repro.data.synthetic import make_routing_trace
        prof = make_routing_trace(
            num_sequences=32, seq_len=32, vocab=cfg.vocab_size,
            num_experts=cfg.moe.num_experts, num_layers=cfg.num_layers,
            skew=1.8, seed=point.seed)
        predictor = ConditionalProbabilityModel(
            cfg.num_layers, cfg.moe.num_experts, cfg.vocab_size
        ).fit(prof.experts, prof.tokens)

    shape = dict(SMOKE_ENGINE if smoke else FULL_ENGINE)
    replay = dict(SMOKE_TRACE if smoke else FULL_TRACE)
    if max_iters:
        replay["max_iters"] = max_iters
    if time_scale:
        replay["time_scale"] = time_scale

    tracer = SpanTracer(process_name=f"sweep:{point.key}") \
        if trace_out else None
    ccfg = ContinuousConfig(strategy=strategy, lever=lever, **shape)
    params = init_model(jax.random.PRNGKey(point.seed), cfg)
    eng = ContinuousEngine(cfg, params, ccfg, mesh=mesh, ep_ranks=ep_ranks,
                           predictor=predictor, tracer=tracer)
    eng.warmup()

    trace = build_workload(point.workload, cfg.vocab_size,
                           horizon=replay["horizon"], rate=replay["rate"],
                           seed=point.seed)
    for r in sorted(to_serve_requests(trace), key=lambda r: r.arrival):
        eng.submit(r)

    # run_trace's virtual clock, with per-step walls kept for percentiles
    walls = []
    now, iters = 0.0, 0
    t_job = time.perf_counter()
    while eng.has_work() and iters < replay["max_iters"]:
        sched = eng.scheduler
        if (not sched.active_slots and sched.waiting
                and sched.waiting[0].arrival > now):
            now = sched.waiting[0].arrival
        t0 = time.perf_counter()
        start = now
        eng.step(start, clock=lambda: start + (
            time.perf_counter() - t0) * replay["time_scale"])
        dt = time.perf_counter() - t0
        walls.append(dt)
        now = start + dt * replay["time_scale"]
        iters += 1
    wall_s = time.perf_counter() - t_job

    recompiled = 0
    try:
        eng.assert_no_recompiles()
    except AssertionError:
        recompiled = 1
    eng.metrics.flush(eng._plan_stack, eng.ep_ranks, ccfg.dup_slots)
    s = eng.metrics.summary()

    metrics = {
        "step_p50_ms": float(np.percentile(walls, 50) * 1e3),
        "step_p99_ms": float(np.percentile(walls, 99) * 1e3),
        "steps": float(iters),
        "submitted": float(len(trace)),
        "recompiled": float(recompiled),
        "drained_ok": float(not eng.has_work()),
    }
    for k in SUMMARY_METRICS:
        if k in s:
            metrics[k] = float(s[k])

    if tracer is not None:
        tracer.export(trace_out, extra={"sweep_point": point.to_obj()})

    return {
        "schema": 1,
        "kind": "sweep-job",
        "key": point.key,
        "config": {**point.to_obj(), "smoke": smoke, **replay,
                   "engine": shape},
        "ok": bool(metrics["drained_ok"]) and not recompiled,
        "wall_s": wall_s,
        "metrics": metrics,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--point", required=True,
                    help="JSON SweepPoint (see matrix.SweepPoint.to_obj)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace-out", default="")
    ap.add_argument("--max-iters", type=int, default=0)
    ap.add_argument("--time-scale", type=float, default=0.0)
    args = ap.parse_args(argv)
    point = SweepPoint.from_obj(json.loads(args.point))
    doc = run_point(point, smoke=args.smoke, trace_out=args.trace_out,
                    max_iters=args.max_iters, time_scale=args.time_scale)
    sys.stdout.flush()
    print(json.dumps(doc))
    return 0 if doc["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
