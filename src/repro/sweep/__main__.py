"""Sweep CLI.

  PYTHONPATH=src python -m repro.sweep run --smoke \
      [--mesh 1x4 --mesh 2x4] [--workload steady ...] [--strategy ...] \
      [--out SWEEP_report.json] [--history benchmarks/history.jsonl] \
      [--trace-dir sweep-traces] [--merged-trace SWEEP_trace.json]

  PYTHONPATH=src python -m repro.sweep report \
      [--history benchmarks/history.jsonl] \
      [--references benchmarks/references.json] [--last 8] [--out FILE]

  PYTHONPATH=src python -m repro.sweep manifests --out-dir k8s/ \
      [--image IMAGE] [--namespace NS] [--full]

  PYTHONPATH=src python -m repro.sweep collect --dir RESULTS_DIR \
      [--history benchmarks/history.jsonl] [--pattern '*.json']
"""

from __future__ import annotations

import argparse
import sys

from repro.sweep.matrix import FULL_SPEC, SMOKE_SPEC, parse_mesh


def _spec_from_args(args):
    spec = SMOKE_SPEC if args.smoke else FULL_SPEC
    return spec.restrict(
        meshes=[parse_mesh(m) for m in args.mesh] if args.mesh else None,
        workloads=args.workload or None,
        strategies=args.strategy or None,
        archs=args.arch or None)


def _add_axis_filters(ap):
    ap.add_argument("--mesh", action="append", default=[],
                    help="restrict to mesh shape(s), e.g. --mesh 2x4 "
                         "(the CI matrix-leg knob; repeatable)")
    ap.add_argument("--workload", action="append", default=[])
    ap.add_argument("--strategy", action="append", default=[])
    ap.add_argument("--arch", action="append", default=[])


def cmd_run(args) -> int:
    from repro.sweep.runner import run_sweep, summarize
    points = _spec_from_args(args).expand()
    if not points:
        print("sweep matrix is empty", file=sys.stderr)
        return 2
    report = run_sweep(points, smoke=args.smoke, out_path=args.out,
                       history_path=args.history, trace_dir=args.trace_dir,
                       merged_trace_path=args.merged_trace,
                       max_iters=args.max_iters)
    print(summarize(report))
    return 1 if report["failed"] else 0


def cmd_report(args) -> int:
    from repro.sweep.report import render_report
    md = render_report(args.history, args.references, last_n=args.last,
                       title=args.title)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")
        print(f"wrote {args.out}")
    else:
        print(md)
    return 0


def cmd_manifests(args) -> int:
    from repro.sweep.k8s import write_manifests
    spec = _spec_from_args(args)
    points = spec.expand()
    paths = write_manifests(points, args.out_dir, image=args.image,
                            namespace=args.namespace, smoke=args.smoke)
    print(f"wrote {len(paths)} Job manifests to {args.out_dir}")
    for p in paths:
        print(f"  {p}")
    return 0


def cmd_collect(args) -> int:
    from repro.sweep.collect import collect_results
    from repro.sweep.runner import sweep_meta
    report = collect_results(args.dir, args.history, meta=sweep_meta(),
                             pattern=args.pattern)
    print(report.summarize())
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweep",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    run_p = sub.add_parser("run", help="execute the sweep locally")
    run_p.add_argument("--smoke", action="store_true",
                       help="smoke tier (SMOKE_SPEC; default is FULL_SPEC)")
    _add_axis_filters(run_p)
    run_p.add_argument("--out", default="SWEEP_report.json")
    run_p.add_argument("--history", default="",
                       help="append one line per job to this JSONL trend db")
    run_p.add_argument("--trace-dir", default="",
                       help="write one Perfetto trace per job here")
    run_p.add_argument("--merged-trace", default="",
                       help="write the merged Perfetto trace here")
    run_p.add_argument("--max-iters", type=int, default=0)
    run_p.set_defaults(fn=cmd_run)

    rep_p = sub.add_parser("report", help="render the markdown trend table")
    rep_p.add_argument("--history", default="benchmarks/history.jsonl")
    rep_p.add_argument("--references", default="benchmarks/references.json")
    rep_p.add_argument("--last", type=int, default=8)
    rep_p.add_argument("--title", default="Perf trend")
    rep_p.add_argument("--out", default="")
    rep_p.set_defaults(fn=cmd_report)

    man_p = sub.add_parser("manifests", help="emit k8s Job manifests")
    man_p.add_argument("--out-dir", required=True)
    man_p.add_argument("--image", default="repro-sweep:latest")
    man_p.add_argument("--namespace", default="default")
    man_p.add_argument("--smoke", action="store_true")
    _add_axis_filters(man_p)
    man_p.set_defaults(fn=cmd_manifests)

    col_p = sub.add_parser(
        "collect", help="ingest per-point cluster result docs into history")
    col_p.add_argument("--dir", required=True,
                       help="directory of completed sweep.job JSON docs")
    col_p.add_argument("--history", default="benchmarks/history.jsonl")
    col_p.add_argument("--pattern", default="*.json")
    col_p.set_defaults(fn=cmd_collect)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
