"""Named workload builders for sweep jobs.

Each entry maps a workload name (a matrix axis value) to a request trace
with a distinct skew dynamic, so the sweep exercises the regimes the GPS
guideline distinguishes: steady flat routing, a shifting hot topic,
diurnal load, and multi-tenant mixtures with opposed skew.
"""

from __future__ import annotations

from typing import List

from repro.workloads import (ShiftingCorpus, TenantSpec, Topic, TraceRequest,
                             make_trace, skew_shift_trace)


def _steady(vocab: int, horizon: float, rate: float,
            seed: int) -> List[TraceRequest]:
    """Poisson arrivals over a flat corpus: skew stays low, the baseline
    regime where duplication should mostly stay off."""
    flat = Topic("broad", zipf_alpha=0.4, vocab_frac=1.0, seed=1)
    corpus = ShiftingCorpus(vocab, [flat], schedule=[(0.0, [1.0])])
    spec = TenantSpec("steady", corpus, arrivals="poisson", rate=rate,
                      prompt_len_mean=24.0, prompt_len_max=64,
                      out_len_mean=6.0, out_len_max=16)
    return make_trace([spec], horizon, seed=seed)


def _skew_shift(vocab: int, horizon: float, rate: float,
                seed: int) -> List[TraceRequest]:
    return skew_shift_trace(vocab, horizon=horizon, rate=rate, seed=seed)


def _diurnal(vocab: int, horizon: float, rate: float,
             seed: int) -> List[TraceRequest]:
    return skew_shift_trace(vocab, horizon=horizon, rate=rate, seed=seed,
                            arrivals="diurnal")


def _multi_tenant(vocab: int, horizon: float, rate: float,
                  seed: int) -> List[TraceRequest]:
    """Two tenants whose hot topics peak at opposite ends of the session,
    so aggregate skew never settles."""
    broad = Topic("broad", zipf_alpha=0.5, vocab_frac=1.0, seed=1)
    hot_a = Topic("hot-a", zipf_alpha=3.0, vocab_frac=0.05, seed=2)
    hot_b = Topic("hot-b", zipf_alpha=3.0, vocab_frac=0.05, seed=3)
    corpus_a = ShiftingCorpus(vocab, [broad, hot_a], schedule=[
        (0.0, [0.2, 0.8]), (0.5 * horizon, [0.9, 0.1]),
        (horizon, [1.0, 0.0])])
    corpus_b = ShiftingCorpus(vocab, [broad, hot_b], schedule=[
        (0.0, [1.0, 0.0]), (0.5 * horizon, [0.9, 0.1]),
        (horizon, [0.2, 0.8])])
    tenants = [
        TenantSpec("tenant-a", corpus_a, arrivals="bursty", rate=rate / 2,
                   prompt_len_mean=24.0, prompt_len_max=64,
                   out_len_mean=6.0, out_len_max=16),
        TenantSpec("tenant-b", corpus_b, arrivals="poisson", rate=rate / 2,
                   prompt_len_mean=24.0, prompt_len_max=64,
                   out_len_mean=6.0, out_len_max=16),
    ]
    return make_trace(tenants, horizon, seed=seed)


def _decode_heavy(vocab: int, horizon: float, rate: float,
                  seed: int) -> List[TraceRequest]:
    """Decode-bound regime: sparse arrivals with short prompts and long
    generation budgets, so after a brief prefill warmup the engine sits in
    a steady decode tail — the state the fused paged-attention kernel (and
    the KindWindowEMA's decode window) is sized for. Output budgets stay
    within the smoke sweep engine's max_len=48 / max_iters bounds (prompt
    <= 16 + out <= 24, sparse arrivals so late tails drain in budget)
    while output tokens still dominate ~2-3x."""
    flat = Topic("broad", zipf_alpha=0.6, vocab_frac=1.0, seed=1)
    corpus = ShiftingCorpus(vocab, [flat], schedule=[(0.0, [1.0])])
    spec = TenantSpec("decode-heavy", corpus, arrivals="poisson",
                      rate=rate / 3, prompt_len_mean=8.0, prompt_len_max=16,
                      out_len_mean=12.0, out_len_max=24)
    return make_trace([spec], horizon, seed=seed)


def _fleet_shift(vocab: int, horizon: float, rate: float,
                 seed: int) -> List[TraceRequest]:
    """The fleet A/B trace: an interactive chat tenant whose load ramps
    up monotonically through the session (diurnal thinning with period
    4x horizon: rate -> 2x rate) while its corpus concentrates on a hot
    topic, against a steady flat batch tenant. Under a static equal HBM
    split the chat model starves as the shift lands; the cross-model
    arbiter should move KV/dup-slot quota toward it."""
    broad = Topic("broad", zipf_alpha=0.5, vocab_frac=1.0, seed=1)
    hot = Topic("hot", zipf_alpha=3.0, vocab_frac=0.05, seed=2)
    corpus_chat = ShiftingCorpus(vocab, [broad, hot], schedule=[
        (0.0, [1.0, 0.0]), (0.4 * horizon, [0.3, 0.7]),
        (horizon, [0.2, 0.8])])
    corpus_batch = ShiftingCorpus(vocab, [broad], schedule=[(0.0, [1.0])])
    tenants = [
        TenantSpec("chat", corpus_chat, arrivals="diurnal", rate=rate,
                   diurnal_amplitude=1.0, diurnal_period=4.0 * horizon,
                   prompt_len_mean=24.0, prompt_len_max=64,
                   out_len_mean=6.0, out_len_max=16),
        TenantSpec("batch", corpus_batch, arrivals="poisson", rate=rate / 2,
                   prompt_len_mean=24.0, prompt_len_max=64,
                   out_len_mean=8.0, out_len_max=16),
    ]
    return make_trace(tenants, horizon, seed=seed)


WORKLOADS = {
    "steady": _steady,
    "skew_shift": _skew_shift,
    "diurnal": _diurnal,
    "multi_tenant": _multi_tenant,
    "decode_heavy": _decode_heavy,
    "fleet_shift": _fleet_shift,
}


def build_workload(name: str, vocab: int, *, horizon: float, rate: float,
                   seed: int = 0) -> List[TraceRequest]:
    try:
        builder = WORKLOADS[name]
    except KeyError:
        raise ValueError(
            f"unknown workload {name!r} (have {sorted(WORKLOADS)})")
    return builder(vocab, horizon, rate, seed)
