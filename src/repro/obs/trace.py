"""Low-overhead span/event tracer with Chrome trace-event JSON export.

The serving stack's phase timings (``moe/profile``), migration ticks
(``runtime/migrate``), plan switches, and GPS verdicts today dead-end in
flat metric floats. This tracer turns them into an inspectable timeline:

  * monotonic clock (``time.perf_counter_ns`` — never wall time, so spans
    are immune to NTP steps and match the engines' duration clocks);
  * fixed-capacity ring buffer (old events are overwritten, a ``dropped``
    counter keeps the loss honest — tracing must never grow memory
    unboundedly under a million-user serving loop);
  * nestable spans (per-thread stack, so ``with tracer.span("step")``
    inside ``span("replay")`` renders as a child) and thread safety (one
    lock around the buffer append — the only shared mutation);
  * named *tracks*: virtual threads (e.g. "migration", "gps",
    "dispatch-profile") that render as separate Perfetto rows;
  * a disabled mode whose per-call cost is one attribute check — the
    engines are instrumented unconditionally, so tracer-off overhead on
    the serving step must stay <1% (asserted by the bench gate).

Export follows the Chrome trace-event JSON-object format (the one
Perfetto and chrome://tracing load directly): complete ("X") events with
microsecond ``ts``/``dur``, instant ("i") events, counter ("C") series,
and process/thread-name metadata ("M"). ``validate_chrome_trace`` checks
a document against that schema; CI runs it on the bench trace artifact.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

# event tuples: (ph, name, cat, ts_ns, dur_ns, tid, args)
_PH_COMPLETE = "X"
_PH_INSTANT = "i"
_PH_COUNTER = "C"

# Chrome trace-event phases this module emits or the validator accepts.
KNOWN_PHASES = frozenset("XiCMbBEensOtPNDvR(){}S'TFpsfc")


class _NullSpan:
    """Reusable no-op context manager (disabled tracer / dropped spans)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set_args(self, **kw):
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    """Open span: records a complete ("X") event on exit."""
    __slots__ = ("tracer", "name", "cat", "tid", "args", "t0")

    def __init__(self, tracer: "SpanTracer", name: str, cat: str,
                 tid: int, args: Optional[dict]):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args
        self.t0 = 0

    def __enter__(self):
        self.t0 = time.perf_counter_ns()
        return self

    def set_args(self, **kw):
        """Attach/extend args after entry (e.g. counts known only once
        the work inside the span ran)."""
        self.args = {**(self.args or {}), **kw}

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self.tracer._append((_PH_COMPLETE, self.name, self.cat, self.t0,
                             t1 - self.t0, self.tid, self.args))
        return False


class SpanTracer:
    """Ring-buffered span/event recorder exporting Chrome trace JSON."""

    def __init__(self, capacity: int = 65536, enabled: bool = True,
                 process_name: str = "repro-serve", pid: int = 1):
        self.enabled = bool(enabled)
        self.capacity = max(int(capacity), 1)
        self.process_name = process_name
        self.pid = int(pid)
        self.dropped = 0
        self._buf: List[Tuple] = []
        self._head = 0                      # next overwrite index when full
        self._lock = threading.Lock()
        self._tracks: Dict[str, int] = {}   # track name -> synthetic tid
        self._next_track_tid = 1 << 20      # keep clear of real thread ids

    # ------------------------------------------------------------- recording
    def now_ns(self) -> int:
        return time.perf_counter_ns()

    def _append(self, ev: Tuple) -> None:
        with self._lock:
            if len(self._buf) < self.capacity:
                self._buf.append(ev)
            else:                           # ring: overwrite the oldest
                self._buf[self._head] = ev
                self._head = (self._head + 1) % self.capacity
                self.dropped += 1

    def _tid(self, track: Optional[str]) -> int:
        if track is None:
            return threading.get_ident() & 0xFFFFF
        tid = self._tracks.get(track)
        if tid is None:
            with self._lock:
                tid = self._tracks.setdefault(track, self._next_track_tid
                                              + len(self._tracks))
        return tid

    def span(self, name: str, cat: str = "serve",
             track: Optional[str] = None, args: Optional[dict] = None):
        """Context manager timing a nested span. Nesting is rendered by
        the viewer from containment (same tid + enclosing [ts, ts+dur))."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, self._tid(track), args)

    def instant(self, name: str, cat: str = "serve",
                track: Optional[str] = None,
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        self._append((_PH_INSTANT, name, cat, time.perf_counter_ns(), 0,
                      self._tid(track), args))

    def counter(self, name: str, value: float, cat: str = "serve",
                track: Optional[str] = None,
                series: str = "value") -> None:
        """One sample of a counter series (rendered as a Perfetto graph)."""
        if not self.enabled:
            return
        self._append((_PH_COUNTER, name, cat, time.perf_counter_ns(), 0,
                      self._tid(track), {series: float(value)}))

    def add_span(self, name: str, dur_s: float, *, ts_ns: Optional[int] = None,
                 cat: str = "serve", track: Optional[str] = None,
                 args: Optional[dict] = None) -> int:
        """Record a RETROSPECTIVE span of known duration (e.g. a phase
        timing measured by ``moe/profile`` outside any live span). Returns
        the span's end timestamp so callers can lay out a sequence.
        """
        if not self.enabled:
            return ts_ns or 0
        t0 = time.perf_counter_ns() if ts_ns is None else int(ts_ns)
        dur = max(int(dur_s * 1e9), 0)
        self._append((_PH_COMPLETE, name, cat, t0, dur, self._tid(track),
                      args))
        return t0 + dur

    # --------------------------------------------------------------- export
    def events(self) -> List[Tuple]:
        """Buffered events in emission order (oldest surviving first)."""
        with self._lock:
            return self._buf[self._head:] + self._buf[:self._head]

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON-object document (Perfetto-loadable)."""
        out = [{"ph": "M", "name": "process_name", "pid": self.pid, "tid": 0,
                "args": {"name": self.process_name}}]
        for track, tid in sorted(self._tracks.items(), key=lambda kv: kv[1]):
            out.append({"ph": "M", "name": "thread_name", "pid": self.pid,
                        "tid": tid, "args": {"name": track}})
        for ph, name, cat, ts_ns, dur_ns, tid, args in self.events():
            ev: Dict[str, Any] = {"ph": ph, "name": name, "cat": cat,
                                  "ts": ts_ns // 1000, "pid": self.pid,
                                  "tid": tid}
            if ph == _PH_COMPLETE:
                ev["dur"] = max(dur_ns // 1000, 1)   # sub-us spans stay visible
            elif ph == _PH_INSTANT:
                ev["s"] = "t"
            if args:
                ev["args"] = dict(args)
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "capacity": self.capacity}}

    def export(self, path: str, extra: Optional[Dict[str, Any]] = None) -> dict:
        """Write the Chrome trace JSON to ``path``; ``extra`` is merged
        into ``otherData`` (side-channel payloads like the GPS audit log
        ride along in the same artifact — viewers ignore unknown keys)."""
        doc = self.to_chrome()
        if extra:
            doc["otherData"].update(extra)
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc


#: Shared disabled tracer — instrument unconditionally, pay ~nothing.
NULL_TRACER = SpanTracer(capacity=1, enabled=False)


def merge_traces(docs: Sequence[Dict[str, Any]],
                 names: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    """Merge Chrome trace documents into one, re-keying pids so processes
    stay distinct rows (the bench merges the meshed-subprocess engine's
    trace into the driver's; the fleet merges one doc per model instance).

    Every distinct (input doc, original pid) pair gets a fresh pid, so
    the merge is collision-free for any number of docs including docs
    that already carry several processes. With ``names``, each merged
    process row is tagged with its doc's model/tenant name: a single-pid
    doc's process is renamed to exactly ``names[i]``; a multi-pid doc's
    processes become ``"{names[i]}/{original}"`` so sibling processes
    inside one doc stay distinguishable."""
    merged: Dict[str, Any] = {"traceEvents": [], "displayTimeUnit": "ms",
                              "otherData": {}}
    next_pid = 1
    for i, doc in enumerate(docs):
        events = doc.get("traceEvents", [])
        pid_map: Dict[Any, int] = {}
        for ev in events:
            p = ev.get("pid", 0)
            if p not in pid_map:
                pid_map[p] = next_pid
                next_pid += 1
        name = names[i] if names and i < len(names) else None
        multi = len(pid_map) > 1
        named_pids = set()
        for ev in events:
            ev = dict(ev)
            orig = ev.get("pid", 0)
            ev["pid"] = pid_map[orig]
            if (name is not None and ev.get("ph") == "M"
                    and ev.get("name") == "process_name"):
                old = (ev.get("args") or {}).get("name", orig)
                ev["args"] = {"name": f"{name}/{old}" if multi else name}
                named_pids.add(orig)
            merged["traceEvents"].append(ev)
        if name is not None:
            # docs missing a process_name metadata row still get tagged
            for orig, pid in pid_map.items():
                if orig not in named_pids:
                    merged["traceEvents"].append(
                        {"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0,
                         "args": {"name": f"{name}/{orig}" if multi
                                  else name}})
        for k, v in doc.get("otherData", {}).items():
            merged["otherData"][f"p{i + 1}_{k}" if k in merged["otherData"]
                                or len(docs) > 1 else k] = v
    return merged


def validate_chrome_trace(doc: Any) -> List[str]:
    """Validate a document against the Chrome trace-event JSON-object
    schema (the subset Perfetto requires to load it). Returns a list of
    human-readable errors — empty means the trace is loadable."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"top level must be a JSON object, got {type(doc).__name__}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["missing or non-list 'traceEvents'"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: event must be an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or ph not in KNOWN_PHASES:
            errors.append(f"{where}: bad phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errors.append(f"{where}: missing 'name'")
        if ph == "M":
            continue                      # metadata events carry no ts
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: missing/negative 'ts' ({ts!r})")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: 'X' event needs 'dur' >= 0")
        if "pid" not in ev or "tid" not in ev:
            errors.append(f"{where}: missing pid/tid")
        args = ev.get("args")
        if args is not None and not isinstance(args, dict):
            errors.append(f"{where}: 'args' must be an object")
        if len(errors) >= 50:
            errors.append("... (truncated)")
            break
    return errors


def span_names(doc: Any) -> set:
    """Names of all non-metadata events in a trace document. Tolerates
    malformed documents (returns an empty set) so the validate CLI can
    report schema errors instead of crashing."""
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else []
    return {ev.get("name") for ev in events
            if isinstance(ev, dict) and ev.get("ph") != "M"}
