"""Metrics registry: counters, gauges, and histograms with label sets.

``ServeMetrics.summary()`` publishes every serving metric through a
registry instead of a hand-rolled dict, so one store feeds three sinks:

  * the flat ``{name: value}`` summary dict the benchmarks embed in
    their ``--json`` schema (unchanged keys — ``snapshot()``);
  * a Prometheus text-format exposition (``to_prometheus``) scrapeable
    from a file or a trivial HTTP handler;
  * JSONL (``to_jsonl``) for the trend database the regression harness
    appends to (``benchmarks/history.jsonl``).

Families are registered idempotently (asking for an existing name with
the same type returns the same family; a type conflict raises), so
``summary()`` can be called repeatedly without duplicating series.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

_DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                    0.5, 1.0, 2.5, 5.0, 10.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(key: LabelKey, extra: Sequence[Tuple[str, str]] = ()
                   ) -> str:
    items = list(key) + list(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


class Counter:
    """Monotonic counter child (one label set)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += float(amount)


class Gauge:
    """Set-to-current-value child."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += float(amount)


class Histogram:
    """Cumulative-bucket histogram child (Prometheus semantics)."""
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float]):
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)    # +1: +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> List[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper edge of the bucket
        holding the q-th observation)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        for i, cum in enumerate(self.cumulative()):
            if cum >= target:
                return (self.buckets[i] if i < len(self.buckets)
                        else math.inf)
        return math.inf


class _Family:
    def __init__(self, name: str, kind: str, help: str, buckets=None):
        self.name = name
        self.kind = kind
        self.help = help
        self.buckets = buckets
        self.children: Dict[LabelKey, object] = {}

    def labels(self, **labels: str):
        key = _label_key(labels)
        child = self.children.get(key)
        if child is None:
            child = {"counter": Counter, "gauge": Gauge}.get(self.kind,
                     lambda: Histogram(self.buckets))()
            self.children[key] = child
        return child


class MetricsRegistry:
    """Registry of metric families; thread-safe registration."""

    def __init__(self):
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _register(self, name: str, kind: str, help: str,
                  buckets=None) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind}")
                return fam
            fam = _Family(name, kind, help, buckets)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._register(name, "counter", help).labels(**labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._register(name, "gauge", help).labels(**labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = _DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._register(name, "histogram", help,
                              buckets=tuple(buckets)).labels(**labels)

    # --------------------------------------------------------------- export
    def snapshot(self) -> Dict[str, float]:
        """Flat ``{name{labels}: value}`` view (histograms expose
        ``_sum``/``_count``). This is what ``ServeMetrics.summary()``
        returns to its callers."""
        out: Dict[str, float] = {}
        for fam in self._families.values():
            for key, child in fam.children.items():
                suffix = _render_labels(key)
                if isinstance(child, Histogram):
                    out[f"{fam.name}_sum{suffix}"] = child.sum
                    out[f"{fam.name}_count{suffix}"] = float(child.count)
                else:
                    out[f"{fam.name}{suffix}"] = child.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines: List[str] = []
        for fam in sorted(self._families.values(), key=lambda f: f.name):
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in sorted(fam.children.items()):
                if isinstance(child, Histogram):
                    cum = child.cumulative()
                    edges = [str(b) for b in child.buckets] + ["+Inf"]
                    for edge, c in zip(edges, cum):
                        lab = _render_labels(key, [("le", edge)])
                        lines.append(f"{fam.name}_bucket{lab} {c}")
                    lines.append(
                        f"{fam.name}_sum{_render_labels(key)} {child.sum}")
                    lines.append(
                        f"{fam.name}_count{_render_labels(key)} {child.count}")
                else:
                    lines.append(
                        f"{fam.name}{_render_labels(key)} {child.value}")
        return "\n".join(lines) + "\n"

    def to_jsonl(self, path: str, extra: Optional[dict] = None,
                 mode: str = "a") -> None:
        """Append one JSON line per metric family (trend-database form)."""
        with open(path, mode) as f:
            for fam in sorted(self._families.values(), key=lambda f_: f_.name):
                for key, child in sorted(fam.children.items()):
                    rec = {"metric": fam.name, "type": fam.kind,
                           "labels": dict(key)}
                    if isinstance(child, Histogram):
                        rec.update(sum=child.sum, count=child.count,
                                   buckets=list(child.buckets),
                                   bucket_counts=list(child.counts))
                    else:
                        rec["value"] = child.value
                    if extra:
                        rec.update(extra)
                    f.write(json.dumps(rec) + "\n")
