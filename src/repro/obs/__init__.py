"""Unified serving observability: span tracer with Chrome-trace/Perfetto
export (``trace``), metrics registry with Prometheus/JSONL exporters
(``metrics``), GPS decision audit log (``audit``), and predictor-accuracy
tracking (``accuracy``). See README "Observability"."""

from repro.obs.accuracy import (PredictorAccuracyTracker, WindowAccuracy,
                                hist_hit_rate, hist_kl, hist_l1)
from repro.obs.audit import GPSAuditLog, GPSAuditRecord
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.trace import (NULL_TRACER, SpanTracer, merge_traces,
                             span_names, validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "GPSAuditLog", "GPSAuditRecord", "Histogram",
    "MetricsRegistry", "NULL_TRACER", "PredictorAccuracyTracker",
    "SpanTracer", "WindowAccuracy", "hist_hit_rate", "hist_kl", "hist_l1",
    "merge_traces", "span_names", "validate_chrome_trace",
]
