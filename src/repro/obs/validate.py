"""Chrome trace-event schema validation CLI (the CI gate for trace
artifacts).

  PYTHONPATH=src python -m repro.obs.validate TRACE.json \
      [--require SPAN_NAME ...]

Exits non-zero when the document fails the trace-event schema (it would
not load in Perfetto) or a ``--require``d span/event name is absent.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs.trace import span_names, validate_chrome_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", help="trace JSON files to validate")
    ap.add_argument("--require", action="append", default=[],
                    metavar="NAME",
                    help="fail unless an event with this name is present")
    args = ap.parse_args(argv)

    failed = False
    for path in args.paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable: {e}", file=sys.stderr)
            failed = True
            continue
        errors = validate_chrome_trace(doc)
        names = span_names(doc)
        missing = [n for n in args.require if n not in names]
        events = doc.get("traceEvents", []) if isinstance(doc, dict) else []
        n_events = len(events)
        if errors or missing:
            failed = True
            print(f"{path}: INVALID ({n_events} events)", file=sys.stderr)
            for e in errors:
                print(f"  schema: {e}", file=sys.stderr)
            for n in missing:
                print(f"  missing required span/event: {n}", file=sys.stderr)
        else:
            print(f"{path}: OK ({n_events} events, "
                  f"{len(names)} distinct names)")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
