"""Predictor-accuracy tracking: predicted vs realized expert histograms.

The paper trades predictor accuracy against overhead; "Prediction Is All
MoE Needs" (arXiv 2404.16914) shows that accuracy drifts over a serving
session as expert load stabilises. This tracker makes the tradeoff
measurable at runtime: at every re-plan boundary the engine snapshots the
(L, E) distribution the predictor committed to (the one Algorithm 1 just
planned from) and, one prediction window later, scores it against the
expert histogram the window actually routed:

  * ``hit_rate`` — per-layer top-1 hot-expert agreement (did the planned
    hottest expert stay the hottest?), the quantity duplication quality
    depends on;
  * ``kl``       — KL(realized || predicted), the estimation error the
    simulator's ``eps`` models (paper Table 1);
  * ``l1``       — total-variation distance, a bounded [0, 1] drift column.

The window's ``strategy`` tag separates Distribution-Only error (EMA
staleness: the estimate lags a shifting distribution) from
Token-to-Expert error (model quality: the predictor's histogram simply
misses), the two failure modes the GPS guideline arbitrates between.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

_EPS = 1e-9


def hist_hit_rate(predicted: np.ndarray, realized: np.ndarray) -> float:
    """Fraction of layers whose predicted argmax expert matched the
    realized argmax."""
    p = np.asarray(predicted, np.float64)
    r = np.asarray(realized, np.float64)
    return float((p.argmax(axis=1) == r.argmax(axis=1)).mean())


def hist_kl(predicted: np.ndarray, realized: np.ndarray) -> float:
    """KL(realized || predicted) per layer, averaged (nats). Smoothed so
    experts the predictor zeroed out stay finite."""
    p = np.asarray(predicted, np.float64) + _EPS
    r = np.asarray(realized, np.float64) + _EPS
    p /= p.sum(axis=1, keepdims=True)
    r /= r.sum(axis=1, keepdims=True)
    return float((r * np.log(r / p)).sum(axis=1).mean())


def hist_l1(predicted: np.ndarray, realized: np.ndarray) -> float:
    """Total-variation distance per layer, averaged (in [0, 1])."""
    p = np.asarray(predicted, np.float64) + _EPS
    r = np.asarray(realized, np.float64) + _EPS
    p /= p.sum(axis=1, keepdims=True)
    r /= r.sum(axis=1, keepdims=True)
    return float(0.5 * np.abs(p - r).sum(axis=1).mean())


@dataclass
class WindowAccuracy:
    """Score of one prediction window."""
    index: int
    strategy: str          # dist_only | token_to_expert (the predictor used)
    tokens: float          # realized routed tokens in the window
    hit_rate: float
    kl: float
    l1: float


class PredictorAccuracyTracker:
    """Accumulates realized histograms against the window's prediction."""

    def __init__(self, num_layers: int, num_experts: int):
        self.num_layers = int(num_layers)
        self.num_experts = int(num_experts)
        self.windows: List[WindowAccuracy] = []
        self._pred: Optional[np.ndarray] = None
        self._strategy: str = ""
        self._realized: Optional[np.ndarray] = None

    def begin_window(self, predicted_dist: Optional[np.ndarray],
                     strategy: str) -> None:
        """Snapshot the (L, E) distribution a re-plan just committed to.
        ``None`` (strategy "none", or nothing predicted yet) records no
        window — there is no prediction to score."""
        self._pred = (None if predicted_dist is None
                      else np.asarray(predicted_dist, np.float64).copy())
        self._strategy = strategy
        self._realized = None

    def observe(self, counts: Optional[np.ndarray]) -> None:
        """Feed one iteration's realized (L, E) expert histogram."""
        if counts is None:
            return
        c = np.asarray(counts, np.float64)
        self._realized = c.copy() if self._realized is None \
            else self._realized + c

    def close_window(self) -> Optional[WindowAccuracy]:
        """Score the open window; returns None when there was no
        prediction or no routed tokens to score it against."""
        pred, realized = self._pred, self._realized
        self._pred = None
        self._realized = None
        if pred is None or realized is None or realized.sum() <= 0:
            return None
        w = WindowAccuracy(index=len(self.windows), strategy=self._strategy,
                           tokens=float(realized.sum()),
                           hit_rate=hist_hit_rate(pred, realized),
                           kl=hist_kl(pred, realized),
                           l1=hist_l1(pred, realized))
        self.windows.append(w)
        return w

    # ----------------------------------------------------------- reporting
    def summary(self) -> Dict[str, float]:
        """Flat scalar columns for the bench JSON schema: overall means
        plus per-error-mode means (dist_only vs token_to_expert)."""
        out: Dict[str, float] = {"pred_windows": float(len(self.windows))}
        if not self.windows:
            return out
        def _mean(ws, attr):
            return float(np.mean([getattr(w, attr) for w in ws]))
        out["pred_hit_rate"] = _mean(self.windows, "hit_rate")
        out["pred_kl"] = _mean(self.windows, "kl")
        out["pred_l1"] = _mean(self.windows, "l1")
        for mode in ("dist_only", "token_to_expert"):
            ws = [w for w in self.windows if w.strategy == mode]
            if ws:
                key = "dist" if mode == "dist_only" else "t2e"
                out[f"pred_{key}_windows"] = float(len(ws))
                out[f"pred_{key}_hit_rate"] = _mean(ws, "hit_rate")
                out[f"pred_{key}_kl"] = _mean(ws, "kl")
        return out

    def to_obj(self) -> List[Dict]:
        return [{"index": w.index, "strategy": w.strategy,
                 "tokens": w.tokens, "hit_rate": w.hit_rate, "kl": w.kl,
                 "l1": w.l1} for w in self.windows]
