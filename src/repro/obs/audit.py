"""GPS decision audit log: every controller verdict with its full inputs.

The paper's thesis is that the right prediction strategy is a function of
measured system state — so every ``OnlineGPSController`` verdict must be
explainable post-hoc from the exact numbers it saw. Each evaluation
appends one ``GPSAuditRecord`` carrying the complete input vector fed to
``repro.core.gps.recommend_strategy`` (measured + transferred skew,
volatility, migration bytes/hidden fraction/amortized stall, simulator
operating point) plus the outcome (recommendation, hysteresis state, the
strategy actually in force, predicted savings per strategy), so a run can
be replayed and every switch — or refusal to switch — justified.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Any, Dict, List, Optional


@dataclass
class GPSAuditRecord:
    """One controller evaluation, inputs and outcome."""
    seq: int                         # evaluation index within the run
    t: float                         # engine clock at the verdict
    # ----------------------------------------------------- measured inputs
    window_iters: int                # iterations aggregated into the window
    skew_measured: float             # window_skew of the aggregated counts
    skew_input: float                # post skew-transfer, what run_gps saw
    volatility: float                # skew std/mean over recent windows
    migration_bytes: float           # replica bytes the window moved
    migration_hidden_bytes: float    # share hidden under forward compute
    migration_hidden_frac: float
    migration_stall_s: float         # amortized exposed stall charged
    # ------------------------------------------------- simulator operating
    batch: int
    seq_len: int
    allow_t2e: bool
    min_saving: float
    # ---------------------------------------------------------- the verdict
    recommended: str                 # what recommend_strategy returned
    strategy_before: str
    strategy_after: str              # in force after hysteresis
    gate: str                        # switched | pending | unchanged
    pending_votes: int
    predict_interval: int
    # ------------------------------------------- predicted economics (why)
    dist_only_saving: float = 0.0
    t2e_saving: float = 0.0
    baseline_total_s: float = 0.0
    best_total_s: float = 0.0
    # ------------------------------ combined strategy space (lever choice)
    # Fields below default so pre-lever JSONL rows stay schema-compatible.
    lever_recommended: str = "duplicate"
    lever_after: str = "duplicate"
    resched_saving: float = 0.0      # best reschedule-lever predicted saving
    resched_residual: float = 0.0    # scheduler residual imbalance fed in
    resched_extra_frac: float = 0.0  # rescue-round a2a surcharge fed in
    overflow_pred_frac: float = 0.0  # scheduler-predicted overflow absorbed
    overflow_realized_frac: float = -1.0  # engine-realized (-1 = no overflow)
    # Model instance this verdict belongs to (fleet serving: one audit log
    # per resident model). Defaults empty so pre-fleet JSONL rows load.
    model: str = ""

    def explain(self) -> str:
        verdict = (self.recommended if self.recommended == "none"
                   else f"{self.recommended}+{self.lever_recommended}")
        running = (self.strategy_after if self.strategy_after == "none"
                   else f"{self.strategy_after}+{self.lever_after}")
        resched = ""
        if self.lever_recommended in ("reschedule", "both") \
                or self.overflow_realized_frac >= 0.0:
            realized = ("?" if self.overflow_realized_frac < 0.0
                        else f"{self.overflow_realized_frac:.0%}")
            resched = (f"resched(save={self.resched_saving:.1%}, "
                       f"absorbed pred={self.overflow_pred_frac:.0%}/"
                       f"real={realized}) ")
        tag = f"{self.model} " if self.model else ""
        return (f"[{tag}{self.seq}] t={self.t:8.2f}s "
                f"skew={self.skew_measured:.2f}"
                f"->{self.skew_input:.2f} vol={self.volatility:.3f} "
                f"mig={self.migration_bytes / 1e6:.2f}MB "
                f"(hidden {self.migration_hidden_frac:.0%}, "
                f"stall {self.migration_stall_s * 1e6:.0f}us) "
                f"savings(dist={self.dist_only_saving:.1%}, "
                f"t2e={self.t2e_saving:.1%}) {resched}=> {verdict} "
                f"[{self.gate}] running={running} "
                f"interval={self.predict_interval}")


class GPSAuditLog:
    """Bounded append-only record of controller evaluations."""

    def __init__(self, maxlen: int = 4096, model: str = ""):
        self.maxlen = int(maxlen)
        self.model = model
        self.records: List[GPSAuditRecord] = []
        self.dropped = 0

    def append(self, rec: GPSAuditRecord) -> None:
        if self.model and not rec.model:
            rec.model = self.model
        if len(self.records) >= self.maxlen:
            self.records.pop(0)
            self.dropped += 1
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    @property
    def switches(self) -> List[GPSAuditRecord]:
        return [r for r in self.records if r.gate == "switched"]

    def to_obj(self) -> List[Dict[str, Any]]:
        return [asdict(r) for r in self.records]

    def to_jsonl(self, path: str, mode: str = "w") -> None:
        with open(path, mode) as f:
            for r in self.records:
                f.write(json.dumps(asdict(r)) + "\n")

    def explain(self, last: Optional[int] = None) -> str:
        recs = self.records if last is None else self.records[-last:]
        return "\n".join(r.explain() for r in recs)

    def summary(self) -> Dict[str, float]:
        n = len(self.records)
        return {
            "gps_verdicts": float(n),
            "gps_switches": float(len(self.switches)),
            "gps_t2e_verdicts": float(sum(
                r.recommended == "token_to_expert" for r in self.records)),
            "gps_none_verdicts": float(sum(
                r.recommended == "none" for r in self.records)),
            "gps_resched_verdicts": float(sum(
                r.recommended != "none"
                and r.lever_recommended in ("reschedule", "both")
                for r in self.records)),
        }
