"""Next-token cross-entropy over (possibly vocab-sharded) logits.

Sharding-aware formulation: `take_along_axis`/`argmax` along a sharded
vocab axis make GSPMD all-gather the full (tokens, V) logits — measured
at 44 GB/device on qwen train_4k (EXPERIMENTS.md §Perf). Instead:

  * gold logit  = sum(one_hot(label) * logits) — per-shard partial sums,
    XLA reduces with a cheap (tokens,)-sized all-reduce;
  * logsumexp   = reduction over V — partitions cleanly;
  * accuracy    = compare gold logit against the max logit (max is a
    clean sharded reduction; equality with the gold entry avoids the
    sharded argmax gather).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits, labels, mask=None):
    """logits: (B, S, V); labels: (B, S) int32. Returns (loss, metrics)."""
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, V, dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    top = logits.max(axis=-1)
    acc = (((gold >= top) & (labels >= 0)) * mask).sum() / denom
    return loss, {"nll": loss, "accuracy": acc}
