"""Checkpointing: flat-key .npz save/restore of arbitrary pytrees.

No external deps (offline container): arrays are stored under their
'/'-joined tree path in a single compressed npz; the treedef is rebuilt
from the paths on restore. Works for params, optimizer state, and the
serving engine's estimator counts alike.
"""

from __future__ import annotations

import os
import re
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(f"#{k.idx}")
            elif isinstance(k, jax.tree_util.GetAttrKey):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        out["/".join(parts)] = np.asarray(leaf)
    return out


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez_compressed(path, **_flatten(tree))


def _insert(root: Dict, keys: Tuple[str, ...], value):
    node = root
    for k in keys[:-1]:
        node = node.setdefault(k, {})
    node[keys[-1]] = value


def _dictify(node):
    """Convert {'#0': .., '#1': ..} levels back into lists."""
    if not isinstance(node, dict):
        return node
    if node and all(re.fullmatch(r"#\d+", k) for k in node):
        return [_dictify(node[f"#{i}"]) for i in range(len(node))]
    return {k: _dictify(v) for k, v in node.items()}


def load(path: str) -> Any:
    """Restore the nested dict/list structure (leaves are np arrays)."""
    with np.load(path, allow_pickle=False) as z:
        root: Dict = {}
        for key in z.files:
            _insert(root, tuple(key.split("/")), z[key])
    return _dictify(root)


def restore_like(template: Any, loaded: Any) -> Any:
    """Map loaded leaves onto ``template``'s pytree BY PATH (robust to
    container-type differences — NamedTuples load back as dicts)."""
    flat_loaded = _flatten(loaded)
    t_flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, t in t_flat:
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(f"#{k.idx}")
            elif isinstance(k, jax.tree_util.GetAttrKey):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        key = "/".join(parts)
        if key not in flat_loaded:
            # NamedTuple fields save as attr names but load back as
            # positional '#i' keys when the container became a list
            alt = "/".join(p if not p.startswith("#") else p
                           for p in parts)
            if alt not in flat_loaded:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            key = alt
        arr = np.asarray(flat_loaded[key])
        if hasattr(t, "dtype"):
            arr = arr.astype(t.dtype).reshape(t.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
