"""jit-able train / prefill / decode step builders.

These are the functions the launcher lowers for the dry-run and executes
in examples — one source of truth for both.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import Runtime, forward
from repro.optim.adamw import AdamWState, adamw_update
from repro.train.loss import lm_loss


def make_train_step(cfg: ModelConfig, rt: Runtime, lr_fn=None,
                    remat: bool = False, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``remat``: wrap the forward in jax.checkpoint (activation recompute —
    trades the memory roofline term for ~1/3 more compute).
    ``microbatches``: split the global batch into sequential microbatches
    with gradient accumulation (lax.scan) — divides activation memory by
    the count at no recompute cost.
    """
    lr_fn = lr_fn or (lambda s: 3e-4)

    def loss_fn(params, batch, plan):
        fwd = forward
        if remat:
            fwd = jax.checkpoint(
                lambda p, b: forward(p, cfg, b, rt, mode="train", plan=plan),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            logits, _, stats = fwd(params, batch)
        else:
            logits, _, stats = forward(params, cfg, batch, rt, mode="train",
                                       plan=plan)
        labels = batch["labels"]
        if cfg.input_mode == "mixed" and "prefix_embeds" in batch:
            # prefix embeddings carry no LM labels: score text positions only
            P = batch["prefix_embeds"].shape[1]
            logits = logits[:, P:]
        loss, metrics = lm_loss(logits, labels, batch.get("loss_mask"))
        if cfg.is_moe:
            loss = loss + stats["aux_loss"] + stats["z_loss"]
            metrics["aux_loss"] = stats["aux_loss"]
            metrics["expert_counts"] = stats["expert_counts"]
        return loss, metrics

    def grads_of(params, batch, plan):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, plan)

    def train_step(params, opt_state: AdamWState, batch, plan=None):
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def body(carry, mbatch):
                acc = carry
                (loss, metrics), grads = grads_of(params, mbatch, plan)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, (losses, metrics) = jax.lax.scan(body, zeros, mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = losses.mean()
            metrics = jax.tree.map(
                lambda m: m.mean(axis=0) if m.ndim else m.mean(), metrics)
        else:
            (loss, metrics), grads = grads_of(params, batch, plan)
        lr = lr_fn(opt_state.step)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, rt: Runtime):
    """Batched prefill. The trailing ``slot_weights_back / slot_ready /
    target_plan`` triple is the overlapped-migration double-buffer view
    (``MoEConfig.overlap_migration``): all traced, so the engines can keep
    serving while a staged migration fills layer by layer — one compile
    covers idle and in-flight steps alike."""
    def prefill_step(params, batch, cache, plan=None, predicted_idx=None,
                     slot_weights=None, slot_weights_back=None,
                     slot_ready=None, target_plan=None, resched=None):
        logits, cache, stats = forward(params, cfg, batch, rt, mode="prefill",
                                       cache=cache, plan=plan,
                                       predicted_idx=predicted_idx,
                                       slot_weights=slot_weights,
                                       slot_weights_back=slot_weights_back,
                                       slot_ready=slot_ready,
                                       target_plan=target_plan,
                                       resched=resched)
        return logits, cache, stats
    return prefill_step


def make_prefill_replan_step(cfg: ModelConfig, rt: Runtime):
    """Fused predict -> plan -> dispatch serving step (one XLA program).

    Runs the prefill with the CURRENT placement plan, then plans the NEXT
    batch's duplication in-graph from this batch's expert histogram via
    the jittable Algorithm 1 (`duplicate_experts_jax`, vmapped over
    layers) — no host round-trip per prediction interval.

    Stays on the per-step gather pool: the replica store is filled by a
    HOST-orchestrated migration (plan diffing is a host decision), which
    would defeat the point of planning in-graph.
    """
    from repro.core.duplication import duplicate_experts_jax

    moe = cfg.moe

    def step(params, batch, cache, plan=None, predicted_idx=None):
        logits, cache, stats = forward(params, cfg, batch, rt, mode="prefill",
                                       cache=cache, plan=plan,
                                       predicted_idx=predicted_idx)
        counts = stats["expert_counts"]                      # (L, E)
        next_plan = jax.vmap(
            lambda c: duplicate_experts_jax(
                c, rt.ep_ranks, moe.duplication_slots, moe.max_copies)
        )(counts)
        return logits, cache, stats, next_plan

    return step


def make_slot_prefill_step(cfg: ModelConfig, rt: Runtime):
    """Continuous-batching prefill: one request padded to a fixed bucket.

    Differences from ``make_prefill_step``: logits are gathered at the
    request's REAL last prompt token (``last_pos``), and ``token_weight``
    masks padding out of the MoE expert histograms. Everything is traced,
    so one compile per prompt-length bucket."""
    def prefill_step(params, batch, cache, plan=None, predicted_idx=None,
                     last_pos=None, token_weight=None, slot_weights=None,
                     slot_weights_back=None, slot_ready=None,
                     target_plan=None, resched=None):
        logits, cache, stats = forward(params, cfg, batch, rt, mode="prefill",
                                       cache=cache, plan=plan,
                                       predicted_idx=predicted_idx,
                                       last_pos=last_pos,
                                       token_weight=token_weight,
                                       slot_weights=slot_weights,
                                       slot_weights_back=slot_weights_back,
                                       slot_ready=slot_ready,
                                       target_plan=target_plan,
                                       resched=resched)
        next_tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache, stats
    return prefill_step


def make_paged_decode_step(cfg: ModelConfig, rt: Runtime):
    """Continuous-batching decode over the paged KV block pool.

    All slots advance one token at their OWN position (``lengths`` is a
    traced (B,) vector — no recompilation as requests join/leave). Returns
    greedy next tokens for every slot; the engine masks idle slots."""
    def decode_step(params, tokens, pool, block_tables, lengths, plan=None,
                    token_weight=None, slot_weights=None,
                    slot_weights_back=None, slot_ready=None,
                    target_plan=None, resched=None):
        logits, pool, stats = forward(params, cfg, {"tokens": tokens}, rt,
                                      mode="decode", cache=pool,
                                      cache_len=lengths, plan=plan,
                                      block_tables=block_tables,
                                      token_weight=token_weight,
                                      slot_weights=slot_weights,
                                      slot_weights_back=slot_weights_back,
                                      slot_ready=slot_ready,
                                      target_plan=target_plan,
                                      resched=resched)
        next_tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
        return next_tok, logits, pool, stats
    return decode_step


def make_decode_step(cfg: ModelConfig, rt: Runtime):
    def decode_step(params, tokens, cache, cache_len, plan=None,
                    slot_weights=None, slot_weights_back=None,
                    slot_ready=None, target_plan=None, resched=None):
        logits, cache, stats = forward(params, cfg, {"tokens": tokens}, rt,
                                       mode="decode", cache=cache,
                                       cache_len=cache_len, plan=plan,
                                       slot_weights=slot_weights,
                                       slot_weights_back=slot_weights_back,
                                       slot_ready=slot_ready,
                                       target_plan=target_plan,
                                       resched=resched)
        next_tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache, stats
    return decode_step
