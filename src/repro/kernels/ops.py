"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` — the kernel
body executes in Python per grid step, validating the exact TPU program.
On a real TPU backend ``interpret`` flips to False automatically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import histogram as _hist
from repro.kernels import moe_gemm as _mg
from repro.kernels import paged_attention as _pa
from repro.kernels import rg_lru as _rg
from repro.kernels import topk_router as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def moe_gemm(x, slot_w: dict, activation: str = "swiglu"):
    """Grouped expert FFN matching `repro.moe.dispatch.grouped_ffn`.
    x: (n_slots, T, d); slot_w: {"w_gate","w_up","w_down"}."""
    w_up = slot_w["w_up"].astype(x.dtype)
    w_gate = slot_w.get("w_gate", slot_w["w_up"]).astype(x.dtype)
    w_down = slot_w["w_down"].astype(x.dtype)
    return _mg.moe_gemm(x, w_gate, w_up, w_down, activation=activation,
                        interpret=_interpret())


def expert_histogram(expert_idx, num_experts: int):
    """(..., K) int32 expert assignments -> (num_experts,) int32 counts."""
    return _hist.histogram(expert_idx.reshape(-1).astype(jnp.int32),
                           num_experts, interpret=_interpret())


def histogram_offsets(idx, num_classes: int):
    """(N,) int32 class ids -> (counts, exclusive-prefix starts), both
    (num_classes,) int32 — the sort-based dispatch packer's slot layout."""
    return _hist.histogram_offsets(idx.reshape(-1).astype(jnp.int32),
                                   num_classes, interpret=_interpret())


def fused_topk_route(logits, top_k: int):
    """(T, E) router logits -> (idx, gates, probs, lse, counts) in one
    fused pass (see `repro.kernels.topk_router`)."""
    return _tk.fused_topk_route(logits, top_k, interpret=_interpret())


def rg_lru_scan(a, b, h0):
    """Linear recurrence h_t = a_t h_{t-1} + b_t (RG-LRU inner scan)."""
    return _rg.rg_lru_scan(a, b, h0, interpret=_interpret())


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           window: int = 0):
    """Fused paged GQA decode over the shared KV block pool — gather +
    online-softmax in one pass, no (B, M*bs, K, hd) intermediate (see
    `repro.kernels.paged_attention`). q: (B, K, G, hd)."""
    return _pa.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                      lengths, window=window,
                                      interpret=_interpret())
