"""Pallas-TPU expert-count histogram.

The Distribution-Only predictor's entire online input is the per-layer
expert histogram — a free side-effect of routing. On TPU a scatter-add
(`.at[].add`) lowers to a serialized scatter; this kernel instead reduces
one-hot comparisons per block on the VPU:

  grid = (N / bn,);  counts += sum_n (idx_blk[n] == iota_E)

The (bn, E) comparison matrix lives in VMEM/VREGs; accumulation revisits
the single (1, E) output block across the sequential grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 1024


def _kernel(idx_ref, o_ref, *, num_classes: int, valid: int, bn: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]                              # (bn,)
    base = i * bn
    offs = base + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)[:, 0]
    classes = jax.lax.broadcasted_iota(jnp.int32, (bn, num_classes), 1)
    onehot = (idx[:, None] == classes) & (offs < valid)[:, None]
    o_ref[0] += onehot.astype(jnp.int32).sum(axis=0)


@functools.partial(jax.jit, static_argnames=("num_classes", "bn", "interpret"))
def histogram(idx, num_classes: int, *, bn: int = DEFAULT_BN,
              interpret: bool = True):
    """idx: (N,) int32 in [0, num_classes) -> counts (num_classes,) int32."""
    N = idx.shape[0]
    bn = min(bn, max(N, 8))
    pn = (-N) % bn
    if pn:
        idx = jnp.pad(idx, (0, pn))
    out = pl.pallas_call(
        functools.partial(_kernel, num_classes=num_classes, valid=N, bn=bn),
        grid=((N + pn) // bn,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, num_classes), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, num_classes), jnp.int32),
        interpret=interpret,
    )(idx)
    return out[0]


def histogram_offsets(idx, num_classes: int, *, bn: int = DEFAULT_BN,
                      interpret: bool = True):
    """Class histogram plus its exclusive prefix sum (slot start offsets).

    The sort-based dispatch packer consumes exactly this pair: counts give
    each slot's fill level, offsets give where each slot's contiguous run
    begins in the argsorted token order. Returns (counts, starts), both
    (num_classes,) int32.
    """
    counts = histogram(idx, num_classes, bn=bn, interpret=interpret)
    starts = jnp.cumsum(counts) - counts
    return counts, starts
