"""Pallas-TPU kernels for the compute hot-spots (moe_gemm: grouped expert
FFN; histogram: expert counts for Distribution-Only prediction; rg_lru:
RecurrentGemma linear recurrence). Each has a pure-jnp oracle in ref.py;
ops.py exposes jit'd wrappers that interpret on CPU."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
