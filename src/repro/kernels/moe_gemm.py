"""Pallas-TPU grouped expert FFN GEMM (the MoE compute hot-spot).

The GPU systems the paper builds on use CUDA "grouped GEMM" kernels for
the per-expert FFN. The TPU adaptation tiles the three expert matmuls into
MXU-aligned VMEM blocks and fuses gate/up/activation/down into one kernel,
so the (tokens_per_slot, d_ff) intermediate never round-trips HBM:

  grid = (slots, T/bt, F/bf)      (sequential minor-most f over d_ff)
  per step:  h = act(x_blk @ wg_blk) [* (x_blk @ wu_blk)]   (bt, bf)
             acc += h @ wd_blk                              (bt, d) f32

Block shapes are multiples of (8, 128) so both matmuls keep the MXU fed;
the f-loop accumulates into a VMEM f32 scratch, written once at f == F-1.
Validated against ref.moe_gemm_ref with interpret=True on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 128       # token-block (second-minor >= 8)
DEFAULT_BF = 512       # d_ff block (lane multiple of 128)


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
            activation: str, nf: int):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                   # (bt, d)
    wu = wu_ref[0]                                 # (d, bf)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    if activation == "swiglu":
        wg = wg_ref[0]
        g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * u
    elif activation == "gelu":
        h = jax.nn.gelu(u)
    else:
        h = jnp.maximum(u, 0.0)
    wd = wd_ref[0]                                 # (bf, d)
    acc_ref[...] += jnp.dot(h.astype(x.dtype), wd,
                            preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("activation", "bt", "bf", "interpret"))
def moe_gemm(x, w_gate, w_up, w_down, *, activation: str = "swiglu",
             bt: int = DEFAULT_BT, bf: int = DEFAULT_BF,
             interpret: bool = True):
    """x: (S, T, d); w_gate/w_up: (S, d, F); w_down: (S, F, d) -> (S, T, d).

    T and F are padded to block multiples internally (zero padding is
    exact for all supported activations: act(0)=0 rows contribute 0).
    """
    S, T, d = x.shape
    F = w_up.shape[-1]
    bt = min(bt, max(8, T))
    bf = min(bf, F)
    pt = (-T) % bt
    pf = (-F) % bf
    if pt:
        x = jnp.pad(x, ((0, 0), (0, pt), (0, 0)))
    if pf:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, pf)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, pf)))
        w_down = jnp.pad(w_down, ((0, 0), (0, pf), (0, 0)))
    Tp, Fp = T + pt, F + pf
    nf = Fp // bf

    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation, nf=nf),
        grid=(S, Tp // bt, nf),
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda s, t, f: (s, t, 0)),
            pl.BlockSpec((1, d, bf), lambda s, t, f: (s, 0, f)),
            pl.BlockSpec((1, d, bf), lambda s, t, f: (s, 0, f)),
            pl.BlockSpec((1, bf, d), lambda s, t, f: (s, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda s, t, f: (s, t, 0)),
        out_shape=jax.ShapeDtypeStruct((S, Tp, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return out[:, :T]
