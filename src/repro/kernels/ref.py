"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gemm_ref(x, w_gate, w_up, w_down, activation: str = "swiglu"):
    """Grouped expert FFN.
    x: (S, T, d); w_gate/w_up: (S, d, F); w_down: (S, F, d) -> (S, T, d)."""
    if activation == "swiglu":
        g = jnp.einsum("std,sdf->stf", x, w_gate.astype(x.dtype))
        u = jnp.einsum("std,sdf->stf", x, w_up.astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("std,sdf->stf", x, w_up.astype(x.dtype))
        h = (jax.nn.gelu(h.astype(jnp.float32)) if activation == "gelu"
             else jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("stf,sfd->std", h, w_down.astype(x.dtype))


def histogram_ref(idx, num_classes: int):
    """idx: (N,) int32 -> counts (num_classes,) int32."""
    return jnp.zeros((num_classes,), jnp.int32).at[idx].add(
        jnp.ones_like(idx))


def rg_lru_ref(a, b, h0):
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t.
    a, b: (B, S, D) f32; h0: (B, D) f32. Returns (h_all (B,S,D), h_last)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    h_last, hs = jax.lax.scan(step, h0,
                              (jnp.swapaxes(a, 0, 1), jnp.swapaxes(b, 0, 1)))
    return jnp.swapaxes(hs, 0, 1), h_last
