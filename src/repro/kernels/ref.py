"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gemm_ref(x, w_gate, w_up, w_down, activation: str = "swiglu"):
    """Grouped expert FFN.
    x: (S, T, d); w_gate/w_up: (S, d, F); w_down: (S, F, d) -> (S, T, d)."""
    if activation == "swiglu":
        g = jnp.einsum("std,sdf->stf", x, w_gate.astype(x.dtype))
        u = jnp.einsum("std,sdf->stf", x, w_up.astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("std,sdf->stf", x, w_up.astype(x.dtype))
        h = (jax.nn.gelu(h.astype(jnp.float32)) if activation == "gelu"
             else jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("stf,sfd->std", h, w_down.astype(x.dtype))


def histogram_ref(idx, num_classes: int):
    """idx: (N,) int32 -> counts (num_classes,) int32."""
    return jnp.zeros((num_classes,), jnp.int32).at[idx].add(
        jnp.ones_like(idx))


def paged_decode_ref(q, k_view, v_view, lengths, *, window: int = 0,
                     block_size: int = 16):
    """Gather-path paged-decode oracle for ``kernels.paged_attention``.

    Runs over the MATERIALISED logical view (its defining cost) but with
    the kernel's exact blockwise online-softmax op sequence — same dots,
    same exp/rescale order, same block-skip — so fused vs gather is
    bit-exact in fp32, not merely allclose.

    q: (B, K, G, hd); k_view/v_view: (B, M*bs, K, hd) gathered views;
    lengths: (B,) int32 (new token already written at ``lengths[b]``).
    Returns (B, K, G, hd) in q's dtype.
    """
    B, K, G, hd = q.shape
    bs = block_size
    M = k_view.shape[1] // bs
    scale = 1.0 / (hd ** 0.5)
    neg_inf = -1e30
    cl = jnp.asarray(lengths, jnp.int32) + 1                      # (B,)
    qf = q.astype(jnp.float32)

    def slot_scores(qb, kb):
        # (K, G, hd) x (bs, K, hd) -> (K, G, bs): batch K, contract hd
        return jax.lax.dot_general(qb, kb, (((2,), (2,)), ((0,), (1,))),
                                   preferred_element_type=jnp.float32)

    def slot_out(pb, vb):
        # (K, G, bs) x (bs, K, hd) -> (K, G, hd): batch K, contract bs
        return jax.lax.dot_general(pb, vb, (((2,), (0,)), ((0,), (1,))),
                                   preferred_element_type=jnp.float32)

    def block_step(carry, inputs):
        m_run, l_run, acc = carry
        mi, k_blk, v_blk = inputs                # (B, bs, K, hd)
        start = mi * bs
        pos = start + jnp.arange(bs, dtype=jnp.int32)
        mask = pos[None, :] < cl[:, None]                         # (B, bs)
        live = start < cl                                         # (B,)
        if window > 0:
            mask &= pos[None, :] >= (cl - window)[:, None]
            live &= start + bs > cl - window
        s = jax.vmap(slot_scores)(qf, k_blk.astype(jnp.float32)) * scale
        s = jnp.where(mask[:, None, None, :], s, neg_inf)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        a_new = acc * corr[..., None] + jax.vmap(slot_out)(
            p, v_blk.astype(jnp.float32))
        keep = live[:, None, None]
        return (jnp.where(keep, m_new, m_run),
                jnp.where(keep, l_new, l_run),
                jnp.where(keep[..., None], a_new, acc)), None

    m0 = jnp.full((B, K, G), neg_inf, jnp.float32)
    l0 = jnp.zeros((B, K, G), jnp.float32)
    a0 = jnp.zeros((B, K, G, hd), jnp.float32)
    kb = jnp.moveaxis(k_view.reshape(B, M, bs, K, hd), 1, 0)
    vb = jnp.moveaxis(v_view.reshape(B, M, bs, K, hd), 1, 0)
    (m_f, l_f, acc), _ = jax.lax.scan(
        block_step, (m0, l0, a0), (jnp.arange(M, dtype=jnp.int32), kb, vb))
    return (acc / jnp.maximum(l_f, 1e-20)[..., None]).astype(q.dtype)


def rg_lru_ref(a, b, h0):
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t.
    a, b: (B, S, D) f32; h0: (B, D) f32. Returns (h_all (B,S,D), h_last)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    h_last, hs = jax.lax.scan(step, h0,
                              (jnp.swapaxes(a, 0, 1), jnp.swapaxes(b, 0, 1)))
    return jnp.swapaxes(hs, 0, 1), h_last
