"""Pallas-TPU fused paged-decode attention (gather + flash softmax in one).

One decode step per grid slot walks the slot's ``block_tables`` row and
attends over its logical KV stream WITHOUT ever materialising the
``(B, M*bs, K, hd)`` gathered view the generic path builds in HBM:

  grid = (B, M);  scalar-prefetch: block_tables (B, M), lengths (B,)
    per (b, m): the in_spec index_map reads ``block_tables[b, m]`` and
    DMAs exactly that physical (bs, K, hd) KV block from the shared pool
    into VMEM — the gather IS the block fetch — then folds it into a
    flash-style running (max, sum, acc) online-softmax state held in
    VMEM scratch across the m-steps of slot b.

Masking happens in-kernel from logical-position arithmetic: positions
``>= lengths[b] + 1`` (ragged slots, and every null-block table entry —
unallocated entries point at reserved block 0 whose logical positions
are always past the valid length) and, for sliding-window archs,
positions ``< cache_len - window``. Blocks wholly outside the valid
window are skipped (``pl.when``), so decode compute scales with each
slot's VALID window, not the table's allocated width — the win the
generic gather path cannot have, since its HBM traffic is fixed at the
full ``(B, M*bs)`` view.

The pure-jnp oracle (``kernels.ref.paged_decode_ref``) runs the same
block-ordered accumulation over the materialised view, so fused vs
gather is bit-exact in fp32 — same dots, same exp/rescale sequence,
per logical block.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, bs: int, window: int,
                   scale: float):
    b, m = pl.program_id(0), pl.program_id(1)
    blocks = pl.num_programs(1)

    @pl.when(m == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cl = lengths_ref[b] + 1                       # new token sits at lengths
    start = m * bs
    # logical positions of this block's entries (2D iota: TPU constraint)
    pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    mask = pos < cl
    live = start < cl
    if window > 0:
        mask &= pos >= cl - window
        live = jnp.logical_and(live, start + bs > cl - window)

    # skip blocks wholly outside the valid (windowed) range: unallocated
    # table entries (the null block) and positions behind the window never
    # cost compute — only the block DMA, which the index_map already
    # resolved to the one reserved null block
    @pl.when(live)
    def _():
        q = q_ref[0].astype(jnp.float32)          # (K, G, hd)
        k = k_ref[0].astype(jnp.float32)          # (bs, K, hd)
        v = v_ref[0].astype(jnp.float32)
        # (K, G, hd) x (bs, K, hd) -> (K, G, bs): batch K, contract hd
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask[None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_scr[...], s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_scr[...] - m_new)
        m_scr[...] = m_new
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        # (K, G, bs) x (bs, K, hd) -> (K, G, hd): batch K, contract bs
        acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)

    @pl.when(m == blocks - 1)
    def _():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-20)[..., None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           window: int = 0, interpret: bool = True):
    """Fused paged GQA decode. q: (B, K, G, hd) — query heads grouped by
    their KV head; k_pool/v_pool: (N_blocks, bs, K, hd) shared pool
    (block 0 reserved null); block_tables: (B, M) int32; lengths: (B,)
    int32 — the slot attends positions ``[0, lengths[b]]`` (its new token
    was already written at ``lengths[b]``), minus anything behind the
    sliding ``window``. Returns (B, K, G, hd) in q's dtype.
    """
    B, K, G, hd = q.shape
    _, bs, _, _ = k_pool.shape
    M = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, K, G, hd), lambda b, m, t, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, K, hd),
                         lambda b, m, t, ln: (t[b, m], 0, 0, 0)),
            pl.BlockSpec((1, bs, K, hd),
                         lambda b, m, t, ln: (t[b, m], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, G, hd),
                               lambda b, m, t, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, G), jnp.float32),        # running max
            pltpu.VMEM((K, G), jnp.float32),        # running denominator
            pltpu.VMEM((K, G, hd), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, bs=bs, window=window, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q, k_pool, v_pool)
