"""Pallas-TPU fused top-k router: softmax + top-k + expert histogram.

One pass over the router logits produces everything the sort-based
dispatch pipeline needs, extending the accumulation pattern of
``kernels/histogram.py``:

  grid = (T / bn,);  per block (bn, E):
    probs = softmax(logits_blk)                      (VPU)
    for k in 0..K-1: gate/idx = max/argmax, mask     (K static, tiny)
    counts += sum_n onehot(idx)                      (revisited (1, E) block)

Compared with the unfused path (softmax -> ``lax.top_k`` -> scatter-add
histogram) the (bn, E) probability block never leaves VMEM between the
three stages, and the histogram — the Distribution-Only predictor's whole
online input — comes out as a free side effect of routing. Also emits the
per-row logsumexp so the router z-loss needs no second pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 256


def _kernel(logits_ref, idx_ref, gates_ref, probs_ref, lse_ref, counts_ref, *,
            num_experts: int, top_k: int, valid: int, bn: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = logits_ref[...].astype(jnp.float32)             # (bn, E)
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    den = jnp.sum(ex, axis=-1, keepdims=True)
    probs = ex / den
    probs_ref[...] = probs
    lse_ref[...] = m + jnp.log(den)

    offs = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    rowok = offs < valid                                # padded rows -> 0
    classes = jax.lax.broadcasted_iota(jnp.int32, (bn, num_experts), 1)
    work = probs
    acc = jnp.zeros((num_experts,), jnp.int32)
    sels, gs = [], []
    for _ in range(top_k):
        g = jnp.max(work, axis=-1)                      # (bn,)
        sel = jnp.argmax(work, axis=-1).astype(jnp.int32)
        hit = classes == sel[:, None]
        acc = acc + (hit & rowok).astype(jnp.int32).sum(axis=0)
        work = jnp.where(hit, -jnp.inf, work)
        sels.append(sel)
        gs.append(g)
    idx_ref[...] = jnp.stack(sels, axis=1)
    gates_ref[...] = jnp.stack(gs, axis=1)
    counts_ref[0] += acc


@functools.partial(jax.jit, static_argnames=("top_k", "bn", "interpret"))
def fused_topk_route(logits, top_k: int, *, bn: int = DEFAULT_BN,
                     interpret: bool = True):
    """logits: (T, E) -> (idx (T,K) i32, gates (T,K) f32 UN-normalised,
    probs (T,E) f32, lse (T,) f32, counts (E,) i32).

    Tie-breaking matches ``lax.top_k`` (lowest expert index wins), so the
    unfused reference router is bit-compatible on the assignments.
    """
    T, E = logits.shape
    bn = min(bn, max(T, 8))
    pn = (-T) % bn
    if pn:
        logits = jnp.pad(logits, ((0, pn), (0, 0)))
    idx, gates, probs, lse, counts = pl.pallas_call(
        functools.partial(_kernel, num_experts=E, top_k=top_k, valid=T, bn=bn),
        grid=((T + pn) // bn,),
        in_specs=[pl.BlockSpec((bn, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bn, top_k), lambda i: (i, 0)),
            pl.BlockSpec((bn, top_k), lambda i: (i, 0)),
            pl.BlockSpec((bn, E), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, E), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T + pn, top_k), jnp.int32),
            jax.ShapeDtypeStruct((T + pn, top_k), jnp.float32),
            jax.ShapeDtypeStruct((T + pn, E), jnp.float32),
            jax.ShapeDtypeStruct((T + pn, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, E), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
    return idx[:T], gates[:T], probs[:T], lse[:T, 0], counts[0]
