"""Pallas-TPU RG-LRU linear-recurrence scan (RecurrentGemma hot-spot).

Griffin's CUDA kernel streams the diagonal recurrence h_t = a_t h_{t-1} +
b_t through shared memory. The TPU adaptation tiles the channel dim into
VMEM lanes and runs the time loop INSIDE the kernel over a VMEM-resident
(S_blk, bd) block — channels are independent, so the grid parallelises
(batch, channel-block) while time stays sequential on the VPU:

  grid = (B, D / bd); per instance: fori over S with a (bd,) f32 carry.

The chunked time dimension keeps the working set (2 x S_blk x bd x 4B)
inside VMEM; the carry crosses grid steps through the h_last output block
(revisited per (b, d) instance — sequential minor-most S-chunk axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BD = 256       # channel block (lane multiple of 128)
DEFAULT_BS = 1024      # time chunk resident in VMEM


def _kernel(a_ref, b_ref, h0_ref, o_ref, hl_ref, *, ns: int, bs: int):
    s = pl.program_id(2)

    h = jnp.where(s == 0, h0_ref[0], hl_ref[0])     # (bd,) carry

    a = a_ref[0]                                    # (bs, bd)
    b = b_ref[0]

    def step(t, carry):
        h, out = carry
        h = a[t] * h + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 0)
        return h, out

    out0 = jnp.zeros_like(a)
    h, out = jax.lax.fori_loop(0, bs, step, (h, out0))
    o_ref[0] = out
    hl_ref[0] = h


@functools.partial(jax.jit, static_argnames=("bd", "bs", "interpret"))
def rg_lru_scan(a, b, h0, *, bd: int = DEFAULT_BD, bs: int = DEFAULT_BS,
                interpret: bool = True):
    """a, b: (B, S, D) f32; h0: (B, D) f32 -> (h_all (B,S,D), h_last (B,D)).

    h_t = a_t * h_{t-1} + b_t per independent channel.
    """
    B, S, D = a.shape
    bd = min(bd, D)
    bs = min(bs, S)
    pd = (-D) % bd
    ps = (-S) % bs
    if pd or ps:
        pad3 = ((0, 0), (0, ps), (0, pd))
        # pad time with a=1, b=0: h_t = h_{t-1}, so the carry (h_last)
        # survives the padded steps unchanged
        a = jnp.pad(a, pad3, constant_values=1.0)
        b = jnp.pad(b, pad3)
        h0 = jnp.pad(h0, ((0, 0), (0, pd)))
    Sp, Dp = S + ps, D + pd

    out, h_last = pl.pallas_call(
        functools.partial(_kernel, ns=Sp // bs, bs=bs),
        grid=(B, Dp // bd, Sp // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, bs, bd), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, bd), lambda bi, di, si: (bi, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, bd), lambda bi, di, si: (bi, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Dp), a.dtype),
            jax.ShapeDtypeStruct((B, Dp), a.dtype),
        ],
        interpret=interpret,
    )(a, b, h0)
    return out[:, :S, :D], h_last[:, :D]
