"""Request arrival processes.

Everything returns a sorted np.ndarray of arrival times in [0, horizon).
Rates are requests/second of *virtual* trace time — the serving benchmark
replays them against a virtual clock, so absolute scale is free.
"""

from __future__ import annotations

import numpy as np


def poisson_arrivals(rate: float, horizon: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Homogeneous Poisson process: i.i.d. exponential gaps."""
    if rate <= 0:
        return np.empty((0,))
    n = max(int(rate * horizon * 2), 16)
    gaps = rng.exponential(1.0 / rate, size=n)
    t = np.cumsum(gaps)
    while t[-1] < horizon:                      # unlikely undershoot
        more = np.cumsum(rng.exponential(1.0 / rate, size=n)) + t[-1]
        t = np.concatenate([t, more])
    return t[t < horizon]


def bursty_arrivals(rate_low: float, rate_high: float, horizon: float,
                    rng: np.random.Generator, *, mean_dwell_low: float = 20.0,
                    mean_dwell_high: float = 5.0) -> np.ndarray:
    """2-state Markov-modulated Poisson process (calm <-> burst).

    The process alternates exponential-length dwell phases; within a phase
    arrivals are Poisson at that phase's rate. This is the classic bursty
    serving model: long quiet stretches punctuated by sharp load spikes.
    """
    times = []
    t = 0.0
    high = False
    while t < horizon:
        dwell = rng.exponential(mean_dwell_high if high else mean_dwell_low)
        end = min(t + dwell, horizon)
        rate = rate_high if high else rate_low
        seg = poisson_arrivals(rate, end - t, rng) + t
        times.append(seg)
        t = end
        high = not high
    return np.sort(np.concatenate(times)) if times else np.empty((0,))


def diurnal_arrivals(base_rate: float, amplitude: float, period: float,
                     horizon: float, rng: np.random.Generator) -> np.ndarray:
    """Inhomogeneous Poisson with a sinusoidal day/night rate, sampled by
    thinning: rate(t) = base * (1 + amplitude * sin(2 pi t / period))."""
    amplitude = float(np.clip(amplitude, 0.0, 1.0))
    rate_max = base_rate * (1.0 + amplitude)
    cand = poisson_arrivals(rate_max, horizon, rng)
    rate_t = base_rate * (1.0 + amplitude * np.sin(2 * np.pi * cand / period))
    keep = rng.random(cand.shape) < rate_t / rate_max
    return cand[keep]
