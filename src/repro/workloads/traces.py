"""Trace assembly: arrival process x length distributions x corpus, per
tenant, merged into one replayable request trace."""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.workloads.arrivals import (bursty_arrivals, diurnal_arrivals,
                                      poisson_arrivals)
from repro.workloads.corpus import ShiftingCorpus, Topic


@dataclass
class TraceRequest:
    rid: int
    arrival: float
    tokens: np.ndarray            # (S,) prompt
    max_new_tokens: int
    tenant: str = ""


@dataclass
class TenantSpec:
    """One tenant's traffic model."""
    name: str
    corpus: ShiftingCorpus
    arrivals: str = "poisson"               # poisson | bursty | diurnal
    rate: float = 1.0                       # base requests/s
    burst_rate: float = 0.0                 # bursty: high-phase rate
    diurnal_amplitude: float = 0.8
    diurnal_period: float = 60.0
    prompt_len_mean: float = 32.0           # lognormal body
    prompt_len_sigma: float = 0.4
    prompt_len_max: int = 64
    out_len_mean: float = 8.0
    out_len_sigma: float = 0.5
    out_len_max: int = 32

    def arrival_times(self, horizon: float,
                      rng: np.random.Generator) -> np.ndarray:
        if self.arrivals == "poisson":
            return poisson_arrivals(self.rate, horizon, rng)
        if self.arrivals == "bursty":
            high = self.burst_rate or 4.0 * self.rate
            return bursty_arrivals(self.rate, high, horizon, rng)
        if self.arrivals == "diurnal":
            return diurnal_arrivals(self.rate, self.diurnal_amplitude,
                                    self.diurnal_period, horizon, rng)
        raise ValueError(self.arrivals)

    def _lognormal_len(self, mean: float, sigma: float, lo: int, hi: int,
                       rng: np.random.Generator) -> int:
        mu = np.log(max(mean, 1.0)) - sigma ** 2 / 2
        return int(np.clip(round(rng.lognormal(mu, sigma)), lo, hi))

    def sample_lengths(self, rng: np.random.Generator) -> Tuple[int, int]:
        p = self._lognormal_len(self.prompt_len_mean, self.prompt_len_sigma,
                                1, self.prompt_len_max, rng)
        o = self._lognormal_len(self.out_len_mean, self.out_len_sigma,
                                1, self.out_len_max, rng)
        return p, o


def make_trace(tenants: Sequence[TenantSpec], horizon: float,
               seed: int = 0) -> List[TraceRequest]:
    """Merge every tenant's arrivals into one rid-ordered trace."""
    rng = np.random.default_rng(seed)
    events: List[Tuple[float, TenantSpec]] = []
    for spec in tenants:
        for t in spec.arrival_times(horizon, rng):
            events.append((float(t), spec))
    events.sort(key=lambda e: e[0])
    trace = []
    for rid, (t, spec) in enumerate(events):
        plen, olen = spec.sample_lengths(rng)
        trace.append(TraceRequest(
            rid=rid, arrival=t,
            tokens=spec.corpus.sample_prompt(t, plen, rng),
            max_new_tokens=olen, tenant=spec.name))
    return trace


def skew_shift_trace(vocab: int, horizon: float = 90.0, rate: float = 1.5,
                     seed: int = 0, *, arrivals: str = "bursty",
                     prompt_len_max: int = 64, out_len_max: int = 16,
                     ) -> List[TraceRequest]:
    """The benchmark's canonical single-tenant trace: bursty arrivals over
    a corpus whose mixture walks flat -> concentrated -> flat, so measured
    expert skew rises then falls across the session and the online GPS
    controller has something real to react to."""
    flat = Topic("broad", zipf_alpha=0.4, vocab_frac=1.0, seed=1)
    hot = Topic("trending", zipf_alpha=3.0, vocab_frac=0.05, seed=2)
    corpus = ShiftingCorpus(vocab, [flat, hot], schedule=[
        (0.0, [1.0, 0.0]),
        (0.35 * horizon, [0.9, 0.1]),
        (0.5 * horizon, [0.05, 0.95]),
        (0.75 * horizon, [0.1, 0.9]),
        (horizon, [1.0, 0.0]),
    ])
    spec = TenantSpec("main", corpus, arrivals=arrivals, rate=rate,
                      prompt_len_mean=24.0, prompt_len_max=prompt_len_max,
                      out_len_mean=6.0, out_len_max=out_len_max)
    return make_trace([spec], horizon, seed=seed)


def to_serve_requests(trace: Sequence[TraceRequest]):
    """TraceRequest -> repro.serve.ServeRequest (import-cycle-free)."""
    from repro.serve.scheduler import ServeRequest
    return [ServeRequest(rid=r.rid, tokens=r.tokens,
                         max_new_tokens=r.max_new_tokens,
                         arrival=r.arrival, tenant=r.tenant)
            for r in trace]
