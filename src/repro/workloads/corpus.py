"""Topic-shifting token corpora.

The paper's premise is that expert load distributions are a property of
the *traffic*: different datasets route with different skew (MMLU 1.39 vs
SST2 1.99, Table 1), and live traffic drifts between regimes. We model
that with **topics**: each topic is a Zipf distribution over its own
permutation of the vocabulary with its own concentration. A concentrated
topic (high alpha) repeats few distinct tokens, which a token-identity
router maps to few experts — high skew; a flat topic spreads tokens — low
skew. A time-varying topic mixture therefore moves the *measured* routing
skew over a serving session, which is exactly the signal the online GPS
controller reacts to.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Topic:
    name: str
    zipf_alpha: float = 1.2        # token concentration (higher = fewer
                                   # distinct tokens = more routing skew)
    vocab_frac: float = 1.0        # fraction of the vocab this topic uses
    seed: int = 0                  # permutation seed (topic identity)


class ShiftingCorpus:
    """Samples prompts from a time-varying mixture of topics.

    ``schedule``: list of (t_start, weights) checkpoints; the mixture is
    linearly interpolated between consecutive checkpoints (weights are
    per-topic, re-normalised). A single checkpoint = stationary corpus.
    """

    def __init__(self, vocab: int, topics: Sequence[Topic],
                 schedule: Sequence[Tuple[float, Sequence[float]]]):
        if not topics:
            raise ValueError("need at least one topic")
        if not schedule:
            raise ValueError("need at least one schedule checkpoint")
        self.vocab = vocab
        self.topics = list(topics)
        self.schedule = sorted((float(t), np.asarray(w, np.float64))
                               for t, w in schedule)
        for _, w in self.schedule:
            if w.shape != (len(self.topics),):
                raise ValueError("schedule weights must match topics")
        self._dists = [self._topic_dist(t) for t in self.topics]

    def _topic_dist(self, topic: Topic) -> np.ndarray:
        rng = np.random.default_rng(topic.seed)
        n = max(int(self.vocab * topic.vocab_frac), 1)
        ranks = np.arange(1, n + 1, dtype=np.float64)
        p = ranks ** (-topic.zipf_alpha)
        p /= p.sum()
        dist = np.zeros((self.vocab,), np.float64)
        ids = rng.permutation(self.vocab)[:n]      # topic's own token subset
        dist[ids] = p
        return dist

    def mixture(self, t: float) -> np.ndarray:
        """Interpolated topic weights at time t (normalised)."""
        sched = self.schedule
        if t <= sched[0][0]:
            w = sched[0][1]
        elif t >= sched[-1][0]:
            w = sched[-1][1]
        else:
            for (t0, w0), (t1, w1) in zip(sched, sched[1:]):
                if t0 <= t <= t1:
                    a = (t - t0) / max(t1 - t0, 1e-12)
                    w = (1 - a) * w0 + a * w1
                    break
        w = np.maximum(w, 0.0)
        return w / max(w.sum(), 1e-12)

    def token_dist(self, t: float) -> np.ndarray:
        """Marginal token distribution at time t."""
        w = self.mixture(t)
        return sum(wi * d for wi, d in zip(w, self._dists))

    def sample_prompt(self, t: float, length: int,
                      rng: np.random.Generator) -> np.ndarray:
        """One request's prompt: topic drawn from the mixture at its
        arrival time, tokens i.i.d. from that topic (requests are
        topically coherent, the mixture shifts only across requests)."""
        k = rng.choice(len(self.topics), p=self.mixture(t))
        return rng.choice(self.vocab, size=length,
                          p=self._dists[k]).astype(np.int32)
