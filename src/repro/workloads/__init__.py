"""Trace-driven serving workloads.

Arrival processes (Poisson / bursty MMPP / diurnal), topic-shifting token
corpora (so expert skew MOVES over a serving session, the condition the
online GPS controller exists for), and multi-tenant trace assembly.
"""
from repro.workloads.arrivals import (bursty_arrivals, diurnal_arrivals,
                                      poisson_arrivals)
from repro.workloads.corpus import ShiftingCorpus, Topic
from repro.workloads.traces import (TenantSpec, TraceRequest, make_trace,
                                    skew_shift_trace, to_serve_requests)

__all__ = [
    "ShiftingCorpus", "TenantSpec", "Topic", "TraceRequest",
    "bursty_arrivals", "diurnal_arrivals", "make_trace", "poisson_arrivals",
    "skew_shift_trace", "to_serve_requests",
]
