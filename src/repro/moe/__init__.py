"""MoE building blocks: the top-k router and the expert-parallel dispatch
runtime (placement-aware duplication, predicted pre-routing)."""
from repro.moe import dispatch, router
from repro.moe.dispatch import (MoEStats, capacity, choose_replica_quota,
                                ep_moe_ffn, ep_moe_ffn_replicated,
                                gather_replica_pool, grouped_ffn)
from repro.moe.router import RouterOutput, expert_histogram, init_router, route

__all__ = [
    "MoEStats", "RouterOutput", "capacity", "choose_replica_quota",
    "dispatch", "ep_moe_ffn", "ep_moe_ffn_replicated", "expert_histogram",
    "gather_replica_pool", "grouped_ffn", "init_router", "route", "router",
]
