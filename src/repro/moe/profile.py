"""Per-phase dispatch timing: route / pack / all_to_all / ffn / combine.

The paper's guideline (Sec 4) picks a prediction strategy from *measured*
hot-path costs, so the repo needs a way to attribute dispatch wall time to
its phases. Inside one jitted shard_map the phases can't be separated on
the host, so this module times each phase as its OWN jitted function on
representative shapes:

  route    router matmul + softmax + top-k + histogram
  pack     send-buffer construction (the ``dispatch_impl`` hot path)
  a2a      send->recv layout transform (the local cost of the all_to_all;
           the wire time is modeled by ``repro.core.simulator``)
  ffn      grouped expert FFN on the received block
  combine  per-assignment gather + gate-weighted reduction

Used by ``benchmarks/bench_dispatch`` (impl comparison) and
``ContinuousEngine.profile_phases`` (serve-side breakdown fed into
``ServeMetrics``).
"""

from __future__ import annotations

import math
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.moe import dispatch as dsp
from repro.moe.router import route

PHASES = ("route", "pack", "a2a", "ffn", "combine")
# Paid once per PLAN SWITCH, not per step — kept out of PHASES so per-step
# totals and the dispatch impl comparison stay impl-independent.
MIGRATE_PHASE = "migrate"
# The HOST-side cost of ISSUING one overlapped fill chunk (enqueue without
# blocking). With the async prefetcher this — not the chunk's execution —
# is what lands on the serving critical path; the execution rides under
# forward compute, so ``migrate`` must not be lumped into step time.
PREFETCH_PHASE = "prefetch"
# Paged-decode attention (not a dispatch phase — kept out of PHASES so
# ``dispatch_phase_times``' total stays a sum of dispatch work only). Timed
# per decode step at serving shapes so the fused-kernel win is visible next
# to the MoE breakdown it competes with on the step wall.
ATTN_PHASE = "attn"


def _time(fn, *args, iters: int) -> float:
    jax.block_until_ready(fn(*args))                 # compile + warm
    best = math.inf
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def dispatch_phase_times(*, d_model: int = 256, d_ff: int = 256,
                         num_experts: int = 64, top_k: int = 2,
                         tokens: int = 2048, ranks: int = 4,
                         capacity_factor: float = 1.25,
                         impl: str = "sort", activation: str = "swiglu",
                         use_kernel: bool = False, iters: int = 5,
                         seed: int = 0) -> Dict[str, float]:
    """Time each dispatch phase on a single device. Returns seconds per
    phase plus ``"total"``; ``impl`` selects the pack formulation.

    Experts map to slots identity-style (no duplication), so the phase
    shapes match an EP deployment with ``ranks`` ranks hosting
    ``num_experts / ranks`` home experts each; the all_to_all phase times
    the (ranks, n_slots, cap) layout transform that brackets the wire.
    """
    if num_experts % ranks:
        ranks = 1
    rng = np.random.default_rng(seed)
    T, K, E, d = tokens, top_k, num_experts, d_model
    N = T * K
    S = E                                  # identity plan: slot == expert
    n_slots = S // ranks
    cap = dsp.capacity(T, K, S, capacity_factor)
    moe = MoEConfig(num_experts=E, top_k=K, d_ff_expert=d_ff,
                    capacity_factor=capacity_factor, dispatch_impl=impl)

    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    router_params = {"w": jnp.asarray(rng.normal(size=(d, E)) * 0.02,
                                      jnp.float32)}
    token_of = jnp.arange(N, dtype=jnp.int32) // K
    pack = dsp._PACKERS[impl]

    # ----------------------------------------------------------- route
    route_fn = jax.jit(lambda p, t: route(
        p, moe, t, impl="fused" if use_kernel else "dense"))
    out = jax.block_until_ready(route_fn(router_params, x))
    gslot = out.expert_idx.reshape(-1)              # identity slot mapping
    gates = out.gates
    valid = jnp.ones((N,), bool)

    # ------------------------------------------------------------ pack
    pack_fn = jax.jit(lambda x_, g_: pack(
        x_, token_of, g_, valid, num_classes=S, cap=cap,
        use_kernel=use_kernel))
    send, in_cap, dest, _, _ = jax.block_until_ready(pack_fn(x, gslot))

    # ------------------------------------------------------------- a2a
    def a2a_fn(s):
        # send (S*cap, d) -> per-rank (ranks, n_slots*cap, d) -> received
        # (n_slots, ranks*cap, d): the two reshuffles around the wire
        r = s.reshape(ranks, n_slots, cap, d)
        return r.transpose(1, 0, 2, 3).reshape(n_slots, ranks * cap, d)
    a2a_jit = jax.jit(a2a_fn)
    recv = send.reshape(S, cap, d)                  # full-slot view for ffn

    # ------------------------------------------------------------- ffn
    slot_w = {
        "w_gate": jnp.asarray(rng.normal(size=(S, d, d_ff)) * 0.02, jnp.float32),
        "w_up": jnp.asarray(rng.normal(size=(S, d, d_ff)) * 0.02, jnp.float32),
        "w_down": jnp.asarray(rng.normal(size=(S, d_ff, d)) * 0.02, jnp.float32),
    }
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        ffn_fn = jax.jit(lambda r: kernel_ops.moe_gemm(r, slot_w, activation))
    else:
        ffn_fn = jax.jit(lambda r: dsp.grouped_ffn(slot_w, r, activation))
    ys = jax.block_until_ready(ffn_fn(recv)).reshape(S * cap, d)

    # --------------------------------------------------------- combine
    def combine_fn(y_recv, g):
        y_flat = jnp.where(in_cap[:, None],
                           y_recv[jnp.minimum(dest, S * cap - 1)], 0.0)
        return (y_flat.reshape(T, K, d) * g[..., None]).sum(axis=1)
    combine_jit = jax.jit(combine_fn)
    jax.block_until_ready(combine_jit(ys, gates))

    times = {
        "route": _time(route_fn, router_params, x, iters=iters),
        "pack": _time(pack_fn, x, gslot, iters=iters),
        "a2a": _time(a2a_jit, send, iters=iters),
        "ffn": _time(ffn_fn, recv, iters=iters),
        "combine": _time(combine_jit, ys, gates, iters=iters),
    }
    times["total"] = sum(times[p] for p in PHASES)
    return times


def migrate_phase_time(*, d_model: int = 256, d_ff: int = 256,
                       num_experts: int = 64, ranks: int = 4,
                       dup_slots: int = 1, layers: int = 2, chunk: int = 8,
                       iters: int = 5, seed: int = 0) -> Dict[str, float]:
    """Device-side cost of ONE fixed-shape replica-migration chunk (gather
    from the home expert stacks + masked scatter into the slot store) at
    representative shapes, plus the host-side cost of merely ISSUING that
    chunk without blocking (the ``prefetch`` phase). The wire term of a
    migration is modeled by ``repro.runtime.cost`` — ``migrate`` times the
    local work that brackets it, mirroring how the ``a2a`` phase times the
    layout transform around the dispatch collective; ``prefetch`` is the
    only part an OVERLAPPED fill charges the serving critical path (the
    execution itself rides under forward compute), so step-time accounting
    must not lump ``migrate`` into overlapped steps. Returns
    ``{"migrate": seconds, "prefetch": seconds}``."""
    from repro.core.placement import identity_plan, stack_plans
    from repro.runtime import ReplicaStore, make_migrate_step

    if num_experts % ranks:
        ranks = 1
    rng = np.random.default_rng(seed)
    E, L = num_experts, layers
    experts = {
        "w_gate": jnp.asarray(rng.normal(size=(L, E, d_model, d_ff)) * 0.02,
                              jnp.float32),
        "w_up": jnp.asarray(rng.normal(size=(L, E, d_model, d_ff)) * 0.02,
                            jnp.float32),
        "w_down": jnp.asarray(rng.normal(size=(L, E, d_ff, d_model)) * 0.02,
                              jnp.float32),
    }
    plan = stack_plans([identity_plan(E, ranks, dup_slots, 4)
                        for _ in range(L)])
    store = ReplicaStore.from_params(experts, plan, num_experts=E,
                                     ep_ranks=ranks, dup_slots=dup_slots)
    step = make_migrate_step(None, num_experts=E, ep_ranks=ranks,
                             dup_slots=dup_slots)
    n_slots = E // ranks + dup_slots
    layer = jnp.asarray(rng.integers(0, L, chunk), jnp.int32)
    dst = jnp.asarray((rng.integers(0, ranks, chunk) * n_slots
                       + E // ranks + rng.integers(0, dup_slots, chunk)),
                      jnp.int32)
    src = jnp.asarray(rng.integers(0, E, chunk), jnp.int32)
    valid = jnp.ones((chunk,), bool)
    t = _time(step, store.weights, experts, layer, dst, src, valid,
              iters=iters)
    # issue-only cost: enqueue the chunk WITHOUT waiting for its result —
    # the critical-path charge of an overlapped (async-prefetch) fill
    best_issue = math.inf
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = step(store.weights, experts, layer, dst, src, valid)
        best_issue = min(best_issue, time.perf_counter() - t0)
        jax.block_until_ready(out)       # drain before the next round
    return {MIGRATE_PHASE: t, PREFETCH_PHASE: best_issue}


def _paged_attn_inputs(*, batch: int, num_kv: int, gqa: int, head_dim: int,
                       block_size: int, max_blocks: int, valid_frac: float,
                       dtype, seed: int):
    """Representative paged-decode state: every slot allocates the full
    ``max_blocks`` table row but only ``valid_frac`` of it holds live
    tokens — the regime where the gather path's HBM traffic is fixed at
    the allocated view while the fused kernel walks only valid blocks."""
    rng = np.random.default_rng(seed)
    B, bs, K, hd, M = batch, block_size, num_kv, head_dim, max_blocks
    N = 1 + B * M                                    # block 0 = null
    q = jnp.asarray(rng.normal(size=(B, K, gqa, hd)), dtype)
    k_pool = jnp.asarray(rng.normal(size=(N, bs, K, hd)), dtype)
    v_pool = jnp.asarray(rng.normal(size=(N, bs, K, hd)), dtype)
    tables = jnp.asarray(
        1 + np.arange(B * M, dtype=np.int32).reshape(B, M))
    valid = max(1, int(M * bs * valid_frac))
    lengths = jnp.asarray(
        rng.integers(max(1, valid // 2), valid, size=B), jnp.int32)
    return q, k_pool, v_pool, tables, lengths


def attn_phase_times(*, batch: int = 8, num_kv: int = 8, gqa: int = 4,
                     head_dim: int = 128, block_size: int = 16,
                     max_blocks: int = 32, valid_frac: float = 0.25,
                     window: int = 0, impl: str = "fused",
                     dtype=jnp.bfloat16, iters: int = 5,
                     seed: int = 0) -> Dict[str, float]:
    """Time one paged-decode attention step at serving shapes. Returns
    ``{"attn": seconds}`` for the selected ``paged_attn_impl`` so engines
    can record it alongside the dispatch phase breakdown."""
    from repro.kernels import ops as kernel_ops
    from repro.kernels import ref as kernel_ref

    q, k_pool, v_pool, tables, lengths = _paged_attn_inputs(
        batch=batch, num_kv=num_kv, gqa=gqa, head_dim=head_dim,
        block_size=block_size, max_blocks=max_blocks,
        valid_frac=valid_frac, dtype=dtype, seed=seed)
    B, K, _, hd = q.shape
    if impl == "fused":
        fn = jax.jit(lambda q_, k_, v_: kernel_ops.paged_decode_attention(
            q_, k_, v_, tables, lengths, window=window))
    else:
        def gather(q_, k_, v_):
            k_view = k_[tables].reshape(B, -1, K, hd)
            v_view = v_[tables].reshape(B, -1, K, hd)
            return kernel_ref.paged_decode_ref(
                q_, k_view, v_view, lengths, window=window,
                block_size=block_size)
        fn = jax.jit(gather)
    return {ATTN_PHASE: _time(fn, q, k_pool, v_pool, iters=iters)}


def attn_impl_times(*, batch: int = 8, num_kv: int = 8, gqa: int = 4,
                    head_dim: int = 128, block_size: int = 16,
                    max_blocks: int = 32, valid_frac: float = 0.25,
                    window: int = 0, dtype=jnp.bfloat16, iters: int = 5,
                    seed: int = 0) -> Dict[str, float]:
    """Head-to-head paged-decode attention timing: the fused Pallas kernel
    vs the materialize-then-attend gather oracle on identical pool state,
    measured INTERLEAVED round by round (same protocol as
    ``pack_impl_times``). Returns {"fused": s, "gather": s} best-of."""
    from repro.kernels import ops as kernel_ops
    from repro.kernels import ref as kernel_ref

    q, k_pool, v_pool, tables, lengths = _paged_attn_inputs(
        batch=batch, num_kv=num_kv, gqa=gqa, head_dim=head_dim,
        block_size=block_size, max_blocks=max_blocks,
        valid_frac=valid_frac, dtype=dtype, seed=seed)
    B, K, _, hd = q.shape

    def gather(q_, k_, v_):
        k_view = k_[tables].reshape(B, -1, K, hd)
        v_view = v_[tables].reshape(B, -1, K, hd)
        return kernel_ref.paged_decode_ref(
            q_, k_view, v_view, lengths, window=window,
            block_size=block_size)

    fns = {
        "fused": jax.jit(lambda q_, k_, v_: kernel_ops.paged_decode_attention(
            q_, k_, v_, tables, lengths, window=window)),
        "gather": jax.jit(gather),
    }
    for fn in fns.values():
        jax.block_until_ready(fn(q, k_pool, v_pool))     # compile + warm
    best = {impl: math.inf for impl in fns}
    for _ in range(max(iters, 1)):
        for impl, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(q, k_pool, v_pool))
            best[impl] = min(best[impl], time.perf_counter() - t0)
    return best


def pack_impl_times(*, d_model: int = 256, num_experts: int = 64,
                    top_k: int = 2, tokens: int = 4096,
                    capacity_factor: float = 1.25, iters: int = 10,
                    seed: int = 0) -> Dict[str, float]:
    """Head-to-head pack-phase timing: both ``dispatch_impl`` formulations
    on identical inputs, measured INTERLEAVED round by round so machine
    drift (CPU contention, allocator state) hits both equally. Returns
    {"sort": s, "onehot": s} best-of-``iters``."""
    rng = np.random.default_rng(seed)
    T, K, E, d = tokens, top_k, num_experts, d_model
    N = T * K
    S = E
    cap = dsp.capacity(T, K, S, capacity_factor)
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    token_of = jnp.arange(N, dtype=jnp.int32) // K
    gslot = jnp.asarray(rng.integers(0, S, N), jnp.int32)
    valid = jnp.ones((N,), bool)

    fns = {}
    for impl, pack in dsp._PACKERS.items():
        fn = jax.jit(lambda x_, g_, p=pack: p(
            x_, token_of, g_, valid, num_classes=S, cap=cap))
        jax.block_until_ready(fn(x, gslot))          # compile + warm
        fns[impl] = fn
    best = {impl: math.inf for impl in fns}
    for _ in range(max(iters, 1)):
        for impl, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x, gslot))
            best[impl] = min(best[impl], time.perf_counter() - t0)
    return best
