"""Expert-parallel MoE dispatch with placement-aware duplication.

Runs inside ``shard_map`` over the ``model`` mesh axis (EP ranks = R).
Every rank hosts ``E_loc = E/R`` home experts plus ``D`` replica slots.

Pipeline per rank (T = local tokens, S = R * n_slots global slots):

  1. (optional) resolve slot weights. With a resident
     ``repro.runtime.ReplicaStore`` shard threaded in (``slot_weights``),
     replica weights are already placed — no collective. Otherwise fill
     the replica pool per step: each source rank contributes ONE expert's
     weights; ``all_gather`` makes the pool of R candidates available
     everywhere (paper Sec 5 transfer model — that collective is the
     per-step duplication overhead the store amortizes away), skipped
     under an identity plan.
  2. route tokens (true router or an external predicted assignment).
  3. pick a replica per (token, k): round-robin over ``n_replicas[e]``.
  4. capacity-dispatch: pack tokens into a (S * C, d) send buffer —
     argsort + histogram-offset gather (``dispatch_impl="sort"``, the
     fast path) or one-hot cumsum + scatter (``"onehot"``, the reference
     oracle) — then ``all_to_all`` over the model axis.
  5. grouped expert FFN on the received (n_slots, R * C, d) block
     (pure-jnp einsum or the Pallas ``moe_gemm`` kernel).
  6. reverse ``all_to_all``; weighted combine with router gates.

Token-to-Expert predicted mode dispatches on *predicted* assignments first
(step 2 uses the prediction; overlappable with attention upstream), then
runs a second, capacity-reduced correction round for mispredicted pairs —
communication grows with the error rate exactly as the paper models.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core.placement import PlacementPlan, plan_dims
from repro.moe.router import RouterOutput


class MoEStats(NamedTuple):
    expert_counts: jnp.ndarray   # (E,) tokens routed per expert (global)
    slot_counts: jnp.ndarray     # (S,) tokens per global slot (global)
    dropped: jnp.ndarray         # scalar: tokens dropped by capacity
    aux_loss: jnp.ndarray
    z_loss: jnp.ndarray
    overflow: jnp.ndarray = 0    # scalar: round-1 capacity overflows (tokens
                                 # the reschedule rescue round tried to save;
                                 # 0 when rescheduling is off)


def capacity(t_local: int, top_k: int, num_slots_global: int, factor: float,
             multiple: int = 8) -> int:
    c = math.ceil(t_local * top_k / num_slots_global * factor)
    return max(multiple, math.ceil(c / multiple) * multiple)


def _positions_in_slot(gslot: jnp.ndarray, num_slots: int) -> jnp.ndarray:
    """Rank of each element within its slot group (one-hot cumsum trick).
    gslot: (N,) int32 in [0, num_slots). Returns (N,) int32."""
    oh = jax.nn.one_hot(gslot, num_slots, dtype=jnp.int32)      # (N, S)
    pos = jnp.cumsum(oh, axis=0) - 1
    return jnp.take_along_axis(pos, gslot[:, None], axis=1)[:, 0]


# ---------------------------------------------------------------------------
# send-buffer packing (the dispatch hot path)
#
# Both packers share one contract: assignments (token_of, gslot, valid) plus
# a per-slot capacity produce a zero-padded (num_classes * cap, d) send
# buffer, in-capacity mask, send-buffer destinations, per-slot counts and the
# dropped-token count. The drop rule is FIRST-COME within each slot in token
# order — ``_pack_sort`` relies on ``argsort`` stability to reproduce the
# one-hot oracle's decisions bit for bit.
# ---------------------------------------------------------------------------

def _pack_onehot(x, token_of, gslot, valid, *, num_classes: int, cap: int,
                 use_kernel: bool = False):
    """Reference oracle: (N, S+1) one-hot cumsum positions + scatter.

    O(N * S) work and a serialized scatter — the slowest correct
    formulation, kept as the equivalence oracle for ``_pack_sort``.
    """
    del use_kernel
    d = x.shape[1]
    g = jnp.where(valid, gslot, num_classes)        # invalid -> overflow class
    pos = _positions_in_slot(g, num_classes + 1)    # invalid don't eat capacity
    in_cap = (pos < cap) & valid
    dest = jnp.where(in_cap, g * cap + pos, num_classes * cap)
    send = jnp.zeros((num_classes * cap + 1, d), x.dtype).at[dest].set(
        x[token_of], mode="drop")[:-1]
    counts = jnp.zeros((num_classes,), jnp.int32).at[
        jnp.minimum(g, num_classes - 1)].add(in_cap.astype(jnp.int32))
    dropped = (valid & ~in_cap).sum()
    return send, in_cap, dest, counts, dropped


def _pack_sort(x, token_of, gslot, valid, *, num_classes: int, cap: int,
               use_kernel: bool = False):
    """Fast path: stable argsort + histogram-offset slot assignment.

    Positions within a slot come from a class histogram's exclusive prefix
    sum instead of an (N, S) one-hot cumsum, and the send buffer is built
    by GATHERING the sorted tokens into each slot's contiguous range
    instead of scattering — O(N log N + S*cap) and fully vectorizable.
    ``use_kernel`` routes the histogram through the Pallas kernel (TPU).
    """
    d = x.shape[1]
    N = gslot.shape[0]
    g = jnp.where(valid, gslot, num_classes)        # invalid -> overflow class
    order = jnp.argsort(g)                          # stable: token order kept
    g_sorted = g[order]
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        hist, starts = kernel_ops.histogram_offsets(g, num_classes + 1)
    else:
        hist = jnp.zeros((num_classes + 1,), jnp.int32).at[g].add(1)
        starts = jnp.cumsum(hist) - hist            # exclusive prefix sum
    pos_sorted = jnp.arange(N, dtype=jnp.int32) - starts[g_sorted]
    pos = jnp.zeros((N,), jnp.int32).at[order].set(pos_sorted)
    in_cap = (pos < cap) & valid
    dest = jnp.where(in_cap, g * cap + pos, num_classes * cap)
    # slot s's send range [s*cap, s*cap + min(hist[s], cap)) gathers the
    # sorted run starting at starts[s]; the rest of the buffer stays zero.
    fill = starts[:num_classes, None] + jnp.arange(cap, dtype=jnp.int32)
    fill_ok = (jnp.arange(cap, dtype=jnp.int32)[None, :]
               < jnp.minimum(hist[:num_classes], cap)[:, None])
    tok_sorted = token_of[order]                                # (N,)
    src = tok_sorted[jnp.clip(fill, 0, N - 1)]                  # (S, cap)
    send = jnp.where(fill_ok[..., None], x[src], 0).reshape(
        num_classes * cap, d)
    counts = jnp.minimum(hist[:num_classes], cap)
    dropped = jnp.maximum(hist[:num_classes] - cap, 0).sum()
    return send, in_cap, dest, counts, dropped


_PACKERS = {"onehot": _pack_onehot, "sort": _pack_sort}


def choose_replica(plan: PlacementPlan, expert: jnp.ndarray,
                   salt: jnp.ndarray) -> jnp.ndarray:
    """Round-robin replica choice. expert, salt: (N,). Returns global slot."""
    n_rep = plan.n_replicas[expert]                              # (N,)
    choice = salt % jnp.maximum(n_rep, 1)
    return plan.replica_table[expert, jnp.minimum(choice, plan.max_copies - 1)]


# quota draw constants — must match repro.schedule.base (kept literal here so
# the dispatch hot path never imports the host-side scheduler package)
_RESCHED_Q = 1 << 16
_RESCHED_MULT = 40503        # odd -> coprime with 2^16 -> equidistributed
_RESCHED_EXPERT = 131


def choose_replica_quota(plan: PlacementPlan, quota: jnp.ndarray,
                         expert: jnp.ndarray, salt: jnp.ndarray,
                         shift: int = 0) -> jnp.ndarray:
    """Quota-weighted replica choice (the reschedule lever's routing map).

    ``quota``: (E, C_max) int32 cumulative thresholds in [0, RESCHED_Q]
    from ``repro.schedule`` (dead copy columns pinned to RESCHED_Q). A
    hashed uniform draw per (token, k) is compared against the expert's
    thresholds, so realized per-copy shares track the scheduler's quotas.
    ``shift`` rotates the choice to the expert's next copy — the rescue
    round uses ``shift=1`` to re-aim overflow tokens at an alternate slot.
    """
    u = ((salt + expert * _RESCHED_EXPERT) * _RESCHED_MULT) % _RESCHED_Q
    choice = (quota[expert] <= u[:, None]).sum(axis=1).astype(jnp.int32)
    n_rep = jnp.maximum(plan.n_replicas[expert], 1)
    choice = (choice + shift) % n_rep
    return plan.replica_table[expert, jnp.minimum(choice, plan.max_copies - 1)]


def _global_positions(gslot: jnp.ndarray, valid: jnp.ndarray,
                      num_classes: int) -> jnp.ndarray:
    """First-come position of each assignment within its global slot (same
    ordering rule as the packers, computed over ALL classes so replicated
    ranks agree on which tokens overflow). Returns (N,) int32."""
    N = gslot.shape[0]
    g = jnp.where(valid, gslot, num_classes)
    order = jnp.argsort(g)                            # stable
    hist = jnp.zeros((num_classes + 1,), jnp.int32).at[g].add(1)
    starts = jnp.cumsum(hist) - hist
    pos_sorted = jnp.arange(N, dtype=jnp.int32) - starts[g[order]]
    return jnp.zeros((N,), jnp.int32).at[order].set(pos_sorted)


def gather_replica_pool(expert_weights: dict, plan: PlacementPlan,
                        axis_name: str) -> dict:
    """Step 1: every rank contributes one expert; all_gather the pool.

    expert_weights: {name: (E_loc, ...)}. Returns {name: (R, ...)} pool.
    """
    rank = jax.lax.axis_index(axis_name)
    e_loc = next(iter(expert_weights.values())).shape[0]
    local_idx = plan.pool_expert[rank] % e_loc                  # home expert -> local
    contrib = {k: w[local_idx] for k, w in expert_weights.items()}
    return {k: jax.lax.all_gather(v, axis_name, axis=0) for k, v in contrib.items()}


def _slot_weights(expert_weights: dict, pool: Optional[dict],
                  plan: PlacementPlan, dup_slots: int, axis_name: str) -> dict:
    """Per-slot weight stack: home experts + replica slots from the pool."""
    if dup_slots == 0 or pool is None:
        return expert_weights
    rank = jax.lax.axis_index(axis_name)
    sel = plan.pool_sel[rank, :dup_slots]                       # (D,) pool entries
    out = {}
    for k, w in expert_weights.items():
        out[k] = jnp.concatenate([w, pool[k][sel]], axis=0)     # (n_slots, ...)
    return out


def _resolve_slot_weights(expert_weights: dict, slot_weights: Optional[dict],
                          plan: PlacementPlan, dup_slots: int, ranks: int,
                          axis_name: str) -> dict:
    """Per-rank (n_slots, ...) slot weights for this step.

    ``slot_weights`` (the persistent ``repro.runtime.ReplicaStore`` shard)
    wins when threaded in: replica weights are already resident, NO
    collective. Otherwise the per-step gather pool is built — skipped via
    ``lax.cond`` when the plan is the identity stack (no expert has a
    second replica), since replica-slot contents are unreachable then and
    zeros serve as well as a gathered pool.
    """
    if slot_weights is not None:
        return slot_weights
    pool = None
    if dup_slots > 0:
        def gather():
            return gather_replica_pool(expert_weights, plan, axis_name)

        def empty():
            return {k: jnp.zeros((ranks,) + w.shape[1:], w.dtype)
                    for k, w in expert_weights.items()}

        # plan arrays are replicated, so every rank takes the same branch
        pool = jax.lax.cond(jnp.any(plan.n_replicas > 1), gather, empty)
    return _slot_weights(expert_weights, pool, plan, dup_slots, axis_name)


def grouped_ffn(slot_w: dict, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    """x: (n_slots, T_s, d) -> (n_slots, T_s, d). Pure-jnp grouped expert FFN
    (the Pallas `moe_gemm` kernel implements the same contraction)."""
    if activation == "swiglu":
        g = jnp.einsum("std,sdf->stf", x, slot_w["w_gate"].astype(x.dtype))
        u = jnp.einsum("std,sdf->stf", x, slot_w["w_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("std,sdf->stf", x, slot_w["w_up"].astype(x.dtype))
        h = jax.nn.gelu(h) if activation == "gelu" else jax.nn.relu(h)
    return jnp.einsum("stf,sfd->std", h, slot_w["w_down"].astype(x.dtype))


def _dispatch_round(x, gslot, valid, *, num_slots: int, ranks: int, cap: int,
                    axis_name: str, slot_w: dict, activation: str,
                    use_kernel: bool = False, impl: str = "sort"):
    """One dispatch -> FFN -> combine round.

    x: (T, d); gslot, valid: (N,) flattened (token, k) assignments with
    token index = n // K. Returns y_flat: (N, d) per-assignment outputs
    (zeros where dropped/invalid) plus per-slot counts, drop count and the
    in-capacity mask (which the reschedule rescue round keys off).
    ``impl`` selects the send-buffer packer (see ``_PACKERS``).
    """
    T, d = x.shape
    N = gslot.shape[0]
    K = N // T
    S = ranks * num_slots
    token_of = jnp.arange(N, dtype=jnp.int32) // K

    send, in_cap, dest, slot_counts, dropped = _PACKERS[impl](
        x, token_of, gslot, valid, num_classes=S, cap=cap,
        use_kernel=use_kernel)
    send = send.reshape(ranks, num_slots * cap, d)
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    # recv: (R_src, n_slots * cap, d) -> (n_slots, R_src * cap, d)
    recv = recv.reshape(ranks, num_slots, cap, d).transpose(1, 0, 2, 3) \
               .reshape(num_slots, ranks * cap, d)

    if use_kernel:
        from repro.kernels import ops as kernel_ops
        y_slots = kernel_ops.moe_gemm(recv, slot_w, activation)
    else:
        y_slots = grouped_ffn(slot_w, recv, activation)

    y_back = y_slots.reshape(num_slots, ranks, cap, d).transpose(1, 0, 2, 3) \
                    .reshape(ranks, num_slots * cap, d)
    y_recv = jax.lax.all_to_all(y_back, axis_name, split_axis=0, concat_axis=0,
                                tiled=False).reshape(S * cap, d)
    y_flat = jnp.where(in_cap[:, None],
                       y_recv[jnp.minimum(dest, S * cap - 1)], 0.0)
    return y_flat, slot_counts, dropped, in_cap


def ep_moe_ffn(
    x: jnp.ndarray,                      # (T, d) local tokens
    router_out: RouterOutput,            # from repro.moe.router.route
    expert_weights: dict,                # {w_gate/w_up/w_down: (E_loc, ...)}
    plan: PlacementPlan,
    moe: MoEConfig,
    *,
    axis_name: str,
    ep_ranks: int,
    activation: str = "swiglu",
    use_duplication: bool = True,
    predicted_idx: Optional[jnp.ndarray] = None,   # (T, K) predicted experts
    correction_cap_frac: float = 0.25,
    use_kernel: bool = False,
    slot_weights: Optional[dict] = None,  # resident per-rank (n_slots, ...) store
    resched_quota: Optional[jnp.ndarray] = None,  # (E, C_max) int32 quotas
) -> Tuple[jnp.ndarray, MoEStats]:
    """Placement-aware EP MoE FFN (see module docstring). Returns (y, stats).

    With ``resched_quota`` threaded in (the token-rescheduling lever,
    ``repro.schedule``), replica choice follows the scheduler's quotas
    instead of blind round-robin, and capacity-overflow tokens get a second
    *rescue* dispatch round aimed at an alternate copy — extra a2a bytes in
    exchange for absorbed drops, which is exactly how the GPS roofline
    costs the lever.
    """
    T, d = x.shape
    K = moe.top_k
    E = moe.num_experts
    dup_slots = moe.duplication_slots if use_duplication else 0
    e_loc, n_slots = plan_dims(E, ep_ranks, dup_slots)
    S = ep_ranks * n_slots
    cap = capacity(T, K, S, moe.capacity_factor)

    slot_w = _resolve_slot_weights(expert_weights, slot_weights, plan,
                                   dup_slots, ep_ranks, axis_name)

    true_idx = router_out.expert_idx                             # (T, K)
    gates = router_out.gates.astype(x.dtype)                     # (T, K)
    salt = (jnp.arange(T, dtype=jnp.int32)[:, None] + jnp.arange(K)[None, :])
    flat = lambda a: a.reshape(-1)

    impl = moe.dispatch_impl
    overflow = jnp.zeros((), jnp.int32)
    if predicted_idx is None:
        if resched_quota is None:
            gslot = choose_replica(plan, flat(true_idx), flat(salt))
        else:
            gslot = choose_replica_quota(plan, resched_quota,
                                         flat(true_idx), flat(salt))
        valid = jnp.ones((T * K,), bool)
        y_flat, slot_counts, dropped, in_cap = _dispatch_round(
            x, gslot, valid, num_slots=n_slots, ranks=ep_ranks, cap=cap,
            axis_name=axis_name, slot_w=slot_w, activation=activation,
            use_kernel=use_kernel, impl=impl)
        if resched_quota is not None:
            # --- rescue round: re-dispatch overflow to an alternate copy --
            miss = valid & ~in_cap
            overflow = miss.sum()
            cap2 = max(8, int(cap * moe.resched_cap_frac))
            gslot2 = choose_replica_quota(plan, resched_quota,
                                          flat(true_idx), flat(salt),
                                          shift=1)
            y2, slot_counts2, dropped, _ = _dispatch_round(
                x, gslot2, miss, num_slots=n_slots, ranks=ep_ranks,
                cap=cap2, axis_name=axis_name, slot_w=slot_w,
                activation=activation, use_kernel=use_kernel, impl=impl)
            y_flat = jnp.where(in_cap[:, None], y_flat, y2)
            slot_counts = slot_counts + slot_counts2
    else:
        # --- Token-to-Expert predicted mode: round 1 on predictions -------
        pred = predicted_idx.astype(jnp.int32)
        if resched_quota is None:
            pick = lambda e, s, sh: choose_replica(plan, e, s + sh)
        else:
            pick = lambda e, s, sh: choose_replica_quota(
                plan, resched_quota, e, s, shift=sh)
        gslot1 = pick(flat(pred), flat(salt), 0)
        valid1 = jnp.ones((T * K,), bool)
        y1, slot_counts, dropped1, _ = _dispatch_round(
            x, gslot1, valid1, num_slots=n_slots, ranks=ep_ranks, cap=cap,
            axis_name=axis_name, slot_w=slot_w, activation=activation,
            use_kernel=use_kernel, impl=impl)
        # --- round 2: correction for mispredicted (token, k) pairs --------
        correct = flat(pred) == flat(true_idx)
        cap2 = max(8, int(cap * correction_cap_frac))
        gslot2 = pick(flat(true_idx), flat(salt), 1)
        y2, slot_counts2, dropped2, _ = _dispatch_round(
            x, gslot2, ~correct, num_slots=n_slots, ranks=ep_ranks, cap=cap2,
            axis_name=axis_name, slot_w=slot_w, activation=activation,
            use_kernel=use_kernel, impl=impl)
        y_flat = jnp.where(correct[:, None], y1, y2)
        slot_counts = slot_counts + slot_counts2
        dropped = dropped1 + dropped2   # slight overcount: r1 drops of mispredicted pairs

    y = (y_flat.reshape(T, K, d) * gates[..., None]).sum(axis=1)

    counts = jnp.zeros((E,), jnp.float32).at[flat(true_idx)].add(1.0)
    stats = MoEStats(
        expert_counts=jax.lax.psum(counts, axis_name),
        slot_counts=jax.lax.psum(slot_counts, axis_name),
        dropped=jax.lax.psum(dropped, axis_name),
        aux_loss=jax.lax.pmean(router_out.aux_loss, axis_name),
        z_loss=jax.lax.pmean(router_out.z_loss, axis_name),
        overflow=jax.lax.psum(overflow, axis_name),
    )
    return y, stats


def ep_moe_ffn_replicated(
    x: jnp.ndarray,                      # (T, d) — SAME tokens on all EP ranks
    router_out: RouterOutput,
    expert_weights: dict,
    plan: PlacementPlan,
    moe: MoEConfig,
    *,
    axis_name: str,
    ep_ranks: int,
    activation: str = "swiglu",
    use_duplication: bool = True,
    predicted_idx=None,
    use_kernel: bool = False,
    tp_axis: Tuple[str, ...] = (),
    slot_weights: Optional[dict] = None,
    resched_quota: Optional[jnp.ndarray] = None,  # (E, C_max) int32 quotas
) -> Tuple[jnp.ndarray, MoEStats]:
    """Decode-path EP dispatch: tokens are replicated over the model axis
    (decode batches are too small to shard over it). Each rank computes the
    (token, k) pairs assigned to ITS slots; a psum combines results. The
    only dispatch communication is the (T, d) psum — appropriate for the
    latency-critical decode stage (paper Sec 2: balancing is secondary
    there, but duplication still helps the compute term).

    ``tp_axis``: 2D expert sharding for decode (EXPERIMENTS.md §Perf
    cycle 2) — expert d_ff is additionally sharded over this mesh axis, so
    weights stay fully sharded AND resident (no ZeRO re-gather per step).
    The activation is elementwise in d_ff, so each rank computes its
    f-shard's partial y and the final psum runs over (tp_axis, ep_axis)."""
    if predicted_idx is not None:
        raise NotImplementedError("predicted pre-routing is a prefill feature")
    T, d = x.shape
    K = moe.top_k
    E = moe.num_experts
    dup_slots = moe.duplication_slots if use_duplication else 0
    e_loc, n_slots = plan_dims(E, ep_ranks, dup_slots)
    S = ep_ranks * n_slots
    cap = capacity(T, K, n_slots, moe.capacity_factor)  # per-rank slot capacity

    slot_w = _resolve_slot_weights(expert_weights, slot_weights, plan,
                                   dup_slots, ep_ranks, axis_name)

    rank = jax.lax.axis_index(axis_name)
    flat = lambda a: a.reshape(-1)
    salt = (jnp.arange(T, dtype=jnp.int32)[:, None] + jnp.arange(K)[None, :])
    expert_flat = flat(router_out.expert_idx)
    if resched_quota is None:
        gslot = choose_replica(plan, expert_flat, flat(salt))
    else:
        gslot = choose_replica_quota(plan, resched_quota, expert_flat,
                                     flat(salt))
    mine = (gslot // n_slots) == rank
    token_of = jnp.arange(T * K, dtype=jnp.int32) // K

    def _local_ffn(send):
        xs = send.reshape(n_slots, cap, d)
        if use_kernel:
            from repro.kernels import ops as kernel_ops
            ys = kernel_ops.moe_gemm(xs, slot_w, activation)
        else:
            ys = grouped_ffn(slot_w, xs, activation)
        return ys.reshape(n_slots * cap, d)

    send, in_cap, dest, _, dropped = _PACKERS[moe.dispatch_impl](
        x, token_of, gslot % n_slots, mine, num_classes=n_slots, cap=cap,
        use_kernel=use_kernel)
    ys = _local_ffn(send)
    y_flat = jnp.where(in_cap[:, None], ys[jnp.minimum(dest, n_slots * cap - 1)],
                       0.0)
    overflow = jnp.zeros((), jnp.int32)
    if resched_quota is not None:
        # Rescue round: every rank recomputes the GLOBAL first-come
        # positions (tokens are replicated, so all ranks agree on which
        # (token, k) pairs overflowed), then serves the subset whose
        # alternate copy lands on one of its own slots.
        pos = _global_positions(gslot, jnp.ones_like(mine), S)
        miss = pos >= cap
        overflow = miss.sum()
        gslot2 = choose_replica_quota(plan, resched_quota, expert_flat,
                                      flat(salt), shift=1)
        mine2 = ((gslot2 // n_slots) == rank) & miss
        send2, in_cap2, dest2, _, dropped = _PACKERS[moe.dispatch_impl](
            x, token_of, gslot2 % n_slots, mine2, num_classes=n_slots,
            cap=cap, use_kernel=use_kernel)
        ys2 = _local_ffn(send2)
        y2 = jnp.where(in_cap2[:, None],
                       ys2[jnp.minimum(dest2, n_slots * cap - 1)], 0.0)
        y_flat = y_flat + y2            # disjoint masks: miss vs in-cap
    gates = router_out.gates.astype(x.dtype)
    y = (y_flat.reshape(T, K, d) * gates[..., None]).sum(axis=1)
    # tp_axis ranks hold d_ff shards: their y's are PARTIAL sums over f;
    # one psum over (tp, ep) both combines f-partials and slot results.
    y = jax.lax.psum(y, tuple(tp_axis) + (axis_name,) if tp_axis
                     else axis_name)

    counts = jnp.zeros((E,), jnp.float32).at[flat(router_out.expert_idx)].add(1.0)
    slot_counts = jnp.zeros((S,), jnp.int32).at[
        jnp.minimum(gslot, S - 1)].add(in_cap.astype(jnp.int32))
    if resched_quota is not None:
        slot_counts = slot_counts.at[jnp.minimum(gslot2, S - 1)].add(
            in_cap2.astype(jnp.int32))
    stats = MoEStats(
        expert_counts=counts,                       # already global (replicated)
        slot_counts=jax.lax.psum(slot_counts, axis_name),
        dropped=jax.lax.psum(dropped, axis_name),
        aux_loss=router_out.aux_loss,
        z_loss=router_out.z_loss,
        overflow=overflow,                          # global (computed replicated)
    )
    return y, stats
