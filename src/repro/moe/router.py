"""Top-k MoE router with load-balance auxiliary loss and router z-loss."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import truncated_normal_init


class RouterOutput(NamedTuple):
    expert_idx: jnp.ndarray     # (T, K) int32
    gates: jnp.ndarray          # (T, K) float32 (normalised over K)
    probs: jnp.ndarray          # (T, E) full softmax (for aux losses / stats)
    aux_loss: jnp.ndarray       # scalar
    z_loss: jnp.ndarray         # scalar


def init_router(key, d_model: int, moe: MoEConfig):
    return {"w": truncated_normal_init(key, (d_model, moe.num_experts), 0.02)}


def route(params, moe: MoEConfig, x, impl: str = "dense") -> RouterOutput:
    """x: (T, d) token-major. Returns top-k assignment + losses.

    ``impl="fused"`` runs the Pallas fused softmax/top-k/histogram kernel
    (`repro.kernels.topk_router`) — one VMEM pass instead of three ops —
    and derives the aux losses from the kernel's histogram and logsumexp
    outputs. Assignments are bit-compatible with the dense path.
    """
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        params["w"].astype(jnp.float32))
    E = moe.num_experts
    if impl == "fused":
        from repro.kernels import ops as kernel_ops
        expert_idx, gates, probs, lse, counts = kernel_ops.fused_topk_route(
            logits, moe.top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        f = counts.astype(jnp.float32) / expert_idx.size
        aux = E * jnp.sum(f * probs.mean(axis=0)) * moe.router_aux_loss
        z = jnp.mean(jnp.square(lse)) * moe.router_z_loss
        return RouterOutput(expert_idx, gates, probs, aux, z)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, moe.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    f = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (expert_idx.size))
    p_mean = probs.mean(axis=0)
    aux = E * jnp.sum(f * p_mean) * moe.router_aux_loss
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))) * moe.router_z_loss
    return RouterOutput(expert_idx.astype(jnp.int32), gates, probs, aux, z)


def expert_histogram(expert_idx, num_experts: int):
    """Token counts per expert. expert_idx: (..., K) -> (E,) float32."""
    return jnp.zeros((num_experts,), jnp.float32).at[
        expert_idx.reshape(-1)].add(1.0)
