"""Serving engine with the paper's predict -> plan -> dispatch pipeline.

Per prediction interval (default: every batch, paper Sec 3.1):

  1. observe per-layer expert histograms from the last batches' router
     stats (the Distribution-Only predictor's input — a free side-effect
     of dispatch) and/or run the Token-to-Expert predictor on the incoming
     batch;
  2. plan: Algorithm 1 (`duplicate_experts_host`) turns the predicted
     distribution into a PlacementPlan per MoE layer;
  3. dispatch: the next prefill executes with the new plan — replicated
     experts receive their tokens round-robin, balancing per-rank load.

The engine is strategy-agnostic: ``strategy`` selects none / dist_only /
token_to_expert exactly as in the paper, and `repro.core.gps` can be asked
which one to use for the deployment's (model, hardware, skew) point.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.duplication import duplicate_experts_host
from repro.core.placement import (PlacementPlan, identity_plan,
                                  quota_limited_plan, stack_plans)
from repro.core.predictors import DistributionEstimator
from repro.models.transformer import Runtime, init_cache
from repro.obs.accuracy import PredictorAccuracyTracker
from repro.obs.trace import NULL_TRACER
from repro.serve.kvcache import (BlockAllocator, init_block_pool,
                                 write_prefill_blocks)
from repro.serve.metrics import (RequestTiming, ServeMetrics, imbalance,
                                 plan_rank_loads)
from repro.serve.scheduler import (ContinuousScheduler, IterationPlan,
                                   ServeRequest)
from repro.train.steps import (make_decode_step, make_paged_decode_step,
                               make_prefill_replan_step, make_prefill_step,
                               make_slot_prefill_step)


class _nullcontext:
    def __enter__(self):
        return self
    def __exit__(self, *a):
        return False


def _clamp_store_dup_slots(cfg: ModelConfig, params, ep_ranks: int,
                           dup_slots: int) -> int:
    """Store-aware memory clamp shared by both engines: shrink the
    requested replica slots until the persistent store (a second copy of
    the home experts plus the replica slots) fits the per-rank HBM budget
    (``MoEConfig.store_hbm_budget_gb``; 0 = unlimited). Callers gate on
    store mode + a mesh — meshless engines never build a store."""
    if not (cfg.is_moe and dup_slots > 0
            and cfg.moe.replica_impl == "store"
            and cfg.moe.store_hbm_budget_gb > 0):
        return dup_slots
    from repro.core.placement import clamp_dup_slots
    from repro.runtime.cost import entry_bytes as _eb
    return clamp_dup_slots(
        cfg.moe.num_experts, ep_ranks, dup_slots,
        entry_bytes=_eb(params["layers"]["moe"]["experts"]),
        num_layers=cfg.num_layers,
        hbm_budget_bytes=cfg.moe.store_hbm_budget_gb * 1e9)


def _chunk_stall_split(moved_bytes: float, window_s: float, hw,
                       overlap: bool):
    """(hidden_s, exposed_s) of one tick's modeled wire time: overlapped
    fills hide up to one window of transfer under forward compute,
    synchronous fills expose everything."""
    from repro.runtime import cost as _c
    stall = _c.migration_stall_s(moved_bytes, hw)
    if not overlap:
        return 0.0, stall
    return _c.split_hidden_exposed(stall, window_s)


class _OverlapStoreMixin:
    """Overlapped-migration plumbing shared by ServeEngine and
    ContinuousEngine. Expects ``_store``, ``_executor``, ``_idle_ready``,
    ``cfg``, ``_current_plan()`` on the engine; engines define
    ``_overlap_active()``."""

    def _overlap_active(self) -> bool:
        raise NotImplementedError

    def _overlap_args(self):
        """(slot_weights_back, slot_ready, target_plan) threaded into the
        step fns. Idle steps pass live==back + all-False ready, so the
        jit signature (and hence the compiled program set) is identical
        whether or not a migration is in flight."""
        if self._store is None or not self._overlap_active():
            return None, None, None
        if self._executor is not None and self._executor.active:
            return (self._executor.back_weights,
                    jnp.asarray(self._executor.ready_mask()),
                    self._executor.target_plan)
        if self._idle_ready is None:
            self._idle_ready = jnp.zeros((self.cfg.num_layers,), bool)
        return self._store.weights, self._idle_ready, self._current_plan()


# ---------------------------------------------------------------------------
# XLA compile counting — the no-recompile guarantee under a mesh.
#
# ``jitted_fn._cache_size()`` is exact on a single device, but under a mesh
# the C++ fastpath adds one cache entry per call for freshly-minted GSPMD
# output shardings WITHOUT recompiling anything (observed on jax 0.4.37,
# verified against the backend-compile log). Meshed engines therefore count
# actual backend compilations through jax.monitoring instead.
# ---------------------------------------------------------------------------

_BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_xla_compiles = [0]
_compile_listener_installed = False


def _install_compile_listener():
    global _compile_listener_installed
    if _compile_listener_installed:
        return
    _compile_listener_installed = True
    from jax import monitoring

    def _on_event(event, duration, **kw):
        if event == _BACKEND_COMPILE_EVENT:
            _xla_compiles[0] += 1

    monitoring.register_event_duration_secs_listener(_on_event)


@dataclass
class ServeConfig:
    strategy: str = "dist_only"       # none | dist_only | token_to_expert
    predict_interval: int = 1         # batches between re-plans (paper Sec 3.1)
    dup_slots: int = 1                # replica slots per EP rank
    max_copies: int = 4               # Algorithm 1 C_max
    ema: float = 0.9                  # moving-average for the MLE estimator
    max_len: int = 2048               # KV-cache length for generation
    in_graph_replan: bool = False     # fuse Algorithm 1 into the prefill
                                      # step (no host round-trip per batch)
    migrate_chunk: int = 8            # slot entries per fixed-shape fill step
                                      # (store mode; overlap follows
                                      # MoEConfig.overlap_migration)
    # Balancing lever (combined strategy space, repro.schedule):
    #   duplicate   re-plan + migrate replica weights every interval
    #   reschedule  freeze the plan after its first adoption; rebalance by
    #               moving TOKENS across the frozen plan's copies (quota
    #               dispatch + overflow rescue round, no migration traffic)
    #   both        migrate on the interval AND token-schedule the residual
    lever: str = "duplicate"
    resched_impl: str = "greedy"      # greedy | lp (repro.schedule)


class ServeEngine(_OverlapStoreMixin):
    """Batched prefill+decode with dynamic expert duplication."""

    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig,
                 mesh=None, ep_ranks: int = 1, predictor=None, tracer=None):
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.mesh = mesh
        self.ep_ranks = ep_ranks
        self.predictor = predictor            # Token-to-Expert model (optional)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.batches_seen = 0
        self._plan_stack: Optional[PlacementPlan] = None
        self.history: List[Dict] = []         # per-batch balance telemetry
        # token rescheduling (repro.schedule): quota stack traced like the
        # plan; None when the duplicate lever runs alone
        self._resched_stack = None
        self._resched_sched = None
        self._resched_frozen = False
        self._store = None                    # repro.runtime.ReplicaStore
        self._migrate_fn = None
        self._executor = None                 # LayerStagedExecutor (overlap)
        self._idle_ready = None               # cached all-False ready mask
        self._recent_step_s = 0.0             # EMA, feeds the overlap budget
        self._step_moved = False              # this call issued fill chunks
        self._window_seeded = False           # first sample (compile) skipped
        self._adopt_ticks = 0
        self._last_migration: Dict = {}

        use_dup = cfg.is_moe and serve.strategy != "none"
        dup_slots = serve.dup_slots if use_dup else 0
        if mesh is not None:
            dup_slots = _clamp_store_dup_slots(cfg, params, ep_ranks,
                                               dup_slots)
            use_dup = use_dup and dup_slots > 0
        if cfg.is_moe:
            self.moe_cfg = dataclasses.replace(
                cfg.moe, duplication_slots=dup_slots,
                max_copies=serve.max_copies)
            self.cfg = dataclasses.replace(cfg, moe=self.moe_cfg)
            self.estimator = DistributionEstimator(
                cfg.num_layers, cfg.moe.num_experts, ema=serve.ema)
            self.accuracy = PredictorAccuracyTracker(
                cfg.num_layers, cfg.moe.num_experts)
        else:
            self.moe_cfg = None
            self.estimator = None
            self.accuracy = None

        self._rt_kw = dict(mesh=mesh, ep=mesh is not None,
                           ep_ranks=ep_ranks, use_duplication=use_dup)
        self._prefill = None
        self._decode = None

    # ------------------------------------------------------------------ plan
    def _identity_stack(self) -> Optional[PlacementPlan]:
        if not self.cfg.is_moe:
            return None
        m = self.moe_cfg
        plans = [identity_plan(m.num_experts, self.ep_ranks,
                               m.duplication_slots, m.max_copies)
                 for _ in range(self.cfg.num_layers)]
        return stack_plans(plans)

    def replan(self) -> Optional[PlacementPlan]:
        """Algorithm 1 per layer from the current distribution estimate.

        Lever "reschedule" adopts ONE plan and freezes it (later re-plans
        only refresh the token-scheduler quotas — zero migration traffic);
        "both" re-plans every interval AND refreshes quotas."""
        if not self.cfg.is_moe or self.serve.strategy == "none":
            return self._identity_stack()
        m = self.moe_cfg
        if (self.serve.lever == "reschedule" and self._resched_frozen
                and self._plan_stack is not None):
            self._replan_resched()
            return self._plan_stack
        dist = self.estimator.predict()                  # (L, E)
        plans = []
        for l in range(self.cfg.num_layers):
            res = duplicate_experts_host(dist[l], self.ep_ranks,
                                         m.duplication_slots, m.max_copies)
            plans.append(res.plan)
        self._plan_stack = self._adopt_plan(stack_plans(plans))
        if self.serve.lever == "reschedule":
            self._resched_frozen = True
        self._replan_resched()
        return self._plan_stack

    def _replan_resched(self):
        """Refresh the (L, E, C_max) quota stack against the plan in force
        (see ``ContinuousEngine._replan_resched``)."""
        if (self.serve.lever == "duplicate" or not self.cfg.is_moe
                or self.serve.strategy == "none"):
            self._resched_stack = None
            return
        from repro.moe.dispatch import capacity
        from repro.schedule import make_scheduler
        m = self.moe_cfg
        plan = self._current_plan()
        if plan is None:
            self._resched_stack = None
            return
        if self._resched_sched is None:
            self._resched_sched = make_scheduler(self.serve.resched_impl)
        dist = np.asarray(self.estimator.predict(), np.float64)
        tokens = float(getattr(self, "_last_prefill_tokens", 0) or 1024)
        counts = dist * tokens * m.top_k
        t_local = max(int(tokens) // self.ep_ranks, 1)
        n_slots_g = (m.num_experts // self.ep_ranks
                     + m.duplication_slots) * self.ep_ranks
        cap = capacity(t_local, m.top_k, n_slots_g,
                       m.capacity_factor) * self.ep_ranks
        layer_plans = [jax.tree.map(lambda a, l=l: np.asarray(a)[l], plan)
                       for l in range(self.cfg.num_layers)]
        quota, results = self._resched_sched.plan_stack(
            counts, layer_plans, ep_ranks=self.ep_ranks,
            dup_slots=m.duplication_slots, cap=float(cap))
        self._resched_stack = jnp.asarray(quota)
        if self.history:
            self.history[-1]["resched_absorbed_pred"] = float(np.mean(
                [r.overflow_absorbed_frac for r in results]))
            self.history[-1]["resched_residual"] = float(np.mean(
                [r.imbalance_sched for r in results])) - 1.0

    # --------------------------------------------------------- replica store
    @property
    def _store_mode(self) -> bool:
        """Persistent slot-weight buffers instead of the per-step pool
        gather. In-graph replanning keeps the gather oracle: its plan is a
        traced value, and migration is a host decision."""
        return (self.cfg.is_moe and self.mesh is not None
                and self.moe_cfg.duplication_slots > 0
                and self.moe_cfg.replica_impl == "store"
                and not self.serve.in_graph_replan)

    @property
    def _overlap_on(self) -> bool:
        return self._store_mode and self.moe_cfg.overlap_migration

    def _slot_weights_arg(self):
        if not self._store_mode:
            return None
        if self._store is None:
            from repro.runtime import (LayerStagedExecutor, ReplicaStore,
                                       make_migrate_step)
            m = self.moe_cfg
            experts = self.params["layers"]["moe"]["experts"]
            self._store = ReplicaStore.from_params(
                experts, self._current_plan(), num_experts=m.num_experts,
                ep_ranks=self.ep_ranks, dup_slots=m.duplication_slots,
                mesh=self.mesh)
            self._migrate_fn = make_migrate_step(
                self.mesh, num_experts=m.num_experts, ep_ranks=self.ep_ranks,
                dup_slots=m.duplication_slots)
            if self._overlap_on:
                self._executor = LayerStagedExecutor(
                    self._migrate_fn, experts, self._store.entry_bytes,
                    num_layers=self.cfg.num_layers,
                    chunk=self.serve.migrate_chunk, tracer=self.tracer)
        return self._store.weights

    def _overlap_active(self) -> bool:
        return self._overlap_on

    def _hw(self):
        from repro.core.simulator import A100_PCIE
        return A100_PCIE

    def _tick_migration(self):
        """Issue this step's overlapped chunk budget (async dispatch — the
        fills queue behind / alongside the forward programs instead of
        stalling between batches); swap plan + store on commit."""
        if self._executor is None or not self._executor.active:
            return
        from repro.runtime import cost as _c
        window = self._recent_step_s
        budget = _c.overlap_chunk_budget(
            window, chunk_entries=self._executor.chunk,
            entry_bytes=self._store.entry_bytes, hw=self._hw())
        ctx = self.mesh or _nullcontext()
        with ctx:
            commit, moved = self._executor.tick(budget)
        self._adopt_ticks += 1
        if moved:
            self._step_moved = True
            hidden, exposed = _chunk_stall_split(moved, window, self._hw(),
                                                 overlap=True)
            m = self._last_migration
            m["moved_bytes"] = m.get("moved_bytes", 0.0) + moved
            m["hidden_s"] = m.get("hidden_s", 0.0) + hidden
            m["exposed_s"] = m.get("exposed_s", 0.0) + exposed
        if commit is not None:
            weights, plan, se = commit
            self._store.adopt(weights, se)
            self._plan_stack = plan
            self._last_migration["steps_to_adopt"] = self._adopt_ticks

    def _adopt_plan(self, target: PlacementPlan) -> PlacementPlan:
        """Pay weight movement once per re-plan: migrate exactly the slots
        the plan switch changes. Synchronous drain-and-swap when
        ``overlap_migration`` is off (this engine re-plans between batches
        anyway); with overlap on, a layer-staged fill is begun instead and
        rides under the following prefill/decode steps — serving reads
        old-plan slots per layer until each layer's fill commits."""
        if not self._store_mode or self._store is None:
            self.tracer.instant("plan.switch", cat="plan", track="plan",
                                args={"batch": self.batches_seen})
            return target
        from repro.runtime import migrate_all, plan_diff, plans_equal
        if (self._overlap_on and self._executor.active
                and plans_equal(self._executor.target_plan, target)):
            # the re-plan reproduced the in-flight target (stable traffic
            # quantizes to the same plan every interval): keep filling —
            # restarting would zero the cursor every batch and a diff
            # larger than one interval's budget would never commit
            return self._current_plan()
        m = self.moe_cfg
        diff = plan_diff(self._current_plan(), target, self.ep_ranks,
                         m.duplication_slots)
        moved = diff.num_entries * self._store.entry_bytes
        self._last_migration = {"entries": diff.num_entries, "bytes": moved}
        self.tracer.instant("plan.switch", cat="plan", track="plan",
                            args={"batch": self.batches_seen,
                                  "entries": int(diff.num_entries),
                                  "bytes": float(moved)})
        if diff.num_entries == 0:
            if self._executor is not None:
                self._executor.cancel()
            return target
        if self._overlap_on:
            self._executor.begin(self._store.weights, diff, target)
            self._adopt_ticks = 0
            return self._current_plan()     # old plan until commits land
        weights = migrate_all(
            self._migrate_fn, self._store.weights,
            self.params["layers"]["moe"]["experts"], diff)
        self._store.adopt(weights, diff.target_slot_experts)
        return target

    def _current_plan(self) -> Optional[PlacementPlan]:
        if self._plan_stack is None:
            self._plan_stack = self._identity_stack()
        return self._plan_stack

    def _runtime(self) -> Runtime:
        return Runtime(**self._rt_kw)

    def _steps(self):
        """Build + jit the step functions ONCE; plan/predictions are traced
        arguments so replanning never recompiles."""
        if self._prefill is None:
            rt = self._runtime()
            in_graph = (self.serve.in_graph_replan and self.cfg.is_moe
                        and self.serve.strategy == "dist_only")
            builder = (make_prefill_replan_step if in_graph
                       else make_prefill_step)
            self._prefill = jax.jit(builder(self.cfg, rt))
            self._in_graph = in_graph
            self._decode = jax.jit(make_decode_step(self.cfg, rt),
                                   static_argnums=(3,))
        return self._prefill, self._decode

    # --------------------------------------------------------------- predict
    def _predict_tokens(self, tokens: np.ndarray) -> Optional[jnp.ndarray]:
        """Token-to-Expert pre-routing: (L, B, S) -> (L, B*S, K) slots."""
        if self.serve.strategy != "token_to_expert" or self.predictor is None:
            return None
        pred = self.predictor.predict(np.asarray(tokens))          # (L, B, S)
        K = self.moe_cfg.top_k
        # top-1 prediction broadcast over k (paper predicts the top-1 expert)
        return jnp.asarray(pred)[..., None].repeat(K, -1)          # (L,B,S,K)

    # ----------------------------------------------------------------- steps
    def prefill(self, batch: Dict, cache=None):
        import time as _time
        t0 = _time.perf_counter()
        tokens = batch["tokens"]
        B, S = tokens.shape
        pred = self._predict_tokens(tokens)
        prefill_step, _ = self._steps()
        if cache is None:
            cache = init_cache(self.cfg, self._runtime(), B, self.serve.max_len)
        self._slot_weights_arg()     # materialize store + executor lazily
        self._step_moved = False
        self._tick_migration()       # overlapped fills ride this step
        # read plan AND weights only after the tick: a commit swaps both
        # atomically, and a (new plan, pre-commit weights) mix would serve
        # replica slots holding the wrong expert
        slot_w = self._slot_weights_arg()
        plan = self._current_plan()
        back_w, ready, tplan = self._overlap_args()
        self._last_prefill_tokens = B * S
        ctx = self.mesh or _nullcontext()
        with ctx:
            if getattr(self, "_in_graph", False):
                logits, cache, stats, next_plan = prefill_step(
                    self.params, batch, cache, plan, pred)
                self._plan_stack = next_plan
            else:
                logits, cache, stats = prefill_step(
                    self.params, batch, cache, plan, pred, slot_w,
                    back_w, ready, tplan, self._resched_stack)
        self._observe(stats, num_tokens=B * S,
                      skip_replan=getattr(self, "_in_graph", False))
        dt = _time.perf_counter() - t0
        self.tracer.add_span("prefill", dt,
                             ts_ns=self.tracer.now_ns() - int(dt * 1e9),
                             args={"batch": B, "tokens": B * S})
        self._note_step_time(dt)
        return logits, cache, stats

    def decode(self, tokens, cache, cache_len: int):
        _, decode_step = self._steps()
        self._slot_weights_arg()     # materialize store + executor lazily
        self._step_moved = False
        self._tick_migration()
        slot_w = self._slot_weights_arg()    # post-commit view (see prefill)
        plan = self._current_plan()
        back_w, ready, tplan = self._overlap_args()
        ctx = self.mesh or _nullcontext()
        with self.tracer.span("decode", args={"cache_len": cache_len}):
            with ctx:
                next_tok, logits, cache, stats = decode_step(
                    self.params, tokens, cache, cache_len, plan, slot_w,
                    back_w, ready, tplan, self._resched_stack)
        return next_tok, logits, cache, stats

    def _note_step_time(self, dt: float):
        """EMA of the MIGRATION-FREE prefill wall time — the overlap
        window the chunk budget is sized against. Only prefill feeds it:
        decode compiles a fresh program per static ``cache_len``, so its
        walls are compile-dominated and would inflate the window by
        orders of magnitude. Steps that issued fill chunks are excluded
        too (their wall includes the fills), and the very first sample is
        discarded (it includes the prefill compile)."""
        if self._step_moved:
            return
        if not self._window_seeded:
            self._window_seeded = True
            return
        self._recent_step_s = (dt if self._recent_step_s <= 0
                               else 0.9 * self._recent_step_s + 0.1 * dt)

    def generate(self, batch: Dict, max_new_tokens: int = 8):
        """Prefill + greedy decode; returns (generated (B, T), telemetry)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        logits, cache, _ = self.prefill(batch, cache=None)
        next_tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
        out = [next_tok]
        for t in range(max_new_tokens - 1):
            next_tok, _, cache, _ = self.decode(next_tok, cache, S + t)
            out.append(next_tok)
        return jnp.concatenate(out, axis=1), self.history[-1] if self.history else {}

    # -------------------------------------------------------------- observe
    def _observe(self, stats: Dict, num_tokens: int,
                 skip_replan: bool = False):
        """Feed router histograms to the estimator; replan on the interval."""
        self.batches_seen += 1
        if not self.cfg.is_moe or stats.get("expert_counts") is None:
            return
        counts = np.asarray(stats["expert_counts"], np.float64)   # (L, E)
        self.estimator.update(counts)
        self.accuracy.observe(counts)
        tele = {"batch": self.batches_seen,
                "skew": float(counts.sum(0).max()
                              / max(counts.sum(0).mean(), 1e-9))}
        for key in ("dropped", "overflow"):
            if stats.get(key) is not None:
                tele[key] = float(np.asarray(stats[key]).sum())
        self.history.append(tele)
        if (not skip_replan and self.serve.strategy != "none"
                and self.batches_seen % self.serve.predict_interval == 0):
            wa = self.accuracy.close_window()
            if wa is not None:
                self.tracer.counter("pred_hit_rate", wa.hit_rate,
                                    track="predictor")
                tele["pred_hit_rate"] = wa.hit_rate
                tele["pred_kl"] = wa.kl
            self.replan()
            # score the distribution this re-plan just planned from
            # against the next window's realized routing
            self.accuracy.begin_window(self.estimator.predict(),
                                       self.serve.strategy)
            if self._last_migration:
                tele["migration_entries"] = self._last_migration["entries"]
                tele["migration_bytes"] = self._last_migration["bytes"]

    # ------------------------------------------------------------- telemetry
    def rank_loads(self, slot_counts: np.ndarray) -> np.ndarray:
        """(L, S) slot counts -> (L, R) per-rank token loads."""
        m = self.moe_cfg
        n_slots = m.num_experts // self.ep_ranks + m.duplication_slots
        sc = np.asarray(slot_counts, np.float64)
        return sc.reshape(sc.shape[0], self.ep_ranks, n_slots).sum(-1)


# ===========================================================================
# continuous batching
# ===========================================================================

@dataclass
class ContinuousConfig:
    """Knobs for the continuous-batching engine.

    All shapes derived from these are STATIC: the decode batch is always
    ``max_slots``, prompts pad to ``prefill_len``, and the KV pool holds
    ``num_blocks`` blocks of ``block_size`` positions — so after warmup no
    request pattern can trigger an XLA recompile.
    """
    max_slots: int = 8                # concurrent requests / decode batch
    prefill_len: int = 64             # prompt bucket (multiple of block_size)
    block_size: int = 16              # KV positions per block
    num_blocks: int = 0               # 0 = fully provision every slot
    max_len: int = 128                # per-request prompt+generation budget
    max_prefills_per_step: int = 2    # admission rate limit per iteration
    strategy: str = "dist_only"       # initial; the controller may switch it
    predict_interval: int = 4         # iterations between re-plans
    dup_slots: int = 1                # replica slots per EP rank
    max_copies: int = 4               # Algorithm 1 C_max
    ema: float = 0.9                  # estimator moving average
    eos_id: int = -1                  # -1: generate exactly max_new_tokens
    metrics_window: int = 16          # iterations per metrics window
    # Replica-weight migration (repro.runtime; active when the engine runs
    # EP on a mesh with dup_slots > 0 and moe.replica_impl == "store")
    migrate_chunk: int = 8            # slot entries per fixed-shape step
    migrate_chunks_per_step: int = 0  # chunk steps per engine iteration
                                      # when overlap is OFF (0 = drain the
                                      # diff at replan time)
    migration_gate: bool = True       # reject re-plans whose EXPOSED stall
                                      # exceeds the predicted imbalance gain
    # Overlapped (async-prefetch) migration: None inherits
    # MoEConfig.overlap_migration. When on, the fixed chunks_per_step
    # budget is replaced by a compute-time-aware schedule (chunks sized to
    # the measured non-migration step time, runtime.cost), fills are
    # layer-staged so each layer adopts the moment its fill lands, and the
    # engine PRE-BEGINS migration toward the predicted next-window plan
    # ``prefetch_lead`` iterations before the re-plan boundary
    # (cancel-on-misprediction via MigrationExecutor.cancel).
    overlap_migration: Optional[bool] = None
    prefetch_lead: int = 2            # iterations before the boundary to
                                      # pre-begin (0 = no predictive start)
    # Balancing lever (combined strategy space, repro.schedule): initial;
    # the controller may switch it when ControllerConfig.levers offers more
    # than the duplicate lever. "reschedule" freezes the plan after its
    # first adoption and rebalances by moving TOKENS across the frozen
    # copies (quota dispatch + rescue round); "both" migrates on the
    # interval AND token-schedules the residual.
    lever: str = "duplicate"          # duplicate | reschedule | both
    resched_impl: str = "greedy"      # greedy | lp (repro.schedule)

    def __post_init__(self):
        if self.prefill_len % self.block_size:
            raise ValueError("prefill_len must be a block_size multiple")
        if self.num_blocks == 0:
            per_slot = -(-self.max_len // self.block_size)
            self.num_blocks = 1 + self.max_slots * per_slot   # +1: null block


@dataclass
class StepEvents:
    """What one engine iteration did (host-side bookkeeping for drivers)."""
    now: float
    prefilled: List[ServeRequest] = dataclasses.field(default_factory=list)
    completed: List[ServeRequest] = dataclasses.field(default_factory=list)
    preempted: List[ServeRequest] = dataclasses.field(default_factory=list)
    decoded_slots: int = 0
    decision: Optional[object] = None          # controller Decision, if any


class ContinuousEngine(_OverlapStoreMixin):
    """Continuous-batching serving engine over a paged KV block pool.

    Each ``step()`` is one mixed iteration: admit + prefill up to
    ``max_prefills_per_step`` waiting requests into free slots, then run
    ONE decode step for every running slot at its own position. Strategy
    (none / dist_only / token_to_expert) and ``predict_interval`` are
    runtime-mutable — an attached ``OnlineGPSController`` switches them as
    the observed traffic skew drifts, with zero recompilation: the
    placement plan and predictions are traced arguments, and both
    prefill signatures (with/without predictions) compile once in
    ``warmup()``.
    """

    def __init__(self, cfg: ModelConfig, params, ccfg: ContinuousConfig,
                 mesh=None, ep_ranks: int = 1, predictor=None,
                 controller=None, tracer=None, metrics=None,
                 model: str = ""):
        if cfg.family in ("ssm", "hybrid") or cfg.is_encdec:
            raise ValueError(f"{cfg.family}: continuous batching supports "
                             "uniform-stack decoder-only architectures")
        if cfg.attention != "gqa":
            raise ValueError("paged KV cache is implemented for GQA")
        if cfg.sliding_window and ccfg.prefill_len > cfg.sliding_window:
            # decode applies the window as a mask over the linear pool, but
            # prefill runs full-causal within the bucket — exact only while
            # the bucket fits inside the window
            raise ValueError(
                f"prefill_len {ccfg.prefill_len} exceeds the model's "
                f"sliding window {cfg.sliding_window}")
        self.ccfg = ccfg
        self.mesh = mesh
        self.ep_ranks = ep_ranks
        if mesh is not None:
            _install_compile_listener()
        self.predictor = predictor
        self.controller = controller
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.model = model
        self.strategy = ccfg.strategy
        self.lever = ccfg.lever
        self.predict_interval = ccfg.predict_interval
        self.iterations = 0
        self._plan_stack: Optional[PlacementPlan] = None
        # token rescheduling (repro.schedule): the quota stack is a traced
        # argument like the plan, so quota re-plans never recompile. Both
        # jit signatures (quota absent / present) compile in warmup when
        # the lever is available, so a runtime lever switch is shape-free.
        self._resched_enabled = cfg.is_moe and (
            ccfg.lever in ("reschedule", "both")
            or (controller is not None
                and any(l != "duplicate"
                        for l in getattr(controller.cfg, "levers", ()))))
        self._resched_stack = None          # (L, E, C_max) int32 device array
        self._resched_sched = None          # TokenScheduler, built lazily
        self._resched_frozen = False        # reschedule lever adopted a plan
        self._resched_residual = None       # last plan's leftover imbalance
        self._resched_absorbed_pred = None  # last plan's predicted absorption
        self._step_overflow = 0.0
        self._step_dropped = 0.0

        if cfg.is_moe:
            dup_slots = ccfg.dup_slots
            if mesh is not None:
                dup_slots = _clamp_store_dup_slots(cfg, params, ep_ranks,
                                                   dup_slots)
            self._overlap = (ccfg.overlap_migration
                             if ccfg.overlap_migration is not None
                             else cfg.moe.overlap_migration)
            # duplication slots are ALWAYS compiled in (even for strategy
            # "none", which runs the identity plan) so switching strategy
            # at runtime never changes a shape
            self.moe_cfg = dataclasses.replace(
                cfg.moe, duplication_slots=dup_slots,
                max_copies=ccfg.max_copies,
                overlap_migration=self._overlap)
            # logical duplication quota <= the compiled dup_slots: a fleet
            # arbiter moves capacity between co-resident models by moving
            # this number, never a shape (see set_dup_slot_quota)
            self.dup_slot_quota = dup_slots
            cfg = dataclasses.replace(cfg, moe=self.moe_cfg)
            self.estimator = DistributionEstimator(
                cfg.num_layers, cfg.moe.num_experts, ema=ccfg.ema)
            self.accuracy = PredictorAccuracyTracker(
                cfg.num_layers, cfg.moe.num_experts)
        else:
            self.moe_cfg = None
            self.estimator = None
            self.accuracy = None
            self._overlap = False
            self.dup_slot_quota = 0
        self.cfg = cfg
        self.params = params

        use_dup = cfg.is_moe and cfg.moe.duplication_slots > 0
        # window_override = max_len disables rotating-window caches: the
        # paged pool is linear in logical positions
        self.rt = Runtime(mesh=mesh, ep=mesh is not None, ep_ranks=ep_ranks,
                          use_duplication=use_dup,
                          window_override=ccfg.max_len)

        self.pool = init_block_pool(cfg, ccfg.num_blocks, ccfg.block_size)
        self.allocator = BlockAllocator(ccfg.num_blocks, ccfg.block_size)
        self.scheduler = ContinuousScheduler(
            ccfg.max_slots, ccfg.prefill_len, ccfg.max_len, self.allocator,
            max_prefills_per_step=ccfg.max_prefills_per_step)
        self.metrics = metrics if metrics is not None else \
            ServeMetrics(window_iters=ccfg.metrics_window)
        self._last_tokens = np.zeros((ccfg.max_slots,), np.int32)

        self._prefill_fn = jax.jit(make_slot_prefill_step(cfg, self.rt))
        self._decode_fn = jax.jit(make_paged_decode_step(cfg, self.rt))
        self._write_fn = jax.jit(write_prefill_blocks)
        self._temp_cache = init_cache(cfg, self.rt, 1, ccfg.prefill_len)
        self._warm = False

        # ----------------------------------------------- replica-weight store
        self._store = None
        self._executor = None
        self._migrate_fn = None
        self._entry_bytes = 0
        self._recent_step_s = 0.0          # EMA over ALL steps
        # overlap window: EMA over migration-free steps, split by iteration
        # kind — prefill-bearing steps offer a much larger window than
        # decode-only ones (repro.runtime.cost.KindWindowEMA)
        from repro.runtime import KindWindowEMA
        self._serve_ema = KindWindowEMA()
        self._step_kind = "decode"
        self._step_migration_bytes = 0.0
        self._step_migration_hidden_bytes = 0.0
        self._idle_ready = None            # cached all-False ready mask
        self._adopt_ticks = 0
        self._prebegun_plan = None         # predictive pre-migration target
        self._pred_counts = None           # t2e predicted expert histogram
        if cfg.is_moe:
            from repro.runtime import cost as _mig_cost
            self._entry_bytes = _mig_cost.entry_bytes(
                params["layers"]["moe"]["experts"])
        if (cfg.is_moe and mesh is not None
                and cfg.moe.duplication_slots > 0
                and cfg.moe.replica_impl == "store"):
            from repro.runtime import (LayerStagedExecutor, MigrationExecutor,
                                       ReplicaStore, make_migrate_step)
            m = self.moe_cfg
            experts = params["layers"]["moe"]["experts"]
            self._store = ReplicaStore.from_params(
                experts, self._current_plan(), num_experts=m.num_experts,
                ep_ranks=ep_ranks, dup_slots=m.duplication_slots, mesh=mesh)
            self._migrate_fn = make_migrate_step(
                mesh, num_experts=m.num_experts, ep_ranks=ep_ranks,
                dup_slots=m.duplication_slots)
            if self._overlap:
                self._executor = LayerStagedExecutor(
                    self._migrate_fn, experts, self._store.entry_bytes,
                    num_layers=cfg.num_layers, chunk=ccfg.migrate_chunk,
                    tracer=self.tracer)
            else:
                self._executor = MigrationExecutor(
                    self._migrate_fn, experts, self._store.entry_bytes,
                    chunk=ccfg.migrate_chunk,
                    chunks_per_tick=ccfg.migrate_chunks_per_step,
                    tracer=self.tracer)

    # ------------------------------------------------------------------ plan
    def _identity_stack(self) -> Optional[PlacementPlan]:
        if not self.cfg.is_moe:
            return None
        m = self.moe_cfg
        return stack_plans([
            identity_plan(m.num_experts, self.ep_ranks, m.duplication_slots,
                          m.max_copies) for _ in range(self.cfg.num_layers)])

    def _current_plan(self) -> Optional[PlacementPlan]:
        if self._plan_stack is None:
            self._plan_stack = self._identity_stack()
        return self._plan_stack

    def replan(self):
        """Algorithm 1 per layer from the estimator's current prediction.

        Lever semantics: "duplicate" and "both" adopt a fresh plan every
        boundary (migrating changed slots); "reschedule" adopts ONE plan
        (the first boundary's, so there are replica copies to schedule
        across) and then freezes it — later boundaries only recompute the
        token-scheduler quotas, so the steady state pays zero migration
        traffic. Quotas are refreshed for any resched lever."""
        if not self.cfg.is_moe or self.strategy == "none":
            out = self._adopt_plan(self._identity_stack())
            self._resched_stack = None
            return out
        m = self.moe_cfg
        if (self.lever == "reschedule" and self._resched_frozen
                and self._plan_stack is not None):
            self._replan_resched()
            return self._plan_stack
        dist = self.estimator.predict()
        q = max(0, min(self.dup_slot_quota, m.duplication_slots))
        if q == m.duplication_slots:
            plans = [duplicate_experts_host(
                dist[l], self.ep_ranks, m.duplication_slots,
                m.max_copies).plan for l in range(self.cfg.num_layers)]
        else:
            # quota-limited: plan with only q replica slots, then rebuild
            # at the FULL compiled geometry so no traced shape changes
            plans = [quota_limited_plan(
                duplicate_experts_host(dist[l], self.ep_ranks, q,
                                       m.max_copies).assignments,
                m.num_experts, self.ep_ranks, m.duplication_slots,
                m.max_copies, quota=q) for l in range(self.cfg.num_layers)]
        out = self._adopt_plan(stack_plans(plans))
        if self.lever == "reschedule":
            self._resched_frozen = True
        self._replan_resched()
        return out

    def set_dup_slot_quota(self, quota: int) -> None:
        """Cap replica slots the planner may USE (per rank) below the
        compiled ``dup_slots``. Takes effect at the next re-plan: shrink
        strands now-unused slots (zero transfer — see
        ``runtime.diff.vacated_slots``), growth migrates weights in
        through the normal plan-diff path."""
        if self.cfg.is_moe:
            self.dup_slot_quota = max(
                0, min(int(quota), self.moe_cfg.duplication_slots))

    def _replan_resched(self):
        """Recompute the (L, E, C_max) quota stack from the estimator's
        distribution against the plan currently IN FORCE (a staged
        migration's target adopts later; the rescue round covers the
        transient). Quotas are host-side microseconds per boundary."""
        if (not self._resched_enabled or self.lever == "duplicate"
                or self.strategy == "none" or not self.cfg.is_moe):
            self._resched_stack = None
            return
        from repro.moe.dispatch import capacity
        from repro.schedule import make_scheduler
        m = self.moe_cfg
        plan = self._current_plan()
        if plan is None:
            self._resched_stack = None
            return
        if self._resched_sched is None:
            self._resched_sched = make_scheduler(self.ccfg.resched_impl)
        dist = np.asarray(self.estimator.predict(), np.float64)   # (L, E)
        # token units: the prefill bucket's routed (token, k) pairs; the
        # scheduler only needs counts and cap on the same scale
        counts = dist * float(self.ccfg.prefill_len * m.top_k)
        t_local = max(self.ccfg.prefill_len // self.ep_ranks, 1)
        n_slots_g = (m.num_experts // self.ep_ranks
                     + m.duplication_slots) * self.ep_ranks
        cap = capacity(t_local, m.top_k, n_slots_g,
                       m.capacity_factor) * self.ep_ranks
        layer_plans = [jax.tree.map(lambda a, l=l: np.asarray(a)[l], plan)
                       for l in range(self.cfg.num_layers)]
        quota, results = self._resched_sched.plan_stack(
            counts, layer_plans, ep_ranks=self.ep_ranks,
            dup_slots=m.duplication_slots, cap=float(cap))
        self._resched_stack = jnp.asarray(quota)
        self._resched_residual = float(np.mean(
            [r.imbalance_sched for r in results])) - 1.0
        self._resched_absorbed_pred = float(np.mean(
            [r.overflow_absorbed_frac for r in results]))
        self.metrics.record_resched(
            planned=True, absorbed_pred=self._resched_absorbed_pred,
            residual=self._resched_residual)
        self.tracer.instant(
            "resched.plan", cat="plan", track="plan",
            args={"iteration": self.iterations,
                  "impl": self.ccfg.resched_impl,
                  "residual": self._resched_residual,
                  "absorbed_pred": self._resched_absorbed_pred})

    # ------------------------------------------------------ replica migration
    def _hw(self):
        from repro.core.simulator import A100_PCIE
        return self.controller.cfg.hardware if self.controller else A100_PCIE

    def _overlap_window_s(self) -> float:
        """The overlap window one engine step offers a staged fill: the
        measured NON-migration step time for the CURRENT iteration kind
        (prefill-bearing vs decode-only steps differ by orders of
        magnitude, so the EMA is split per kind), falling back to the
        whole-step EMA and then to the profiled per-layer dispatch phase
        total."""
        w = self._serve_ema.window(self._step_kind)
        if w > 0:
            return w
        if self._recent_step_s > 0:
            return self._recent_step_s
        per_layer = self.metrics.phase_times.get("total", 0.0)
        return per_layer * self.cfg.num_layers

    def _overlap_budget(self) -> int:
        from repro.runtime import overlap_chunk_budget
        return overlap_chunk_budget(
            self._overlap_window_s(), chunk_entries=self.ccfg.migrate_chunk,
            entry_bytes=max(self._entry_bytes, 1), hw=self._hw())

    def _overlap_active(self) -> bool:
        return self._overlap

    def _hidden_estimate(self, stall_s: float, entries: int) -> float:
        """Predicted hidden share of a migration's stall under the overlap
        schedule: the fill drains over ``ceil(entries / (chunk * budget))``
        steps, each hiding up to one overlap window of wire time."""
        if not self._overlap or entries <= 0:
            return 0.0
        window = self._overlap_window_s()
        per_tick = max(self.ccfg.migrate_chunk * self._overlap_budget(), 1)
        drain_steps = -(-entries // per_tick)
        return min(stall_s, drain_steps * window)

    def _adopt_plan(self, target):
        """serve -> diff -> staged fill -> per-layer swap. Without a store
        the plan swaps immediately (and the diff is still costed, so
        dispatcherless smoke deployments surface the plan-churn bytes a
        real EP cluster would pay); with one, only changed slots are
        filled and each layer keeps serving the OLD plan until its fill
        commits. A pre-begun predictive migration toward this exact plan
        just keeps filling; toward a different plan it is cancelled
        (misprediction) and the fill restarts from the live buffers."""
        if (target is None or self._plan_stack is None
                or not self.cfg.is_moe
                or self.moe_cfg.duplication_slots == 0):
            self._plan_stack = target
            return target
        from repro.runtime import migration_stall_s, plan_diff, plans_equal
        m = self.moe_cfg
        if (self._executor is not None and self._executor.active
                and self._prebegun_plan is not None):
            if plans_equal(target, self._prebegun_plan):
                # prediction confirmed: the transfer started early and is
                # (partially) done — the boundary re-plan costs nothing new
                self._prebegun_plan = None
                self.metrics.record_migration(replanned=True)
                return self._plan_stack
            self._executor.cancel()
            self._prebegun_plan = None
            self.metrics.record_migration(cancelled=True)
        diff = plan_diff(self._plan_stack, target, self.ep_ranks,
                         m.duplication_slots)
        planned = diff.num_entries * self._entry_bytes
        stall = migration_stall_s(planned, self._hw())
        self.metrics.record_migration(replanned=True, planned_bytes=planned,
                                      stall_s=stall)
        self.tracer.instant(
            "plan.switch", cat="plan", track="plan",
            args={"iteration": self.iterations, "strategy": self.strategy,
                  "entries": int(diff.num_entries), "bytes": float(planned),
                  "stall_us": stall * 1e6})
        if self._store is None or diff.num_entries == 0:
            # no store to fill, or the switch moves no weights (replica
            # routing tables can shrink without any slot changing expert);
            # an in-flight migration toward an older target is superseded
            if self._executor is not None:
                self._executor.cancel()
            if self._store is None and planned > 0:
                # model the overlap economics for store-less smoke engines
                # too, so the controller sees the same hidden/exposed split
                # a real EP deployment's prefetcher would produce
                hidden = self._hidden_estimate(stall, diff.num_entries)
                self.metrics.record_migration(hidden_s=hidden,
                                              exposed_s=stall - hidden)
                self._step_migration_bytes += planned
                if stall > 0:
                    self._step_migration_hidden_bytes += \
                        planned * (hidden / stall)
            self._plan_stack = target
            return target
        if not self._migration_accept(stall, target, diff.num_entries):
            # a previously ACCEPTED in-flight fill (if any) keeps draining
            # toward its own target — it already passed the gate. A switch
            # to "none"/identity never lands here: its diff is empty, so
            # the branch above cancels any in-flight migration first.
            self.metrics.record_migration(rejected=True)
            self.tracer.instant(
                "plan.reject", cat="plan", track="plan",
                args={"iteration": self.iterations,
                      "stall_us": stall * 1e6, "bytes": float(planned)})
            return self._plan_stack
        self._executor.begin(self._store.weights, diff, target)
        self._adopt_ticks = 0
        if not self._overlap and self.ccfg.migrate_chunks_per_step == 0:
            self._tick_migration()              # drain + commit right away
        return self._plan_stack

    def _migration_accept(self, stall_s: float, target,
                          entries: int = 0) -> bool:
        """Hysteresis: a re-plan must repay its EXPOSED weight movement
        (total stall minus the share the overlap schedule hides under
        forward compute) with predicted imbalance gain before the next
        re-plan. With overlap on, re-plans whose transfer rides entirely
        under compute are accepted even when the same transfer would have
        been rejected as a synchronous stall."""
        if not self.ccfg.migration_gate or self._recent_step_s <= 0:
            return True
        from repro.runtime import should_migrate
        m = self.moe_cfg
        counts = self.estimator.predict()
        old = imbalance(plan_rank_loads(counts, self._plan_stack,
                                        self.ep_ranks, m.duplication_slots))
        new = imbalance(plan_rank_loads(counts, target, self.ep_ranks,
                                        m.duplication_slots))
        gain_frac = max(old - new, 0.0) / max(old, 1e-9)
        gain_s = gain_frac * max(self.predict_interval, 1) * self._recent_step_s
        return should_migrate(stall_s, gain_s,
                              hidden_s=self._hidden_estimate(stall_s, entries))

    def _tick_migration(self):
        """Issue this step's migration budget (compute-time-aware when
        overlapped, the fixed chunks_per_step knob otherwise); swap plan +
        store on commit. Chunk programs are enqueued WITHOUT blocking, so
        on an async backend they execute under the forward compute of the
        iteration that follows."""
        if self._executor is None or not self._executor.active:
            return
        budget = self._overlap_budget() if self._overlap else None
        with self.mesh:          # same lowering context as warmup's compile
            commit, moved = self._executor.tick(budget)
        self._adopt_ticks += 1
        if moved:
            self._step_migration_bytes += moved
            hidden, exposed = _chunk_stall_split(
                moved, self._overlap_window_s(), self._hw(),
                overlap=self._overlap)
            stall = hidden + exposed
            if stall > 0:
                self._step_migration_hidden_bytes += moved * (hidden / stall)
            self.metrics.record_migration(bytes_moved=moved, hidden_s=hidden,
                                          exposed_s=exposed)
        if commit is not None:
            weights, plan, se = commit
            self._store.adopt(weights, se)
            self._plan_stack = plan
            self._prebegun_plan = None
            self.metrics.record_migration(committed=True)

    # --------------------------------------------------------------- predict
    def _shape_predictions(self, tokens: np.ndarray):
        """(1, S) prompt -> (L, 1, S, K) predicted expert slots (the top-1
        prediction broadcast over k). One definition site: warmup and
        serving MUST build the identical jit signature."""
        pred = self.predictor.predict(np.asarray(tokens))          # (L, 1, S)
        self._last_token_pred = pred
        K = self.moe_cfg.top_k
        return jnp.asarray(pred)[..., None].repeat(K, -1)

    def _predict_tokens(self, tokens: np.ndarray):
        if self.strategy != "token_to_expert" or self.predictor is None:
            return None
        out = self._shape_predictions(tokens)
        self._note_predicted(self._last_token_pred)
        return out

    def _note_predicted(self, pred: np.ndarray):
        """Publish the Token-to-Expert predictor's output as a predicted
        next-window expert histogram — available BEFORE dispatch, so the
        prefetch controller can pre-begin migration toward the plan the
        next re-plan will most likely produce."""
        E = self.moe_cfg.num_experts
        L = self.cfg.num_layers
        ids = np.clip(np.asarray(pred).reshape(L, -1), 0, E - 1)
        hist = np.stack([np.bincount(ids[l], minlength=E)
                         for l in range(L)]).astype(np.float64)
        if self._pred_counts is None:
            self._pred_counts = hist
        else:
            e = self.ccfg.ema
            self._pred_counts = e * self._pred_counts + (1 - e) * hist

    def _predicted_dist(self) -> Optional[np.ndarray]:
        """(L, E) next-window hot-expert distribution, published EARLY:
        the Token-to-Expert predictor's aggregated output when that
        strategy runs, else the Distribution-Only estimator (whose EMA
        state is exactly what the boundary re-plan will consume)."""
        if not self.cfg.is_moe:
            return None
        if self.strategy == "token_to_expert" and self._pred_counts is not None:
            tot = np.maximum(self._pred_counts.sum(axis=1, keepdims=True),
                             1e-9)
            return self._pred_counts / tot
        return self.estimator.predict()

    def _prebegin_migration(self):
        """Start filling replica slots toward the PREDICTED next-window
        plan while the current window is still serving — by the re-plan
        boundary the transfer has ridden under ``prefetch_lead`` steps of
        forward compute. A boundary plan that differs cancels the stale
        fill (the live buffers were never touched)."""
        if self._store is None or self._executor is None:
            return
        from repro.runtime import migration_stall_s, plan_diff
        m = self.moe_cfg
        dist = self._predicted_dist()
        if dist is None:
            return
        target = stack_plans([
            duplicate_experts_host(dist[l], self.ep_ranks,
                                   m.duplication_slots, m.max_copies).plan
            for l in range(self.cfg.num_layers)])
        diff = plan_diff(self._plan_stack, target, self.ep_ranks,
                         m.duplication_slots)
        if diff.num_entries == 0:
            return
        planned = diff.num_entries * self._entry_bytes
        stall = migration_stall_s(planned, self._hw())
        if not self._migration_accept(stall, target, diff.num_entries):
            return
        self._executor.begin(self._store.weights, diff, target)
        self._prebegun_plan = target
        self._adopt_ticks = 0
        # the diff cost is accounted HERE (the boundary re-plan that
        # confirms the prediction records only the replan event, so
        # planned-vs-moved stays comparable for prebegun migrations)
        self.metrics.record_migration(prebegun=True, planned_bytes=planned,
                                      stall_s=stall)
        self.tracer.instant(
            "migration.prebegin", cat="migration", track="migration",
            args={"iteration": self.iterations,
                  "entries": int(diff.num_entries), "bytes": float(planned)})

    # ---------------------------------------------------------------- warmup
    def warmup(self):
        """Compile every step signature once (both prefill variants when a
        predictor is attached). Must run before any request is admitted —
        it writes garbage into unallocated blocks."""
        assert not self.scheduler.active_slots, "warmup() before serving"
        ccfg = self.ccfg
        toks = np.zeros((1, ccfg.prefill_len), np.int32)
        tw = np.zeros((1, ccfg.prefill_len), np.float32)
        last = jnp.zeros((1,), jnp.int32)
        plan = self._current_plan()
        table = jnp.zeros((ccfg.prefill_len // ccfg.block_size,), jnp.int32)
        preds = [None]
        if self.predictor is not None:
            preds.append(self._shape_predictions(toks))
        rescheds = [None]
        if self._resched_enabled:
            # the quota variant is its own jit signature: compile it now so
            # a runtime lever switch (controller or config) never recompiles
            from repro.schedule import even_quota_stack
            rescheds.append(jnp.asarray(even_quota_stack(
                self.cfg.num_layers, jax.tree.map(lambda a: np.asarray(a)[0],
                                                  plan))))
        slot_w = self._store.weights if self._store is not None else None
        ctx = self.mesh or _nullcontext()
        with ctx:
            back_w, ready, tplan = self._overlap_args()
            if self._migrate_fn is not None:
                # compile the migration step once (a no-op chunk: every
                # entry invalid) so later plan switches never compile
                z = jnp.zeros((self.ccfg.migrate_chunk,), jnp.int32)
                jax.block_until_ready(self._migrate_fn(
                    self._store.weights,
                    self.params["layers"]["moe"]["experts"],
                    z, z, z, jnp.zeros((self.ccfg.migrate_chunk,), bool)))
            for pred in preds:
                for resched in rescheds:
                    _, _, temp, _ = jax.block_until_ready(self._prefill_fn(
                        self.params, {"tokens": jnp.asarray(toks)},
                        self._temp_cache, plan, pred, last, jnp.asarray(tw),
                        slot_w, back_w, ready, tplan, resched))
            dec_toks = jnp.zeros((ccfg.max_slots, 1), jnp.int32)
            tables = jnp.zeros(
                (ccfg.max_slots, self.scheduler.tables.max_blocks_per_slot),
                jnp.int32)
            lens = jnp.zeros((ccfg.max_slots,), jnp.int32)
            aw = jnp.zeros((ccfg.max_slots, 1), jnp.float32)
            # run the steady-state write -> decode cycle TWICE: under a
            # mesh the pool's sharding layout settles only after the first
            # decode, and each distinct input layout is its own jit entry
            for resched in rescheds:
                for _ in range(2):
                    self.pool = jax.block_until_ready(
                        self._write_fn(self.pool, temp, table))
                    out = self._decode_fn(self.params, dec_toks, self.pool,
                                          tables, lens, plan, aw, slot_w,
                                          back_w, ready, tplan, resched)
                    self.pool = jax.block_until_ready(out[2])
            if self.mesh is not None:
                self._warm_converts()
        if self.mesh is not None:
            # the serving loop builds some device arrays OUTSIDE the mesh
            # context (jit cache keys include it) and re-plans on the host;
            # warm both so the backend-compile counter stays flat
            self._warm_converts()
            if self.cfg.is_moe and self.strategy != "none":
                self.replan()       # estimator is empty -> identity plan,
                                    # but the plan-build programs compile
                while self._executor is not None and self._executor.active:
                    self._tick_migration()      # never leak a warmup fill
                # warmup's replan must not count as serving plan churn,
                # and its garbage-token predictions must not seed the
                # prefetcher's published histogram
                self.metrics.migration = dict.fromkeys(
                    self.metrics.migration, 0.0)
                self.metrics.resched = dict.fromkeys(
                    self.metrics.resched, 0.0)
                self._pred_counts = None
        self._warm = True
        self._compile_baseline = self.compile_counts()

    def _warm_converts(self):
        """Compile the np->device conversion programs ``step()`` issues
        (their avals differ from the zeros used to warm the step fns)."""
        ccfg = self.ccfg
        t = self.scheduler.tables
        jax.block_until_ready((
            jnp.asarray([0], jnp.int32),
            jnp.asarray(t.tables[0, :ccfg.prefill_len // ccfg.block_size],
                        jnp.int32),
            jnp.asarray(self._last_tokens[:, None]),
            jnp.asarray(t.tables),
            jnp.asarray(t.lengths),
            jnp.asarray(np.zeros((ccfg.max_slots, 1), np.float32)),
            jnp.asarray(np.zeros((1, ccfg.prefill_len), np.float32)),
            jnp.asarray(np.zeros((1, ccfg.prefill_len), np.int32)),
            # the overlapped-migration ready mask (np bool (L,) -> device)
            jnp.asarray(np.zeros((self.cfg.num_layers,), bool)),
            jnp.zeros((self.cfg.num_layers,), bool),
        ) + ((
            # the np int32 quota-stack -> device conversion (re-plans build
            # quotas on the host every boundary)
            jnp.asarray(np.zeros((self.cfg.num_layers,
                                  self.moe_cfg.num_experts,
                                  self.moe_cfg.max_copies), np.int32)),
        ) if self._resched_enabled else ()))

    def compile_counts(self) -> Dict[str, int]:
        """Compilation state for the no-recompile check: per-step-function
        jit cache sizes on a single device, the process-wide backend
        compile count under a mesh (where per-fn cache sizes overcount —
        see ``_install_compile_listener``)."""
        if self.mesh is not None:
            return {"xla_compiles": _xla_compiles[0]}
        out = {}
        names = ("_prefill_fn", "_decode_fn", "_write_fn") + (
            ("_migrate_fn",) if self._migrate_fn is not None else ())
        for name in names:
            fn = getattr(self, name)
            try:
                out[name] = fn._cache_size()
            except AttributeError:                      # older jit wrappers
                out[name] = -1
        return out

    def profile_phases(self, iters: int = 3, impl: Optional[str] = None,
                       tokens: Optional[int] = None) -> Dict[str, float]:
        """Measure the per-step phase breakdown: the paged decode
        ``attn`` kernel at this deployment's pool/table shapes (any GQA
        model, MoE or not), plus — for MoE configs — the dispatch phases
        (route/pack/a2a/ffn/combine) and the ``migrate`` chunk-fill cost
        when duplication is on. ``tokens`` picks the dispatch shape
        (default: this deployment's prefill bucket; pass ``max_slots``
        for a decode-shaped profile). The breakdown is recorded into
        ``metrics`` only when it profiles the ACTIVE ``dispatch_impl``
        and the phase columns are empty — what-if runs with an ``impl``
        override just return their numbers, and a second shape must
        ``metrics.reset_phases()`` first, so repeated calls can't
        silently double-accumulate the reported columns. Every profile
        also lands as a sequence of retrospective spans on the tracer's
        "dispatch-profile" track. Returns seconds per phase; ``migrate``
        is NOT part of ``total`` (it is paid per plan switch, not per
        step)."""
        from repro.moe.profile import (ATTN_PHASE, attn_phase_times,
                                       dispatch_phase_times,
                                       migrate_phase_time)
        m = self.moe_cfg
        tokens = tokens or self.ccfg.prefill_len
        phases: Dict[str, float] = {}
        if self.cfg.attention in ("gqa", "mixed") \
                and self.cfg.num_kv_heads > 0:
            phases.update(attn_phase_times(
                batch=self.ccfg.max_slots,
                num_kv=self.cfg.num_kv_heads,
                gqa=max(self.cfg.num_heads // self.cfg.num_kv_heads, 1),
                head_dim=self.cfg.head_dim,
                block_size=self.ccfg.block_size,
                max_blocks=max(self.ccfg.max_len // self.ccfg.block_size, 1),
                window=self.cfg.sliding_window,
                impl=getattr(self.cfg, "paged_attn_impl", "fused"),
                iters=iters))
        if m is not None:
            phases.update(dispatch_phase_times(
                d_model=self.cfg.d_model, d_ff=m.d_ff_expert,
                num_experts=m.num_experts, top_k=m.top_k,
                tokens=tokens, ranks=self.ep_ranks,
                capacity_factor=m.capacity_factor,
                impl=impl or m.dispatch_impl,
                activation=self.cfg.activation, iters=iters))
            if m.duplication_slots > 0:
                phases.update(migrate_phase_time(
                    d_model=self.cfg.d_model, d_ff=m.d_ff_expert,
                    num_experts=m.num_experts, ranks=self.ep_ranks,
                    dup_slots=m.duplication_slots,
                    layers=self.cfg.num_layers,
                    chunk=self.ccfg.migrate_chunk, iters=iters))
        if not phases:
            return {}
        ts = None
        for k in (ATTN_PHASE, "route", "pack", "a2a", "ffn", "combine",
                  "migrate"):
            if k in phases:
                ts = self.tracer.add_span(
                    k, phases[k], ts_ns=ts, cat="dispatch",
                    track="dispatch-profile",
                    args={"impl": impl or (m.dispatch_impl if m else
                                           getattr(self.cfg,
                                                   "paged_attn_impl",
                                                   "fused")),
                          "tokens": tokens})
        if (impl is None or (m is not None and impl == m.dispatch_impl)) \
                and not self.metrics.phase_times:
            self.metrics.record_phases(phases)
        return phases

    def assert_no_recompiles(self):
        assert self._warm, "call warmup() first"
        now = self.compile_counts()
        assert all(v >= 0 for v in now.values()), (
            "jit cache introspection unavailable on this jax version — "
            f"the no-recompile guarantee cannot be checked: {now}")
        assert now == self._compile_baseline, (
            f"recompilation after warmup: {self._compile_baseline} -> {now}")

    # ------------------------------------------------------------------ step
    def submit(self, req: ServeRequest):
        self.scheduler.submit(req)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def step(self, now: float, clock=None) -> StepEvents:
        """One mixed prefill+decode iteration starting at (virtual) time
        ``now``. ``clock``: optional zero-arg callable returning the
        CURRENT virtual time, so first-token / completion timestamps
        include the cost of the iteration that produced them (run_trace
        wires this to the scaled wall clock); default: frozen at ``now``.
        """
        import time as _time
        t_wall0 = _time.perf_counter()
        clock = clock or (lambda: now)
        ccfg = self.ccfg
        sched = self.scheduler
        events = StepEvents(now=now)
        iter_counts = None
        prefill_tokens = 0
        ctx = self.mesh or _nullcontext()
        step_args = {"iteration": self.iterations}
        if self.model:
            step_args["model"] = self.model
        step_span = self.tracer.span("step", args=step_args)
        step_span.__enter__()
        self._step_migration_bytes = 0.0
        self._step_migration_hidden_bytes = 0.0
        self._step_overflow = 0.0
        self._step_dropped = 0.0
        self._tick_migration()       # commit BEFORE this iteration's plan read
        plan = self._current_plan()
        resched = (self._resched_stack
                   if self.lever in ("reschedule", "both") else None)
        slot_w = self._store.weights if self._store is not None else None
        back_w, ready, tplan = self._overlap_args()

        with self.tracer.span("admission") as adm:
            splan: IterationPlan = sched.schedule(now)
            adm.set_args(prefills=len(splan.prefills),
                         decode_slots=len(splan.decode_slots),
                         preempted=len(splan.preempted))
        self._step_kind = "prefill" if splan.prefills else "decode"

        # ---------------------------------------------------------- prefill
        for req in splan.prefills:
            pf_span = self.tracer.span(
                "prefill", args={"rid": req.rid,
                                 "prompt_len": req.prompt_len})
            pf_span.__enter__()
            slot = req.slot
            S = ccfg.prefill_len
            toks = np.zeros((1, S), np.int32)
            toks[0, :req.prompt_len] = req.tokens[:S]
            tw = np.zeros((1, S), np.float32)
            tw[0, :req.prompt_len] = 1.0
            pred = self._predict_tokens(toks)
            last = jnp.asarray([req.prompt_len - 1], jnp.int32)
            table = jnp.asarray(
                sched.tables.tables[slot, :S // ccfg.block_size], jnp.int32)
            with ctx:
                next_tok, _, temp, stats = self._prefill_fn(
                    self.params, {"tokens": jnp.asarray(toks)},
                    self._temp_cache, plan, pred, last, jnp.asarray(tw),
                    slot_w, back_w, ready, tplan, resched)
                self.pool = self._write_fn(self.pool, temp, table)
            tok0 = int(np.asarray(next_tok)[0, 0])
            req.generated.append(tok0)
            req.t_first_token = clock()
            self._last_tokens[slot] = tok0
            prefill_tokens += req.prompt_len
            iter_counts = self._accumulate(iter_counts, stats)
            events.prefilled.append(req)
            pf_span.__exit__()

        # ----------------------------------------------------------- finish
        # (requests whose whole budget was one token, or whose first token
        # already hit EOS, never reach decode)
        for slot in list(sched.active_slots):
            self._maybe_finish(slot, clock(), events)

        # ----------------------------------------------------------- decode
        sched.ensure_decode_capacity(splan)
        events.preempted = splan.preempted
        decode_slots = [s for s in splan.decode_slots
                        if sched.slots[s] is not None]
        attn_live = attn_alloc = 0.0
        if decode_slots:
            # attention-compute roofline for this decode iteration, from
            # the PRE-increment lengths the kernel actually sees: the
            # gather oracle materializes and attends over every allocated
            # table column (max_slots x tbl_m blocks) while the fused
            # kernel's @pl.when(live) guard only computes blocks holding
            # in-context (and, under a sliding window, in-window) tokens.
            # alloc/live is the fused kernel's structural speedup bound.
            bs = ccfg.block_size
            tbl_m = sched.tables.tables.shape[1]
            cl = sched.tables.lengths.astype(np.int64) + 1
            starts = np.arange(tbl_m, dtype=np.int64)[None, :] * bs
            live = starts < cl[:, None]
            if self.cfg.sliding_window > 0:
                live &= starts + bs > cl[:, None] - self.cfg.sliding_window
            attn_live = float(live.sum())
            attn_alloc = float(ccfg.max_slots * tbl_m)
            active = np.zeros((ccfg.max_slots, 1), np.float32)
            active[decode_slots] = 1.0
            with self.tracer.span("decode",
                                  args={"slots": len(decode_slots)}):
                with ctx:
                    next_tok, _, self.pool, stats = self._decode_fn(
                        self.params, jnp.asarray(self._last_tokens[:, None]),
                        self.pool, jnp.asarray(sched.tables.tables),
                        jnp.asarray(sched.tables.lengths), plan,
                        jnp.asarray(active), slot_w, back_w, ready, tplan,
                        resched)
            nt = np.asarray(next_tok)
            for slot in decode_slots:
                req = sched.slots[slot]
                tok = int(nt[slot, 0])
                req.generated.append(tok)
                sched.tables.lengths[slot] += 1
                self._last_tokens[slot] = tok
            iter_counts = self._accumulate(iter_counts, stats)
            events.decoded_slots = len(decode_slots)
            for slot in decode_slots:
                self._maybe_finish(slot, clock(), events)

        # ---------------------------------------------------------- observe
        obs_span = self.tracer.span("observe")
        obs_span.__enter__()
        self.iterations += 1
        if self.cfg.is_moe and iter_counts is not None:
            self.estimator.update(iter_counts)
            self.accuracy.observe(iter_counts)
            boundary = self.iterations % self.predict_interval == 0
            if boundary:
                # score the prediction the LAST re-plan boundary committed
                # to against the window's realized routing
                wa = self.accuracy.close_window()
                if wa is not None:
                    self.metrics.record_accuracy(wa.hit_rate, wa.kl)
                    self.tracer.counter("pred_hit_rate", wa.hit_rate,
                                        track="predictor")
                    self.tracer.counter("pred_kl", wa.kl, track="predictor")
            if self.strategy != "none" and boundary:
                self.replan()
            elif (self._overlap and self.strategy != "none"
                  and self.ccfg.prefetch_lead > 0
                  and self._executor is not None
                  and not self._executor.active
                  and self.predict_interval > self.ccfg.prefetch_lead
                  and (self.iterations + self.ccfg.prefetch_lead)
                  % self.predict_interval == 0):
                # the predictors publish next-window hot experts EARLY:
                # start moving weights toward the predicted plan now, so
                # the boundary re-plan finds the transfer already hidden
                # under this window's forward compute
                self._prebegin_migration()
            if boundary:
                self.accuracy.begin_window(
                    self._predicted_dist() if self.strategy != "none"
                    else None, self.strategy)
        if self.cfg.is_moe and (self._step_overflow or self._step_dropped):
            # rescue-round a2a surcharge: each overflow (token, k) pair is
            # re-dispatched once — activation there and back in bf16
            self.metrics.record_resched(
                overflow_tokens=self._step_overflow,
                dropped_tokens=self._step_dropped,
                extra_a2a_bytes=self._step_overflow * self.cfg.d_model * 2 * 2)
        decision = None
        if self.controller is not None and self.cfg.is_moe:
            decision = self.controller.observe(
                iter_counts, now,
                migration_bytes=self._step_migration_bytes,
                migration_hidden_bytes=self._step_migration_hidden_bytes,
                overflow_tokens=self._step_overflow,
                dropped_tokens=self._step_dropped,
                resched_residual=self._resched_residual,
                resched_absorbed_pred=self._resched_absorbed_pred)
            if decision is not None:
                self.tracer.instant(
                    "gps.decision", cat="gps", track="gps",
                    args={"recommended": decision.recommended,
                          "strategy": decision.strategy,
                          "skew": decision.skew,
                          "volatility": decision.volatility,
                          "switched": decision.switched,
                          "predict_interval": decision.predict_interval})
                self.tracer.counter("skew", decision.skew, track="gps")
                if decision.switched:
                    self.tracer.instant(
                        "gps.switch", cat="gps", track="gps",
                        args={"to": decision.strategy})
                self._apply_decision(decision)
        events.decision = decision
        obs_span.__exit__()

        dt = clock() - now
        self._recent_step_s = (dt if self._recent_step_s <= 0
                               else 0.9 * self._recent_step_s + 0.1 * dt)
        wall = _time.perf_counter() - t_wall0
        if self._step_migration_bytes == 0:
            # migration-free steps calibrate the overlap window (the
            # compute time a staged fill can hide under). Measured on the
            # WALL clock, not the driver's virtual clock — the window is a
            # physical property of the forward pass, and frozen-clock
            # drivers (tests, fixed-rate replay) would otherwise report 0.
            # Keyed by iteration kind: a decode-only step must not inherit
            # a prefill-sized window (and vice versa) — with the fused
            # decode kernel the decode step wall is materially smaller, so
            # the KindWindowEMA decode windows shrink to match.
            self._serve_ema.update(self._step_kind, wall)
        self.metrics.record_iteration(
            now, dt, prefill_tokens=prefill_tokens,
            decode_tokens=len(decode_slots),
            counts=iter_counts, plan=self._plan_stack,
            ep_ranks=self.ep_ranks,
            dup_slots=self.moe_cfg.duplication_slots if self.moe_cfg else 0,
            strategy=self.strategy, wall_s=wall,
            attn_live_blocks=attn_live, attn_alloc_blocks=attn_alloc)
        step_span.set_args(prefills=len(splan.prefills),
                           decoded=len(decode_slots))
        step_span.__exit__()
        return events

    # ----------------------------------------------------------- internals
    def _accumulate(self, acc, stats):
        if not self.cfg.is_moe or stats.get("expert_counts") is None:
            return acc
        self._step_dropped += float(np.asarray(stats.get("dropped", 0)).sum())
        self._step_overflow += float(np.asarray(stats.get("overflow", 0)).sum())
        c = np.asarray(stats["expert_counts"], np.float64)
        return c if acc is None else acc + c

    def _maybe_finish(self, slot: int, now: float, events: StepEvents):
        req = self.scheduler.slots[slot]
        if req is None:
            return
        hit_eos = (self.ccfg.eos_id >= 0 and req.generated
                   and req.generated[-1] == self.ccfg.eos_id)
        if req.done or hit_eos:
            self.scheduler.finish_slot(slot, now)
            self.metrics.record_completion(RequestTiming(
                rid=req.rid, arrival=req.arrival,
                t_first_token=req.t_first_token, t_finished=now,
                prompt_len=req.prompt_len, new_tokens=len(req.generated),
                n_preemptions=req.n_preemptions, tenant=req.tenant))
            events.completed.append(req)

    def _apply_decision(self, decision):
        lever = getattr(decision, "lever", "duplicate")
        lever_changed = (self._resched_enabled and lever != self.lever
                         and decision.strategy != "none"
                         and lever in ("duplicate", "reschedule", "both"))
        if decision.strategy != self.strategy or lever_changed:
            self.strategy = decision.strategy
            if lever_changed:
                self.lever = lever
                # a fresh reschedule tenure freezes the NEXT adopted plan,
                # not whatever an older tenure froze
                self._resched_frozen = False
            # replan() handles "none" too (identity stack through
            # _adopt_plan, which also cancels any in-flight migration —
            # a direct _plan_stack write here would let a stale commit
            # reinstate the abandoned duplicated plan)
            self.replan()
        self.predict_interval = decision.predict_interval

    # ------------------------------------------------------------ trace run
    def run_trace(self, requests: List[ServeRequest], *, max_iters: int = 0,
                  time_scale: float = 1.0) -> float:
        """Replay a trace on a virtual clock: each iteration costs its
        measured wall time x ``time_scale``; idle gaps fast-forward to the
        next arrival. ``time_scale > 1`` compresses a long trace horizon
        into less wall time (every virtual second costs 1/scale wall
        seconds of compute). Returns the virtual completion time."""
        import time as _time
        for r in sorted(requests, key=lambda r: r.arrival):
            self.submit(r)
        now = 0.0
        iters = 0
        while self.has_work():
            if (not self.scheduler.active_slots and self.scheduler.waiting
                    and self.scheduler.waiting[0].arrival > now):
                now = self.scheduler.waiting[0].arrival
            t0 = _time.perf_counter()
            start = now
            self.step(start, clock=lambda: start + (
                _time.perf_counter() - t0) * time_scale)
            now = start + (_time.perf_counter() - t0) * time_scale
            iters += 1
            if max_iters and iters >= max_iters:
                break
        self.metrics.flush(
            self._plan_stack, self.ep_ranks,
            self.moe_cfg.duplication_slots if self.moe_cfg else 0)
        return now
