"""Serving engine with the paper's predict -> plan -> dispatch pipeline.

Per prediction interval (default: every batch, paper Sec 3.1):

  1. observe per-layer expert histograms from the last batches' router
     stats (the Distribution-Only predictor's input — a free side-effect
     of dispatch) and/or run the Token-to-Expert predictor on the incoming
     batch;
  2. plan: Algorithm 1 (`duplicate_experts_host`) turns the predicted
     distribution into a PlacementPlan per MoE layer;
  3. dispatch: the next prefill executes with the new plan — replicated
     experts receive their tokens round-robin, balancing per-rank load.

The engine is strategy-agnostic: ``strategy`` selects none / dist_only /
token_to_expert exactly as in the paper, and `repro.core.gps` can be asked
which one to use for the deployment's (model, hardware, skew) point.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.duplication import duplicate_experts_host
from repro.core.placement import PlacementPlan, identity_plan, stack_plans
from repro.core.predictors import DistributionEstimator
from repro.models.transformer import Runtime, forward, init_cache
from repro.train.steps import (make_decode_step, make_prefill_replan_step,
                               make_prefill_step)


class _nullcontext:
    def __enter__(self):
        return self
    def __exit__(self, *a):
        return False


@dataclass
class ServeConfig:
    strategy: str = "dist_only"       # none | dist_only | token_to_expert
    predict_interval: int = 1         # batches between re-plans (paper Sec 3.1)
    dup_slots: int = 1                # replica slots per EP rank
    max_copies: int = 4               # Algorithm 1 C_max
    ema: float = 0.9                  # moving-average for the MLE estimator
    max_len: int = 2048               # KV-cache length for generation
    in_graph_replan: bool = False     # fuse Algorithm 1 into the prefill
                                      # step (no host round-trip per batch)


class ServeEngine:
    """Batched prefill+decode with dynamic expert duplication."""

    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig,
                 mesh=None, ep_ranks: int = 1, predictor=None):
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.mesh = mesh
        self.ep_ranks = ep_ranks
        self.predictor = predictor            # Token-to-Expert model (optional)
        self.batches_seen = 0
        self._plan_stack: Optional[PlacementPlan] = None
        self.history: List[Dict] = []         # per-batch balance telemetry

        use_dup = cfg.is_moe and serve.strategy != "none"
        dup_slots = serve.dup_slots if use_dup else 0
        if cfg.is_moe:
            self.moe_cfg = dataclasses.replace(
                cfg.moe, duplication_slots=dup_slots,
                max_copies=serve.max_copies)
            self.cfg = dataclasses.replace(cfg, moe=self.moe_cfg)
            self.estimator = DistributionEstimator(
                cfg.num_layers, cfg.moe.num_experts, ema=serve.ema)
        else:
            self.moe_cfg = None
            self.estimator = None

        self._rt_kw = dict(mesh=mesh, ep=mesh is not None,
                           ep_ranks=ep_ranks, use_duplication=use_dup)
        self._prefill = None
        self._decode = None

    # ------------------------------------------------------------------ plan
    def _identity_stack(self) -> Optional[PlacementPlan]:
        if not self.cfg.is_moe:
            return None
        m = self.moe_cfg
        plans = [identity_plan(m.num_experts, self.ep_ranks,
                               m.duplication_slots, m.max_copies)
                 for _ in range(self.cfg.num_layers)]
        return stack_plans(plans)

    def replan(self) -> Optional[PlacementPlan]:
        """Algorithm 1 per layer from the current distribution estimate."""
        if not self.cfg.is_moe or self.serve.strategy == "none":
            return self._identity_stack()
        m = self.moe_cfg
        dist = self.estimator.predict()                  # (L, E)
        plans = []
        for l in range(self.cfg.num_layers):
            res = duplicate_experts_host(dist[l], self.ep_ranks,
                                         m.duplication_slots, m.max_copies)
            plans.append(res.plan)
        self._plan_stack = stack_plans(plans)
        return self._plan_stack

    def _current_plan(self) -> Optional[PlacementPlan]:
        if self._plan_stack is None:
            self._plan_stack = self._identity_stack()
        return self._plan_stack

    def _runtime(self) -> Runtime:
        return Runtime(**self._rt_kw)

    def _steps(self):
        """Build + jit the step functions ONCE; plan/predictions are traced
        arguments so replanning never recompiles."""
        if self._prefill is None:
            rt = self._runtime()
            in_graph = (self.serve.in_graph_replan and self.cfg.is_moe
                        and self.serve.strategy == "dist_only")
            builder = (make_prefill_replan_step if in_graph
                       else make_prefill_step)
            self._prefill = jax.jit(builder(self.cfg, rt))
            self._in_graph = in_graph
            self._decode = jax.jit(make_decode_step(self.cfg, rt),
                                   static_argnums=(3,))
        return self._prefill, self._decode

    # --------------------------------------------------------------- predict
    def _predict_tokens(self, tokens: np.ndarray) -> Optional[jnp.ndarray]:
        """Token-to-Expert pre-routing: (L, B, S) -> (L, B*S, K) slots."""
        if self.serve.strategy != "token_to_expert" or self.predictor is None:
            return None
        pred = self.predictor.predict(np.asarray(tokens))          # (L, B, S)
        K = self.moe_cfg.top_k
        # top-1 prediction broadcast over k (paper predicts the top-1 expert)
        return jnp.asarray(pred)[..., None].repeat(K, -1)          # (L,B,S,K)

    # ----------------------------------------------------------------- steps
    def prefill(self, batch: Dict, cache=None):
        tokens = batch["tokens"]
        B, S = tokens.shape
        pred = self._predict_tokens(tokens)
        prefill_step, _ = self._steps()
        if cache is None:
            cache = init_cache(self.cfg, self._runtime(), B, self.serve.max_len)
        plan = self._current_plan()
        ctx = self.mesh or _nullcontext()
        with ctx:
            if getattr(self, "_in_graph", False):
                logits, cache, stats, next_plan = prefill_step(
                    self.params, batch, cache, plan, pred)
                self._plan_stack = next_plan
            else:
                logits, cache, stats = prefill_step(self.params, batch,
                                                    cache, plan, pred)
        self._observe(stats, num_tokens=B * S,
                      skip_replan=getattr(self, "_in_graph", False))
        return logits, cache, stats

    def decode(self, tokens, cache, cache_len: int):
        _, decode_step = self._steps()
        plan = self._current_plan()
        ctx = self.mesh or _nullcontext()
        with ctx:
            next_tok, logits, cache, stats = decode_step(
                self.params, tokens, cache, cache_len, plan)
        return next_tok, logits, cache, stats

    def generate(self, batch: Dict, max_new_tokens: int = 8):
        """Prefill + greedy decode; returns (generated (B, T), telemetry)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        logits, cache, _ = self.prefill(batch, cache=None)
        next_tok = logits[:, -1].argmax(-1).astype(jnp.int32)[:, None]
        out = [next_tok]
        for t in range(max_new_tokens - 1):
            next_tok, _, cache, _ = self.decode(next_tok, cache, S + t)
            out.append(next_tok)
        return jnp.concatenate(out, axis=1), self.history[-1] if self.history else {}

    # -------------------------------------------------------------- observe
    def _observe(self, stats: Dict, num_tokens: int,
                 skip_replan: bool = False):
        """Feed router histograms to the estimator; replan on the interval."""
        self.batches_seen += 1
        if not self.cfg.is_moe or stats.get("expert_counts") is None:
            return
        counts = np.asarray(stats["expert_counts"], np.float64)   # (L, E)
        self.estimator.update(counts)
        tele = {"batch": self.batches_seen,
                "skew": float(counts.sum(0).max()
                              / max(counts.sum(0).mean(), 1e-9))}
        self.history.append(tele)
        if (not skip_replan and self.serve.strategy != "none"
                and self.batches_seen % self.serve.predict_interval == 0):
            self.replan()

    # ------------------------------------------------------------- telemetry
    def rank_loads(self, slot_counts: np.ndarray) -> np.ndarray:
        """(L, S) slot counts -> (L, R) per-rank token loads."""
        m = self.moe_cfg
        n_slots = m.num_experts // self.ep_ranks + m.duplication_slots
        sc = np.asarray(slot_counts, np.float64)
        return sc.reshape(sc.shape[0], self.ep_ranks, n_slots).sum(-1)
