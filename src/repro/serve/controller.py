"""Online GPS controller: re-runs the paper's strategy selection on LIVE
traffic instead of fixing the strategy at engine construction.

The paper's core claim is that the best predictor depends on the
deployment point (model, hardware, skew) — and skew is a property of the
*traffic*, which drifts ("Prediction Is All MoE Needs" observes expert
distributions fluctuating early in a serving session and stabilising
later). So the controller:

  1. aggregates the engine's per-iteration expert histograms over a
     sliding window;
  2. measures the window's skewness and its volatility across windows;
  3. feeds the measured skew into ``repro.core.gps.recommend_strategy``
     for the deployment's (model, hardware) point;
  4. switches the engine strategy (none / dist_only / token_to_expert)
     with hysteresis — a switch needs ``patience`` consecutive windows
     agreeing, so a single bursty window can't thrash the plan;
  5. adapts ``predict_interval``: volatile windows re-plan every batch,
     stable windows stretch the interval (stale plans are fine when the
     distribution stops moving).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.gps import GPSReport, recommend_strategy
from repro.core.simulator import A100_PCIE, HardwareConfig
from repro.obs.audit import GPSAuditLog, GPSAuditRecord
from repro.serve.metrics import window_skew


@dataclass
class ControllerConfig:
    hardware: HardwareConfig = A100_PCIE
    window_iters: int = 16          # iterations aggregated per decision
    patience: int = 2               # consecutive agreeing windows to switch
    min_saving: float = 0.02        # below this, run strategy "none"
    batch: int = 8                  # simulator operating point
    seq: int = 256
    # predict_interval ladder by skew volatility (std/mean across windows)
    volatile_interval: int = 1
    stable_interval: int = 8
    volatility_threshold: float = 0.05
    history_windows: int = 4        # windows used for the volatility estimate
    # Migration-aware hysteresis: charge duplicating strategies the stall
    # of the replica-weight traffic the engine MEASURED last window
    # (repro.runtime), amortized per layer-step, so the guideline rejects
    # a strategy whose plan churn outweighs its balance gain. The scale
    # knob compensates when the engine serves a reduced smoke model while
    # the controller simulates the production point (cf. skew transfer).
    migration_aware: bool = True
    migration_bytes_scale: float = 1.0
    # Combined strategy space: which balancing levers the engine can drive.
    # The default keeps the pre-lever duplicate-only arbitration (and its
    # exact costing — replica HBM reads are only charged once a second
    # lever exists to arbitrate against). Add "reschedule"/"both" when the
    # engine runs the token scheduler (repro.schedule).
    levers: tuple = ("duplicate",)
    # Scheduler residual imbalance assumed until the engine reports a
    # measured one via observe(resched_residual=...).
    resched_residual_default: float = 0.05
    # Skew transfer: when the engine measures skew on a REDUCED smoke model
    # while the controller simulates the production deployment point, the
    # achievable skew caps differ (max share is bounded by top_k/E, so
    # skew <= E/top_k). Mapping preserves relative concentration:
    #   c = (skew - 1) / (cap_obs - 1);  skew' = 1 + c * (cap_target - 1).
    # 0 disables the transfer (engine and controller share one model).
    skew_cap_observed: float = 0.0
    skew_cap_target: float = 0.0


@dataclass
class Decision:
    """One controller evaluation (ticked every ``window_iters``)."""
    t: float
    skew: float
    volatility: float
    recommended: str
    strategy: str                   # strategy actually in force after this tick
    predict_interval: int
    switched: bool
    migration_stall_s: float = 0.0  # per-layer-step stall charged this tick
    migration_hidden_frac: float = 0.0  # window fraction hidden by overlap
    lever: str = "duplicate"        # balancing lever in force after this tick
    lever_recommended: str = "duplicate"
    overflow_realized_frac: float = -1.0  # window's absorbed overflow share
    report: Optional[GPSReport] = field(default=None, repr=False)


class OnlineGPSController:
    """Feeds measured per-window skew back into the GPS guideline."""

    def __init__(self, model_cfg: ModelConfig, cfg: ControllerConfig = None,
                 *, predictor_available: bool = False,
                 initial_strategy: str = "dist_only",
                 initial_lever: str = "duplicate",
                 audit: Optional[GPSAuditLog] = None):
        if not model_cfg.is_moe:
            raise ValueError("the GPS controller needs a MoE model")
        self.model_cfg = model_cfg
        self.cfg = cfg or ControllerConfig()
        self.predictor_available = predictor_available
        self.strategy = initial_strategy
        self.lever = "none" if initial_strategy == "none" else initial_lever
        self.predict_interval = self.cfg.volatile_interval
        # every _evaluate appends its full recommend_strategy input vector
        # + outcome here (repro.obs.audit), so verdicts are replayable
        self.audit = audit if audit is not None else GPSAuditLog()
        self.decisions: List[Decision] = []
        self._iters = 0
        self._counts: Optional[np.ndarray] = None
        self._skew_history: List[float] = []
        self._pending: Optional[str] = None
        self._pending_votes = 0
        self._migration_bytes = 0.0
        self._migration_hidden_bytes = 0.0
        # token-rescheduling lever measurements (repro.schedule)
        self._overflow_tokens = 0.0
        self._dropped_tokens = 0.0
        self._resched_residual: Optional[float] = None
        self._resched_absorbed_pred: Optional[float] = None

    # ------------------------------------------------------------- observe
    def observe(self, counts: Optional[np.ndarray], now: float,
                migration_bytes: float = 0.0,
                migration_hidden_bytes: float = 0.0,
                overflow_tokens: float = 0.0,
                dropped_tokens: float = 0.0,
                resched_residual: Optional[float] = None,
                resched_absorbed_pred: Optional[float] = None,
                ) -> Optional[Decision]:
        """Feed one iteration's (L, E) expert histogram (None for MoE-less
        iterations) plus the replica-weight bytes the engine's migration
        executor moved this iteration. ``migration_hidden_bytes`` is the
        share of those bytes whose transfer the overlapped prefetcher hid
        under forward compute — only the exposed remainder is charged to
        duplicating strategies.

        Token-rescheduling measurements (all optional, repro.schedule):
        ``overflow_tokens`` / ``dropped_tokens`` — capacity-overflow tokens
        this iteration and how many the rescue round still dropped; their
        window ratio is the REALIZED absorbed fraction, and overflow over
        routed tokens prices the rescue round's extra a2a bytes.
        ``resched_residual`` — the scheduler's leftover rank imbalance for
        the current quota plan (``RescheduleResult.imbalance_sched - 1``).
        ``resched_absorbed_pred`` — the scheduler's predicted absorbed
        overflow fraction, audited against the realized one.

        Returns a Decision when a window closes, else None."""
        self._iters += 1
        self._migration_bytes += float(migration_bytes)
        self._migration_hidden_bytes += min(float(migration_hidden_bytes),
                                            float(migration_bytes))
        self._overflow_tokens += float(overflow_tokens)
        self._dropped_tokens += float(dropped_tokens)
        if resched_residual is not None:
            self._resched_residual = float(resched_residual)
        if resched_absorbed_pred is not None:
            self._resched_absorbed_pred = float(resched_absorbed_pred)
        if counts is not None:
            c = np.asarray(counts, np.float64)
            self._counts = c if self._counts is None else self._counts + c
        if self._iters < self.cfg.window_iters:
            return None
        decision = self._evaluate(now)
        self._iters = 0
        self._counts = None
        self._migration_bytes = 0.0
        self._migration_hidden_bytes = 0.0
        self._overflow_tokens = 0.0
        self._dropped_tokens = 0.0
        return decision

    # ------------------------------------------------------------ evaluate
    def _measured_skew(self) -> Optional[float]:
        if self._counts is None:
            return None
        return window_skew(self._counts)

    def _volatility(self) -> float:
        h = self._skew_history[-self.cfg.history_windows:]
        if len(h) < 2:
            return 0.0
        return float(np.std(h) / max(np.mean(h), 1e-9))

    def _transfer_skew(self, skew: float) -> float:
        c = self.cfg
        if not (c.skew_cap_observed > 1.0 and c.skew_cap_target > 1.0):
            return skew
        conc = (skew - 1.0) / (c.skew_cap_observed - 1.0)
        return 1.0 + float(np.clip(conc, 0.0, 1.0)) * (c.skew_cap_target - 1.0)

    def _evaluate(self, now: float) -> Optional[Decision]:
        skew = self._measured_skew()
        if skew is None:
            return None
        self._skew_history.append(skew)
        vol = self._volatility()
        strategy_before = self.strategy

        mig_stall = 0.0
        hidden_frac = 0.0
        if self.cfg.migration_aware and self._migration_bytes > 0:
            from repro.runtime.cost import amortized_layer_stall_s
            hidden_frac = min(
                self._migration_hidden_bytes / self._migration_bytes, 1.0)
            # charge only the EXPOSED traffic (overlapped fills ride under
            # forward compute and cost the serving path nothing)
            mig_stall = amortized_layer_stall_s(
                (self._migration_bytes - self._migration_hidden_bytes)
                * self.cfg.migration_bytes_scale,
                self.cfg.hardware, num_layers=self.model_cfg.num_layers,
                window_steps=self.cfg.window_iters)

        # lever costs measured this window (see observe docstring)
        routed = float(self._counts.sum()) if self._counts is not None else 0.0
        resched_extra_frac = (self._overflow_tokens / routed
                              if routed > 0 else 0.0)
        resched_residual = (self._resched_residual
                            if self._resched_residual is not None
                            else self.cfg.resched_residual_default)
        overflow_realized = (1.0 - self._dropped_tokens / self._overflow_tokens
                             if self._overflow_tokens > 0 else -1.0)
        # replica-slot weight reads; charged only once a second lever exists
        # to arbitrate against, so duplicate-only costing stays pre-lever.
        dup_hbm = 0.0
        if len(self.cfg.levers) > 1 and self.model_cfg.moe is not None:
            from repro.core.simulator import expert_bytes
            dup_hbm = (expert_bytes(self.model_cfg)
                       * max(self.model_cfg.moe.duplication_slots, 0))

        skew_input = self._transfer_skew(skew)
        recommended, report = recommend_strategy(
            self.model_cfg, self.cfg.hardware, skew=skew_input,
            batch=self.cfg.batch, seq=self.cfg.seq,
            allow_t2e=self.predictor_available,
            min_saving=self.cfg.min_saving,
            migration_stall_s=mig_stall,
            levers=tuple(self.cfg.levers),
            resched_residual=resched_residual,
            resched_extra_frac=resched_extra_frac,
            dup_hbm_bytes=dup_hbm)

        # hysteresis over the COMBINED (prediction, lever) verdict: require
        # `patience` consecutive windows agreeing on the same pair — a lever
        # flip alone (same prediction mode) still re-wires the engine, so it
        # gates exactly like a prediction switch.
        rec_lever = getattr(recommended, "lever", "duplicate")
        rec_key = (recommended if recommended == "none"
                   else f"{recommended}+{rec_lever}")
        cur_key = (self.strategy if self.strategy == "none"
                   else f"{self.strategy}+{self.lever}")
        switched = False
        if rec_key != cur_key:
            if rec_key == self._pending:
                self._pending_votes += 1
            else:
                self._pending, self._pending_votes = rec_key, 1
            if self._pending_votes >= self.cfg.patience:
                self.strategy = str(recommended)
                self.lever = rec_lever if recommended != "none" else "none"
                self._pending, self._pending_votes = None, 0
                switched = True
        else:
            self._pending, self._pending_votes = None, 0

        self.predict_interval = (
            self.cfg.volatile_interval
            if vol >= self.cfg.volatility_threshold
            else self.cfg.stable_interval)

        d = Decision(t=now, skew=skew, volatility=vol,
                     recommended=recommended, strategy=self.strategy,
                     predict_interval=self.predict_interval,
                     switched=switched, migration_stall_s=mig_stall,
                     migration_hidden_frac=hidden_frac,
                     lever=self.lever, lever_recommended=rec_lever,
                     overflow_realized_frac=overflow_realized, report=report)
        self.decisions.append(d)

        gate = ("switched" if switched
                else "pending" if self._pending is not None else "unchanged")
        self.audit.append(GPSAuditRecord(
            seq=len(self.audit.records) + self.audit.dropped,
            t=float(now),
            window_iters=self.cfg.window_iters,
            skew_measured=float(skew),
            skew_input=float(skew_input),
            volatility=float(vol),
            migration_bytes=float(self._migration_bytes),
            migration_hidden_bytes=float(self._migration_hidden_bytes),
            migration_hidden_frac=float(hidden_frac),
            migration_stall_s=float(mig_stall),
            batch=self.cfg.batch,
            seq_len=self.cfg.seq,
            allow_t2e=self.predictor_available,
            min_saving=self.cfg.min_saving,
            recommended=recommended,
            strategy_before=strategy_before,
            strategy_after=self.strategy,
            gate=gate,
            pending_votes=self._pending_votes,
            predict_interval=self.predict_interval,
            dist_only_saving=float(report.dist_only_saving),
            t2e_saving=float(report.t2e_saving),
            baseline_total_s=float(report.baseline.total),
            best_total_s=float(report.best.total),
            lever_recommended=rec_lever,
            lever_after=self.lever,
            resched_saving=float(report.reschedule_saving),
            resched_residual=float(resched_residual),
            resched_extra_frac=float(resched_extra_frac),
            overflow_pred_frac=float(self._resched_absorbed_pred or 0.0),
            overflow_realized_frac=float(overflow_realized)))
        return d

    # ------------------------------------------------------------ reporting
    @property
    def num_switches(self) -> int:
        return sum(d.switched for d in self.decisions)

    def switch_log(self) -> List[str]:
        return [f"t={d.t:8.2f}s skew={d.skew:.2f} vol={d.volatility:.3f} "
                f"-> {d.strategy if d.strategy == 'none' else d.strategy + '+' + d.lever} "
                f"(interval={d.predict_interval})"
                for d in self.decisions if d.switched]
