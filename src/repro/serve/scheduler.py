"""Batched request scheduler for the serving examples/benchmarks.

Deliberately simple (FIFO + padding to a fixed batch): the paper's
contribution is inside the MoE layer, not the scheduler — but the engine
needs a realistic request flow to exercise per-batch prediction/replanning.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (S,) prompt tokens
    max_new_tokens: int = 8
    generated: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class BatchScheduler:
    """FIFO scheduler: pads prompts to a common length, yields full batches."""

    def __init__(self, batch_size: int, seq_len: int, pad_id: int = 0):
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.pad_id = pad_id
        self.queue: List[Request] = []
        self.completed: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def has_work(self) -> bool:
        return len(self.queue) > 0

    def next_batch(self) -> Optional[Dict]:
        if not self.queue:
            return None
        batch_reqs = self.queue[:self.batch_size]
        self.queue = self.queue[self.batch_size:]
        toks = np.full((len(batch_reqs), self.seq_len), self.pad_id, np.int32)
        mask = np.zeros((len(batch_reqs), self.seq_len), np.float32)
        for i, r in enumerate(batch_reqs):
            s = min(len(r.tokens), self.seq_len)
            toks[i, :s] = r.tokens[:s]
            mask[i, :s] = 1.0
        # pad the batch dim to a full batch (static shapes for jit)
        if len(batch_reqs) < self.batch_size:
            pad = self.batch_size - len(batch_reqs)
            toks = np.concatenate([toks, np.zeros((pad, self.seq_len), np.int32)])
            mask = np.concatenate([mask, np.zeros((pad, self.seq_len), np.float32)])
        return {"tokens": toks, "mask": mask, "requests": batch_reqs}

    def finish(self, reqs: List[Request], generated: np.ndarray):
        for i, r in enumerate(reqs):
            r.generated.extend(int(t) for t in generated[i])
            self.completed.append(r)
