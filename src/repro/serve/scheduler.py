"""Request schedulers for the serving engine.

Two generations live here:

* ``BatchScheduler`` — the original pad-to-one-batch FIFO, kept for the
  synchronous examples/tests and as the reference semantics for the
  continuous scheduler's compatibility mode.
* ``ContinuousScheduler`` — production-style continuous batching: requests
  arrive at arbitrary times, are admitted into fixed *slots* as capacity
  (slots + KV blocks) allows, decode every iteration at their own position,
  and leave the instant they finish. KV memory is managed per-slot through
  a ``BlockAllocator`` (paged pool); when the pool runs dry the youngest
  running request is preempted (blocks freed, request requeued for full
  recompute — greedy decoding makes the retry deterministic).

The scheduler is pure host-side bookkeeping: it never touches device
arrays, it only decides *what* the engine's jitted steps run on next.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serve.kvcache import BlockAllocator, SlotTables


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (S,) prompt tokens
    max_new_tokens: int = 8
    generated: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclass
class ServeRequest:
    """A request flowing through the continuous engine."""
    rid: int
    tokens: np.ndarray            # (S,) prompt tokens
    max_new_tokens: int = 8
    arrival: float = 0.0
    tenant: str = ""
    generated: List[int] = field(default_factory=list)
    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    n_preemptions: int = 0
    # timestamps stamped by the engine (virtual/wall clock of the driver)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.tokens))

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


# ---------------------------------------------------------------------------
# shared padding (reference semantics for compatibility mode)
# ---------------------------------------------------------------------------

def pad_fifo_batch(batch_reqs, batch_size: int, seq_len: int, pad_id: int = 0
                   ) -> Dict:
    """Pad a FIFO group to (batch_size, seq_len) exactly like the original
    ``BatchScheduler`` did — the contract the compatibility mode preserves."""
    toks = np.full((len(batch_reqs), seq_len), pad_id, np.int32)
    mask = np.zeros((len(batch_reqs), seq_len), np.float32)
    for i, r in enumerate(batch_reqs):
        s = min(len(r.tokens), seq_len)
        toks[i, :s] = r.tokens[:s]
        mask[i, :s] = 1.0
    if len(batch_reqs) < batch_size:
        pad = batch_size - len(batch_reqs)
        toks = np.concatenate([toks, np.zeros((pad, seq_len), np.int32)])
        mask = np.concatenate([mask, np.zeros((pad, seq_len), np.float32)])
    return {"tokens": toks, "mask": mask, "requests": list(batch_reqs)}


class BatchScheduler:
    """FIFO scheduler: pads prompts to a common length, yields full batches."""

    def __init__(self, batch_size: int, seq_len: int, pad_id: int = 0):
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.pad_id = pad_id
        self.queue: List[Request] = []
        self.completed: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def has_work(self) -> bool:
        return len(self.queue) > 0

    def next_batch(self) -> Optional[Dict]:
        if not self.queue:
            return None
        batch_reqs = self.queue[:self.batch_size]
        self.queue = self.queue[self.batch_size:]
        return pad_fifo_batch(batch_reqs, self.batch_size, self.seq_len,
                              self.pad_id)

    def finish(self, reqs: List[Request], generated: np.ndarray):
        for i, r in enumerate(reqs):
            r.generated.extend(int(t) for t in generated[i])
            self.completed.append(r)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@dataclass
class IterationPlan:
    """What the engine should run this iteration."""
    prefills: List[ServeRequest] = field(default_factory=list)
    decode_slots: List[int] = field(default_factory=list)
    preempted: List[ServeRequest] = field(default_factory=list)


class ContinuousScheduler:
    """Continuous-batching admission + slot + KV-block management.

    ``max_slots``      — concurrent requests (the decode batch dimension).
    ``prefill_len``    — prompt bucket: prompts are right-padded to this
                         (and truncated above it); one jit compile total.
    ``max_len``        — per-request position budget (prompt + generation).
    ``allocator``      — shared ``BlockAllocator`` over the physical pool.
    ``max_prefills_per_step`` — admission rate limit per iteration (bounds
                         prefill head-of-line blocking of running decodes).
    ``compat_fifo``    — preserve ``BatchScheduler`` semantics: admissions
                         happen only when ALL slots are idle, in strict
                         FIFO groups of ``max_slots`` (see ``next_batch``).
    """

    def __init__(self, max_slots: int, prefill_len: int, max_len: int,
                 allocator: BlockAllocator, max_prefills_per_step: int = 2,
                 compat_fifo: bool = False, pad_id: int = 0):
        if max_len < prefill_len:
            raise ValueError("max_len must cover the prefill bucket")
        self.max_slots = max_slots
        self.prefill_len = prefill_len
        self.max_len = max_len
        self.alloc = allocator
        self.max_prefills_per_step = max_prefills_per_step
        self.compat_fifo = compat_fifo
        self.pad_id = pad_id
        bs = allocator.block_size
        self.tables = SlotTables(max_slots, -(-max_len // bs))
        self.waiting: List[ServeRequest] = []
        self.slots: List[Optional[ServeRequest]] = [None] * max_slots
        self.completed: List[ServeRequest] = []

    # ------------------------------------------------------------ submission
    def submit(self, req: ServeRequest):
        if req.prompt_len == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.prompt_len > self.prefill_len:
            req.tokens = np.asarray(req.tokens[:self.prefill_len])
        # prefill always emits the first token, so the budget floor is 1
        req.max_new_tokens = max(1, min(req.max_new_tokens,
                                        self.max_len - req.prompt_len))
        # positions ever written: the prompt plus each generated token fed
        # BACK as decode input — the final token comes out of logits and
        # never writes KV, hence the -1
        need = self.alloc.blocks_for(req.prompt_len + req.max_new_tokens - 1)
        if need > self.alloc.num_blocks - 1:
            raise ValueError(
                f"request {req.rid} needs {need} blocks but the pool only "
                f"has {self.alloc.num_blocks - 1}: it would preempt itself "
                "forever")
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(r is not None for r in self.slots)

    def queue_depth(self, now: Optional[float] = None) -> int:
        """Waiting requests eligible to run (arrived by ``now``; all of
        them when ``now`` is None). The fleet arbiter reads this as the
        admission-backpressure signal: a persistently deep queue means
        the model's slot/KV share is starving it."""
        if now is None:
            return len(self.waiting)
        return sum(1 for r in self.waiting if r.arrival <= now)

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is not None]

    def request_in(self, slot: int) -> ServeRequest:
        r = self.slots[slot]
        assert r is not None, f"slot {slot} idle"
        return r

    # ------------------------------------------------------------- admission
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _admit(self, req: ServeRequest, now: float) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        n = self.alloc.blocks_for(req.prompt_len)
        blocks = self.alloc.alloc(n)
        if blocks is None:
            return False
        req.slot = slot
        req.state = RequestState.RUNNING
        req.t_admitted = now
        req.generated = []
        self.slots[slot] = req
        self.tables.assign(slot, blocks, req.prompt_len)
        return True

    def schedule(self, now: float) -> IterationPlan:
        """Admit what fits, then decode everything running."""
        plan = IterationPlan()
        if self.compat_fifo:
            # legacy semantics: one synchronous FIFO group at a time
            if not any(self.slots) and self.waiting:
                group = [r for r in self.waiting[:self.max_slots]
                         if r.arrival <= now]
                for req in group:
                    if self._admit(req, now):
                        self.waiting.remove(req)
                        plan.prefills.append(req)
        else:
            admitted = 0
            while (self.waiting and admitted < self.max_prefills_per_step
                   and self.waiting[0].arrival <= now):
                if not self._admit(self.waiting[0], now):
                    break                      # no slot / no blocks: backpressure
                plan.prefills.append(self.waiting.pop(0))
                admitted += 1
        plan.decode_slots = self.active_slots
        return plan

    # ------------------------------------------------------ growth / evict
    def ensure_decode_capacity(self, plan: IterationPlan):
        """Before a decode step, every active slot must own the block its
        next position lands in. Grows tables; preempts the youngest
        request (LIFO) when the pool is dry — freeing ITS blocks for the
        others. A preempted request goes back to the head of the waiting
        queue for full recompute."""
        bs = self.alloc.block_size
        for slot in list(plan.decode_slots):
            req = self.slots[slot]
            if req is None:
                continue
            while (self.tables.lengths[slot] >= self.tables.capacity_tokens(
                    slot, bs)):
                blocks = self.alloc.alloc(1)
                if blocks is not None:
                    self.tables.grow(slot, blocks[0])
                    continue
                victim = self._youngest_running(exclude_finished=True)
                if victim is None or victim.slot == slot:
                    # nothing else to evict: preempt this request itself
                    self._preempt(req, plan)
                    break
                self._preempt(victim, plan)
        plan.decode_slots = self.active_slots

    def _youngest_running(self, exclude_finished=True) -> Optional[ServeRequest]:
        running = [r for r in self.slots if r is not None]
        if not running:
            return None
        return max(running, key=lambda r: (r.t_admitted or 0.0, r.rid))

    def _preempt(self, req: ServeRequest, plan: IterationPlan):
        slot = req.slot
        self.alloc.free(self.tables.release(slot))
        self.slots[slot] = None
        req.state = RequestState.WAITING
        req.slot = None
        req.generated = []
        req.n_preemptions += 1
        self.waiting.insert(0, req)
        plan.preempted.append(req)
        if slot in plan.decode_slots:
            plan.decode_slots.remove(slot)

    # --------------------------------------------------------------- finish
    def finish_slot(self, slot: int, now: float) -> ServeRequest:
        req = self.slots[slot]
        assert req is not None
        self.alloc.free(self.tables.release(slot))
        self.slots[slot] = None
        req.state = RequestState.FINISHED
        req.t_finished = now
        req.slot = None
        self.completed.append(req)
        return req

    # --------------------------------------------- compatibility-mode facade
    def next_batch(self) -> Optional[Dict]:
        """BatchScheduler-compatible synchronous interface (compat mode):
        returns the next FIFO group padded exactly like the original."""
        assert self.compat_fifo, "next_batch() requires compat_fifo=True"
        if not self.waiting:
            return None
        group = self.waiting[:self.max_slots]
        self.waiting = self.waiting[self.max_slots:]
        return pad_fifo_batch(group, self.max_slots, self.prefill_len,
                              self.pad_id)
