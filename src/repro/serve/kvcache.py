"""Paged (block-pool) KV cache for continuous batching.

The device side is ONE fixed-shape pool per layer stack —
``{"k","v"}: (L, num_blocks, block_size, K, hd)`` — so every jitted step
sees static shapes no matter how requests join, leave, grow, or get
preempted. The host side is a free-list allocator plus per-slot block
tables (``(max_slots, max_blocks_per_slot)`` int32) that map each slot's
logical positions onto physical blocks.

Block 0 is reserved as the **null block**: table entries past a slot's
allocation point at it, writes into it are garbage, and reads from it are
always masked by the per-slot length — so padded tables need no special
casing inside jit.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

NULL_BLOCK = 0


def init_block_pool(cfg: ModelConfig, num_blocks: int, block_size: int,
                    dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Device-side pool. Requires a uniform-stack GQA architecture (the
    continuous engine asserts this)."""
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    shape = (L, num_blocks, block_size, K, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def write_prefill_blocks(pool: Dict[str, jnp.ndarray],
                         temp: Dict[str, jnp.ndarray],
                         table: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Scatter a prefilled (L, 1, S_pad, K, hd) linear cache into the pool.

    ``table``: (S_pad // block_size,) physical-block ids (traced). Entries
    past the request's allocation are NULL_BLOCK — those writes land in
    the null block and are never read. jit this once per prefill bucket.
    """
    def upd(p, t):
        L, _, S, K, hd = t.shape
        bs = p.shape[2]
        blocks = t.reshape(L, S // bs, bs, K, hd)
        return p.at[:, table].set(blocks.astype(p.dtype))
    return jax.tree.map(upd, pool, temp)


class BlockAllocator:
    """Host-side free-list over the physical blocks (block 0 reserved).

    ``quota`` caps *in-use* blocks below the physical pool size, so a
    fleet arbiter can carve one physical pool into per-model shares and
    move capacity between them without reshaping any device array.
    Shrinking the quota below current usage is legal: nothing is
    reclaimed eagerly, the allocator just refuses growth until enough
    blocks drain back through ``free`` (deferred handback).
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least one block beyond the null block")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque = deque(range(1, num_blocks))
        self._quota = num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    @property
    def quota(self) -> int:
        return self._quota

    def set_quota(self, n: int) -> None:
        """Cap in-use blocks at ``n`` (clamped to the physical pool)."""
        self._quota = max(0, min(int(n), self.num_blocks - 1))

    def blocks_for(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n physical blocks, or None (all-or-nothing) if the pool is dry
        or the grant would exceed the quota."""
        if n > len(self._free) or self.in_use + n > self._quota:
            return None
        return [self._free.popleft() for _ in range(n)]

    def free(self, blocks: List[int]):
        for b in blocks:
            if b == NULL_BLOCK:
                raise ValueError("freeing the null block")
            self._free.append(b)


class SlotTables:
    """Per-slot logical->physical block maps + lengths, as one pinned numpy
    pair that is shipped to the device every iteration (small: ints)."""

    def __init__(self, max_slots: int, max_blocks_per_slot: int):
        self.max_slots = max_slots
        self.max_blocks_per_slot = max_blocks_per_slot
        self.tables = np.full((max_slots, max_blocks_per_slot), NULL_BLOCK,
                              np.int32)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.owned: List[List[int]] = [[] for _ in range(max_slots)]

    def assign(self, slot: int, blocks: List[int], length: int):
        self.tables[slot] = NULL_BLOCK
        self.tables[slot, :len(blocks)] = blocks
        self.lengths[slot] = length
        self.owned[slot] = list(blocks)

    def grow(self, slot: int, block: int):
        n = len(self.owned[slot])
        if n >= self.max_blocks_per_slot:
            raise ValueError(f"slot {slot} exceeds max_blocks_per_slot")
        self.tables[slot, n] = block
        self.owned[slot].append(block)

    def release(self, slot: int) -> List[int]:
        blocks, self.owned[slot] = self.owned[slot], []
        self.tables[slot] = NULL_BLOCK
        self.lengths[slot] = 0
        return blocks

    def capacity_tokens(self, slot: int, block_size: int) -> int:
        """Positions this slot can hold before needing another block."""
        return len(self.owned[slot]) * block_size
