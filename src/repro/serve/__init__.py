"""Serving runtime: batched prefill/decode with prediction-guided dynamic
expert duplication in the loop (the paper's end-to-end feature)."""
from repro.serve.engine import ServeEngine, ServeConfig
from repro.serve.scheduler import Request, BatchScheduler

__all__ = ["BatchScheduler", "Request", "ServeConfig", "ServeEngine"]
