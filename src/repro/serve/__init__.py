"""Serving runtime: continuous batching over a paged KV block pool, with
prediction-guided dynamic expert duplication and an online GPS controller
in the loop (the paper's end-to-end feature under live traffic).

``ContinuousEngine`` is the production path; ``ServeEngine`` +
``BatchScheduler`` remain as the synchronous (pad-to-one-batch) reference.
"""
from repro.serve.controller import (ControllerConfig, Decision,
                                    OnlineGPSController)
from repro.serve.engine import (ContinuousConfig, ContinuousEngine,
                                ServeConfig, ServeEngine, StepEvents)
from repro.serve.kvcache import BlockAllocator, init_block_pool
from repro.serve.metrics import (RequestTiming, ServeMetrics, imbalance,
                                 plan_rank_loads)
from repro.serve.scheduler import (BatchScheduler, ContinuousScheduler,
                                   IterationPlan, Request, RequestState,
                                   ServeRequest)

__all__ = [
    "BatchScheduler", "BlockAllocator", "ContinuousConfig",
    "ContinuousEngine", "ContinuousScheduler", "ControllerConfig",
    "Decision", "IterationPlan", "OnlineGPSController", "Request",
    "RequestState", "RequestTiming", "ServeConfig", "ServeEngine",
    "ServeMetrics", "ServeRequest", "StepEvents", "imbalance",
    "init_block_pool", "plan_rank_loads",
]
