"""SLO metrics for the serving subsystem.

Per-request: TTFT (arrival -> first token), TPOT (mean inter-token time),
end-to-end latency. Per-window: throughput, goodput (completions meeting
their SLOs), measured skew, and per-rank load imbalance derived from the
expert histogram + the ACTIVE placement plan (so the reported imbalance is
what the cluster would carry under the engine's current duplication plan,
not the raw expert skew).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.placement import PlacementPlan, plan_dims
from repro.obs.metrics import MetricsRegistry


# ---------------------------------------------------------------------------
# per-request accounting
# ---------------------------------------------------------------------------

@dataclass
class RequestTiming:
    rid: int
    arrival: float
    t_first_token: float
    t_finished: float
    prompt_len: int
    new_tokens: int
    n_preemptions: int = 0
    tenant: str = ""

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.arrival

    @property
    def tpot(self) -> float:
        if self.new_tokens <= 1:
            return 0.0
        return (self.t_finished - self.t_first_token) / (self.new_tokens - 1)

    @property
    def latency(self) -> float:
        return self.t_finished - self.arrival


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


# ---------------------------------------------------------------------------
# plan-aware imbalance
# ---------------------------------------------------------------------------

def plan_rank_loads(counts: np.ndarray, plan: Optional[PlacementPlan],
                    ep_ranks: int, dup_slots: int) -> np.ndarray:
    """Expected per-rank token load for one window.

    counts: (L, E) expert histogram. Tokens for expert e split round-robin
    over its ``n_replicas[e]`` copies (plan semantics); with no plan every
    expert sits in its home slot. Returns (L, R) loads."""
    counts = np.asarray(counts, np.float64)
    L, E = counts.shape
    e_loc, n_slots = plan_dims(E, ep_ranks, dup_slots)
    loads = np.zeros((L, ep_ranks), np.float64)
    if plan is None:
        home_rank = np.arange(E) // e_loc
        for l in range(L):
            np.add.at(loads[l], home_rank, counts[l])
        return loads
    n_rep = np.asarray(plan.n_replicas)          # (L, E) stacked plans
    table = np.asarray(plan.replica_table)       # (L, E, C_max)
    for l in range(L):
        for e in range(E):
            k = max(int(n_rep[l, e]), 1)
            share = counts[l, e] / k
            for c in range(k):
                rank = int(table[l, e, c]) // n_slots
                loads[l, rank] += share
    return loads


def imbalance(loads: np.ndarray) -> float:
    """max/mean over ranks, averaged over layers (1.0 = perfect)."""
    loads = np.asarray(loads, np.float64)
    mean = np.maximum(loads.mean(axis=-1), 1e-12)
    return float((loads.max(axis=-1) / mean).mean())


def window_skew(counts: np.ndarray) -> float:
    """Measured skewness of an aggregated (L, E) expert histogram:
    max share x E per layer, averaged over layers (paper Sec 2). The ONE
    definition both the metrics windows and the GPS controller report —
    the controller's switching signal must equal the printed skew column."""
    c = np.asarray(counts, np.float64)
    p = c / np.maximum(c.sum(axis=1, keepdims=True), 1e-12)
    return float((p.max(axis=1) * p.shape[1]).mean())


# ---------------------------------------------------------------------------
# rolling serve metrics
# ---------------------------------------------------------------------------

@dataclass
class WindowRecord:
    t_start: float
    t_end: float
    iterations: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    completions: int = 0
    skew: float = 0.0
    imbalance: float = 1.0
    strategy: str = ""
    # predictor accuracy of the prediction window(s) closing inside this
    # metrics window (repro.obs.accuracy; nan until one closes)
    pred_hit_rate: float = float("nan")
    pred_kl: float = float("nan")


class ServeMetrics:
    """Collects per-iteration + per-request events; summarises SLOs."""

    def __init__(self, window_iters: int = 16, slo_ttft: float = float("inf"),
                 slo_tpot: float = float("inf"),
                 registry: Optional[MetricsRegistry] = None,
                 model: str = ""):
        self.window_iters = window_iters
        self.slo_ttft = slo_ttft
        self.slo_tpot = slo_tpot
        # every summary() key is published here as a serve_* gauge, and
        # per-request timings as histograms — scrape via
        # registry.to_prometheus() / registry.to_jsonl(). When several
        # model instances share one registry (fleet serving), ``model``
        # becomes a label on every serve_* series so co-resident engines
        # don't overwrite each other; empty keeps the historical unlabeled
        # series names.
        self.registry = registry if registry is not None else MetricsRegistry()
        self.model = model
        self._labels: Dict[str, str] = {"model": model} if model else {}
        self.timings: List[RequestTiming] = []
        self.windows: List[WindowRecord] = []
        self.phase_times: Dict[str, float] = {}   # dispatch phase breakdown
        # replica-weight migration accounting (repro.runtime): planned =
        # bytes a re-plan's diff would move; moved = bytes actually shipped
        # by the executor; stall = modeled serialized wire time, split into
        # hidden (overlapped with forward compute by the layer-staged
        # prefetcher) and exposed (still on the serving critical path);
        # prebegun/cancelled = predictive pre-migrations started before the
        # re-plan boundary / abandoned on misprediction
        self.migration: Dict[str, float] = {
            "planned_bytes": 0.0, "bytes_moved": 0.0, "stall_s": 0.0,
            "hidden_s": 0.0, "exposed_s": 0.0,
            "replans": 0.0, "commits": 0.0, "rejected": 0.0,
            "prebegun": 0.0, "cancelled": 0.0}
        # token-rescheduling accounting (repro.schedule): capacity-overflow
        # tokens seen at dispatch, how many the rescue round still dropped,
        # the rescue round's extra a2a bytes, and the scheduler's per-plan
        # predictions (absorbed overflow fraction, residual imbalance)
        self.resched: Dict[str, float] = {
            "overflow_tokens": 0.0, "dropped_tokens": 0.0,
            "resched_a2a_bytes": 0.0, "plans": 0.0,
            "absorbed_pred_sum": 0.0, "residual_sum": 0.0}
        # decode fast-path accounting: wall seconds and emitted tokens of
        # pure-decode iterations (prefill_tokens == 0) feed the
        # decode_toks_per_s summary column; live/alloc block counts from
        # the paged-attention block tables feed the fused-vs-gather
        # attention-compute roofline (the gather oracle materializes and
        # attends over every allocated table column, the fused kernel only
        # touches live blocks)
        self._decode_wall_s: float = 0.0
        self._decode_tokens_n: float = 0.0
        self._attn_live_blocks: float = 0.0
        self._attn_alloc_blocks: float = 0.0
        self._win_counts: Optional[np.ndarray] = None
        self._win: Optional[WindowRecord] = None
        self._t0: Optional[float] = None
        self._t_last: float = 0.0

    # ------------------------------------------------------------- per-iter
    def record_iteration(self, now: float, dt: float, *, prefill_tokens: int,
                         decode_tokens: int, counts: Optional[np.ndarray],
                         plan: Optional[PlacementPlan], ep_ranks: int,
                         dup_slots: int, strategy: str = "",
                         wall_s: float = 0.0,
                         attn_live_blocks: float = 0.0,
                         attn_alloc_blocks: float = 0.0):
        if self._t0 is None:
            self._t0 = now
        self._t_last = now + dt
        if prefill_tokens == 0 and decode_tokens > 0:
            self._decode_wall_s += float(wall_s)
            self._decode_tokens_n += float(decode_tokens)
            self._attn_live_blocks += float(attn_live_blocks)
            self._attn_alloc_blocks += float(attn_alloc_blocks)
        if self._win is None:
            self._win = WindowRecord(t_start=now, t_end=now + dt,
                                     strategy=strategy)
        w = self._win
        w.iterations += 1
        w.t_end = now + dt
        w.prefill_tokens += prefill_tokens
        w.decode_tokens += decode_tokens
        w.strategy = strategy
        if counts is not None:
            c = np.asarray(counts, np.float64)
            self._win_counts = c if self._win_counts is None \
                else self._win_counts + c
        if w.iterations >= self.window_iters:
            self._close_window(plan, ep_ranks, dup_slots)

    def _close_window(self, plan, ep_ranks: int, dup_slots: int):
        w = self._win
        if w is None:
            return
        if self._win_counts is not None:
            agg = self._win_counts
            w.skew = window_skew(agg)
            if ep_ranks > 1:
                w.imbalance = imbalance(
                    plan_rank_loads(agg, plan, ep_ranks, dup_slots))
        self.windows.append(w)
        self._win = None
        self._win_counts = None

    def flush(self, plan=None, ep_ranks: int = 1, dup_slots: int = 0):
        self._close_window(plan, ep_ranks, dup_slots)

    # ------------------------------------------------------- phase timings
    def record_phases(self, phases: Dict[str, float]):
        """Attach a measured dispatch phase breakdown (seconds per phase:
        route/pack/a2a/ffn/combine/total, from
        ``repro.moe.profile.dispatch_phase_times``). Repeated calls
        accumulate, so callers can record prefill- and decode-shaped
        profiles separately."""
        for k, v in phases.items():
            self.phase_times[k] = self.phase_times.get(k, 0.0) + float(v)

    def reset_phases(self) -> Dict[str, float]:
        """Clear the accumulated phase breakdown (returning the old one) so
        a second profile — e.g. decode-shaped after prefill-shaped — starts
        from zero instead of double-accumulating into the same columns."""
        old = self.phase_times
        self.phase_times = {}
        return old

    # ----------------------------------------------------------- migration
    def record_migration(self, *, planned_bytes: float = 0.0,
                         bytes_moved: float = 0.0, stall_s: float = 0.0,
                         hidden_s: float = 0.0, exposed_s: float = 0.0,
                         replanned: bool = False, committed: bool = False,
                         rejected: bool = False, prebegun: bool = False,
                         cancelled: bool = False):
        """Account one replica-migration event (re-plan diffed, chunk
        executed, swap committed, re-plan rejected by the cost gate, a
        predictive pre-begin, or a cancel-on-misprediction). ``hidden_s``
        / ``exposed_s`` split the modeled wire time of the chunks a step
        issued into overlapped-with-compute vs critical-path seconds."""
        m = self.migration
        m["planned_bytes"] += float(planned_bytes)
        m["bytes_moved"] += float(bytes_moved)
        m["stall_s"] += float(stall_s)
        m["hidden_s"] += float(hidden_s)
        m["exposed_s"] += float(exposed_s)
        m["replans"] += bool(replanned)
        m["commits"] += bool(committed)
        m["rejected"] += bool(rejected)
        m["prebegun"] += bool(prebegun)
        m["cancelled"] += bool(cancelled)

    # ---------------------------------------------------------- rescheduling
    def record_resched(self, *, overflow_tokens: float = 0.0,
                       dropped_tokens: float = 0.0,
                       extra_a2a_bytes: float = 0.0,
                       planned: bool = False,
                       absorbed_pred: float = 0.0,
                       residual: float = 0.0):
        """Account token-rescheduling activity: per-iteration overflow /
        rescue-drop counts and the rescue round's extra a2a bytes, plus
        (``planned=True``) one scheduler quota-plan event with its
        predicted absorbed-overflow fraction and residual imbalance."""
        r = self.resched
        r["overflow_tokens"] += float(overflow_tokens)
        r["dropped_tokens"] += float(dropped_tokens)
        r["resched_a2a_bytes"] += float(extra_a2a_bytes)
        if planned:
            r["plans"] += 1.0
            r["absorbed_pred_sum"] += float(absorbed_pred)
            r["residual_sum"] += float(residual)

    # ---------------------------------------------------------- per-request
    def record_completion(self, t: RequestTiming):
        self.timings.append(t)
        if self._win is not None:
            self._win.completions += 1
        reg = self.registry
        lbl = self._labels
        reg.counter("serve_requests_completed_total",
                    "Requests that finished decoding", **lbl).inc()
        reg.histogram("serve_ttft_seconds",
                      "Time to first token", **lbl).observe(t.ttft)
        if t.new_tokens > 1:
            reg.histogram("serve_tpot_seconds",
                          "Mean inter-token time per request",
                          **lbl).observe(t.tpot)
        reg.histogram("serve_latency_seconds",
                      "End-to-end request latency", **lbl).observe(t.latency)

    # ------------------------------------------------- predictor accuracy
    def record_accuracy(self, hit_rate: float, kl: float) -> None:
        """Attach the score of the prediction window that just closed to
        the open (or latest) metrics window, so per-window rows carry the
        predictor-accuracy columns next to skew/imbalance."""
        w = self._win if self._win is not None else \
            (self.windows[-1] if self.windows else None)
        if w is not None:
            w.pred_hit_rate = float(hit_rate)
            w.pred_kl = float(kl)
        reg = self.registry
        reg.gauge("serve_pred_hit_rate",
                  "Predictor top-1 hot-expert hit rate, last closed "
                  "prediction window", **self._labels).set(float(hit_rate))
        reg.gauge("serve_pred_kl",
                  "KL(realized || predicted), last closed prediction "
                  "window", **self._labels).set(float(kl))

    # -------------------------------------------------------------- summary
    def summary(self) -> Dict[str, float]:
        ts = self.timings
        ttfts = [t.ttft for t in ts]
        tpots = [t.tpot for t in ts if t.new_tokens > 1]
        lats = [t.latency for t in ts]
        horizon = max((self._t_last - self._t0) if self._t0 is not None
                      else 0.0, 1e-9)
        good = [t for t in ts
                if t.ttft <= self.slo_ttft and t.tpot <= self.slo_tpot]
        total_tokens = sum(t.new_tokens for t in ts)
        phase_cols = {f"phase_{k}_us": v * 1e6
                      for k, v in self.phase_times.items()}
        mig = self.migration
        rs = self.resched
        # realized absorbed fraction: of the overflow tokens the dispatch
        # saw, how many the rescue round kept (1.0 when nothing overflowed
        # — there was nothing to absorb and nothing was dropped)
        absorbed = (1.0 - rs["dropped_tokens"] / rs["overflow_tokens"]
                    if rs["overflow_tokens"] > 0 else 1.0)
        out = {
            **phase_cols,
            "dropped_tokens": rs["dropped_tokens"],
            "overflow_tokens": rs["overflow_tokens"],
            "resched_a2a_bytes": rs["resched_a2a_bytes"],
            "overflow_absorbed_frac": absorbed,
            "resched_plans": rs["plans"],
            "resched_absorbed_pred": (rs["absorbed_pred_sum"] / rs["plans"]
                                      if rs["plans"] > 0 else 0.0),
            "resched_residual": (rs["residual_sum"] / rs["plans"]
                                 if rs["plans"] > 0 else 0.0),
            "migration_planned_bytes": mig["planned_bytes"],
            "migration_bytes_moved": mig["bytes_moved"],
            "migration_stall_us": mig["stall_s"] * 1e6,
            "migration_hidden_s": mig["hidden_s"],
            "migration_exposed_s": mig["exposed_s"],
            "migration_replans": mig["replans"],
            "migration_commits": mig["commits"],
            "migration_rejected": mig["rejected"],
            "migration_prebegun": mig["prebegun"],
            "migration_cancelled": mig["cancelled"],
            "completed": float(len(ts)),
            "ttft_p50": _pct(ttfts, 50), "ttft_p99": _pct(ttfts, 99),
            "tpot_mean": float(np.mean(tpots)) if tpots else 0.0,
            "tpot_p99": _pct(tpots, 99),
            "latency_p50": _pct(lats, 50), "latency_p99": _pct(lats, 99),
            "throughput_tok_s": total_tokens / horizon,
            "throughput_req_s": len(ts) / horizon,
            "goodput_req_s": len(good) / horizon,
            "preemptions": float(sum(t.n_preemptions for t in ts)),
        }
        # decode fast path: wall-clock decode throughput plus the
        # attention-compute roofline ratio (allocated table blocks the
        # gather oracle covers / live blocks the fused kernel computes).
        # The ratio is structurally >= 1.0 — it is the fused kernel's
        # block-skip advantage measured from real engine block-table
        # state, independent of interpret-mode overheads.
        if self._decode_wall_s > 0:
            out["decode_toks_per_s"] = \
                self._decode_tokens_n / self._decode_wall_s
        if self._attn_alloc_blocks > 0:
            out["fused_vs_gather_speedup"] = (
                self._attn_alloc_blocks / max(self._attn_live_blocks, 1.0))
        # publish every summary column through the registry so the same
        # numbers are scrapeable (Prometheus text / JSONL) without a second
        # hand-rolled aggregation path
        for k, v in out.items():
            self.registry.gauge(
                f"serve_{k}", f"ServeMetrics summary column {k}",
                **self._labels).set(v)
        return out

    # --------------------------------------------------------- SLO per tenant
    def slo_attainment(self, *, tenant: Optional[str] = None,
                       slo_ttft: Optional[float] = None,
                       slo_tpot: Optional[float] = None) -> float:
        """Fraction of completed requests meeting the SLOs, optionally
        restricted to one tenant and/or overriding the instance SLOs with
        a tenant class's targets. 1.0 with no matching completions — no
        evidence of violation is not a violation (the fleet arbiter must
        not starve a model for having served nothing yet)."""
        ttft = self.slo_ttft if slo_ttft is None else slo_ttft
        tpot = self.slo_tpot if slo_tpot is None else slo_tpot
        ts = [t for t in self.timings
              if tenant is None or t.tenant == tenant]
        if not ts:
            return 1.0
        good = sum(1 for t in ts if t.ttft <= ttft and t.tpot <= tpot)
        return good / len(ts)

    def imbalance_over_time(self) -> List[float]:
        return [w.imbalance for w in self.windows]

    def skew_over_time(self) -> List[float]:
        return [w.skew for w in self.windows]
