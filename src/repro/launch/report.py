"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md roofline
table.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def fmt_b(x: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load_rows(dir_: str, tag: str = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        base = os.path.basename(path)[:-5]
        if tag and not base.endswith("_" + tag):
            continue
        if not tag and any(base.endswith(s) for s in ("_kernel", "_nofsdp")):
            pass  # variants still listed; caller filters by mesh
        with open(path) as f:
            rows.append(json.load(f))
    return rows


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def table(rows, mesh: str) -> str:
    rows = [r for r in rows if r.get("mesh") == mesh and r.get("status") == "ok"]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "MODEL/HLO useful | peak bytes/dev | compile |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} "
            f"| {fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {fmt_b(r.get('temp_bytes', 0) + r.get('argument_bytes', 0))} "
            f"| {r.get('compile_s', 0):.0f}s |")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    rows = load_rows(args.dir)
    print(table(rows, args.mesh))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
