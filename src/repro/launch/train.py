"""Training driver.

Runs REAL steps (CPU: use --reduced; TPU: full configs) with the same
step builders the dry-run lowers — one source of truth.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.synthetic import token_batches
from repro.launch.specs import plan_args
from repro.models.transformer import Runtime, init_model
from repro.optim.adamw import adamw_init
from repro.optim.schedules import cosine_schedule, wsd_schedule
from repro.train import checkpoint as ckpt
from repro.train.steps import make_train_step


def build_lr_fn(cfg, base_lr: float, total_steps: int):
    if cfg.lr_schedule == "wsd":
        return wsd_schedule(base_lr, warmup=max(10, total_steps // 20),
                            stable=int(total_steps * 0.7),
                            total=total_steps)
    return cosine_schedule(base_lr, warmup=max(10, total_steps // 20),
                           total=total_steps)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default="", help="save checkpoint here at the end")
    ap.add_argument("--data-mesh", type=int, default=0,
                    help="devices for a (data, model) dev mesh (0 = single)")
    ap.add_argument("--model-mesh", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    mesh = None
    rt = Runtime()
    if args.data_mesh and args.model_mesh:
        mesh = jax.make_mesh((args.data_mesh, args.model_mesh),
                             ("data", "model"))
        rt = Runtime(mesh=mesh, ep=cfg.is_moe, ep_ranks=args.model_mesh,
                     use_duplication=False)

    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"(analytical {cfg.num_params()/1e6:.1f}M) "
          f"family={cfg.family} moe={cfg.is_moe}")

    opt = adamw_init(params)
    lr_fn = build_lr_fn(cfg, args.lr, args.steps)
    step_fn = jax.jit(make_train_step(cfg, rt, lr_fn=lr_fn))
    plan = plan_args(cfg, rt.ep_ranks) if rt.ep else None

    gen = token_batches(args.seed, cfg.vocab_size, args.batch, args.seq)
    losses = []
    t0 = time.perf_counter()
    for step in range(args.steps):
        raw = next(gen)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.input_mode == "mixed" and cfg.num_prefix_embeddings:
            batch["prefix_embeds"] = jnp.zeros(
                (args.batch, cfg.num_prefix_embeddings, cfg.d_model),
                jnp.bfloat16)
        if cfg.is_encdec:
            batch["frames"] = jnp.zeros(
                (args.batch, min(64, cfg.encoder.max_source_len),
                 cfg.encoder.d_model), jnp.bfloat16)
        ctx = mesh or _null()
        with ctx:
            params, opt, metrics = step_fn(params, opt, batch, plan)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            extra = ""
            if cfg.is_moe and metrics.get("expert_counts") is not None:
                c = np.asarray(metrics["expert_counts"]).sum(0)
                extra = f" skew={c.max() / max(c.mean(), 1e-9):.2f}"
            print(f"step {step:4d} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f}{extra}")
    dt = time.perf_counter() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({dt / args.steps * 1e3:.0f} ms/step); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params, "opt": opt})
        print(f"checkpoint saved to {args.ckpt}")
    return 0 if losses[-1] < losses[0] else 1


class _null:
    def __enter__(self):
        return self
    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    raise SystemExit(main())
