"""Abstract (ShapeDtypeStruct) stand-ins for every model input/state.

Nothing here allocates device memory: params/opt/cache trees come from
``jax.eval_shape`` over the real init functions, then get NamedShardings
attached, so ``jit(...).lower(**specs)`` sees exactly what a real launch
would pass — the shannon/kernels dry-run pattern.
"""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core.placement import identity_plan, stack_plans
from repro.models.transformer import Runtime, init_cache, init_model
from repro.optim.adamw import adamw_init
from repro.sharding import batch_axes, param_specs

# Sliding window applied to full-attention archs for long_500k decode
# (Mixtral's own 4K window — paper-faithful; DESIGN.md Sec 4).
LONG_CONTEXT_WINDOW = 4096


def _sds(tree_struct, spec_tree, mesh: Mesh):
    """Attach NamedShardings to an eval_shape output."""
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        tree_struct, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _cast_tree(struct, dtype):
    cast = lambda s: jax.ShapeDtypeStruct(
        s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype)
    return jax.tree.map(cast, struct)


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

def runtime_for(cfg: ModelConfig, mesh: Mesh, shape: InputShape,
                *, use_kernel: bool = False,
                decode_expert_tp: bool = False) -> Runtime:
    window = 0
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid") \
            and not cfg.sliding_window:
        window = LONG_CONTEXT_WINDOW
    return Runtime(mesh=mesh, ep=cfg.is_moe, ep_ranks=mesh.shape["model"],
                   use_duplication=cfg.is_moe
                   and (cfg.moe.duplication_slots > 0),
                   use_kernel=use_kernel, window_override=window,
                   decode_expert_tp=decode_expert_tp)


def plan_args(cfg: ModelConfig, ep_ranks: int):
    """Concrete identity placement-plan stack (tiny arrays, replicated)."""
    if not cfg.is_moe:
        return None
    m = cfg.moe
    plans = [identity_plan(m.num_experts, ep_ranks, m.duplication_slots,
                           m.max_copies) for _ in range(cfg.num_layers)]
    return stack_plans(plans)


# ---------------------------------------------------------------------------
# inputs
# ---------------------------------------------------------------------------

def _batch_axes_for(mesh: Mesh, B: int):
    """Batch axes, dropped to replication when B isn't evenly divisible
    (e.g. long-context decode with global_batch=1)."""
    b = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in b])) if b else 1
    return b if b and B % n == 0 else ()

def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                *, per_device_batch: Optional[int] = None) -> Dict:
    """ShapeDtypeStructs for the step inputs of (arch, input-shape).

    train/prefill: {"tokens", "labels"[, "prefix_embeds"|"frames"]}
    decode: {"tokens": (B, 1)} — the cache is separate (abstract_cache).
    """
    B, S = shape.global_batch, shape.seq_len
    b = _batch_axes_for(mesh, B)
    bspec = NamedSharding(mesh, P(b, None))

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                               sharding=bspec)}

    specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=bspec)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32,
                                               sharding=bspec)
    if cfg.input_mode == "mixed" and cfg.num_prefix_embeddings:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_prefix_embeddings, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(b, None, None)))
    if cfg.is_encdec:
        enc = cfg.encoder
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, enc.max_source_len, enc.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(b, None, None)))
    return specs


# ---------------------------------------------------------------------------
# params / optimizer / cache
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, mesh: Mesh, *, dtype=jnp.bfloat16,
                    fsdp: bool = True, expert_tp: bool = False):
    struct = jax.eval_shape(partial(init_model, cfg=cfg),
                            jax.random.PRNGKey(0))
    struct = _cast_tree(struct, dtype)
    fsdp_axes = batch_axes(mesh) if fsdp else ()
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp_axes])) or 1
    specs = param_specs(struct, stacked_prefixes=("layers", "enc_layers"),
                        fsdp_axes=fsdp_axes, fsdp_size=fsdp_size, mesh=mesh,
                        expert_tp_axes=batch_axes(mesh) if expert_tp else ())
    return _sds(struct, specs, mesh), specs


def abstract_opt_state(params_struct, param_spec_tree, mesh: Mesh,
                       *, moment_dtype=jnp.float32):
    struct = jax.eval_shape(adamw_init, params_struct)
    # mu/nu inherit the param sharding; step is replicated
    from repro.optim.adamw import AdamWState
    mu = _sds(_cast_tree(struct.mu, moment_dtype), param_spec_tree, mesh)
    nu = _sds(_cast_tree(struct.nu, moment_dtype), param_spec_tree, mesh)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return AdamWState(step=step, mu=mu, nu=nu)


def cache_specs(cfg: ModelConfig, cache_struct, mesh: Mesh, B: int):
    """PartitionSpec tree matching init_cache's structure."""
    m = mesh.shape["model"]
    b = _batch_axes_for(mesh, B)     # batch axis dropped when not divisible

    def leaf_spec(path: str, leaf):
        nd = len(leaf.shape)
        if "cross_k" in path or "cross_v" in path:      # (L,B,Se,KV,hd)
            kv_ok = cfg.num_kv_heads % m == 0
            return P(None, b, None if kv_ok else "model",
                     "model" if kv_ok else None, None)
        if path.endswith("/k") or path.endswith("/v") or path in ("k", "v"):
            if nd == 5:                                  # (L,B,C,KV,hd)
                kv_ok = cfg.num_kv_heads % m == 0
                cl = leaf.shape[2]
                seq_ok = (not kv_ok) and cl % m == 0
                return P(None, b, "model" if seq_ok else None,
                         "model" if kv_ok else None, None)
            if nd == 4:                                  # hybrid: (B,W,KV,hd)
                kv_ok = cfg.num_kv_heads % m == 0
                return P(b, None, "model" if kv_ok else None, None)
        if "c_kv" in path or "k_rope" in path:           # MLA: (L,B,C,r)
            return P(None, b, None, None)
        if "wkv" in path:                                # rwkv: (L,B,H,hd,hd)
            h_ok = leaf.shape[2] % m == 0
            return P(None, b, "model" if h_ok else None, None, None)
        if "shift" in path:                              # rwkv: (L,B,d)
            return P(None, b, "model" if cfg.d_model % m == 0 else None)
        if path.endswith("/h") or path == "h":           # griffin: (B,dr)
            dr = leaf.shape[-1]
            return P(b, "model" if dr % m == 0 else None)
        if "conv" in path:                               # griffin: (B,w,dr)
            dr = leaf.shape[-1]
            return P(b, None, "model" if dr % m == 0 else None)
        # default: shard batch dim if it matches
        return P(*([b] + [None] * (nd - 1)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_struct)
    specs = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
        specs.append(leaf_spec("/".join(parts), leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def abstract_cache(cfg: ModelConfig, rt: Runtime, shape: InputShape,
                   mesh: Mesh):
    B = shape.global_batch
    max_len = shape.seq_len
    if cfg.input_mode == "mixed" and cfg.num_prefix_embeddings:
        max_len += cfg.num_prefix_embeddings    # prefix fills cache positions
    struct = jax.eval_shape(
        partial(init_cache, cfg, rt, B, max_len))
    specs = cache_specs(cfg, struct, mesh, B)
    return _sds(struct, specs, mesh)
