"""Serving driver: batched requests through the ServeEngine with
prediction-guided expert duplication.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
      --reduced --strategy dist_only --requests 32 --batch 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.predictors import ConditionalProbabilityModel
from repro.data.synthetic import make_routing_trace, token_batches
from repro.models.transformer import init_model
from repro.serve import BatchScheduler, Request, ServeConfig, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--strategy", default="dist_only",
                    choices=["none", "dist_only", "token_to_expert"])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--dup-slots", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-mesh", type=int, default=0)
    ap.add_argument("--model-mesh", type=int, default=0)
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the run "
                         "(open in Perfetto / chrome://tracing)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    mesh, ep_ranks = None, 1
    if args.data_mesh and args.model_mesh:
        mesh = jax.make_mesh((args.data_mesh, args.model_mesh),
                             ("data", "model"))
        ep_ranks = args.model_mesh

    params = init_model(jax.random.PRNGKey(args.seed), cfg)

    predictor = None
    if args.strategy == "token_to_expert" and cfg.is_moe:
        trace = make_routing_trace(
            num_sequences=64, seq_len=args.seq, vocab=cfg.vocab_size,
            num_experts=cfg.moe.num_experts, num_layers=cfg.num_layers,
            skew=1.5, seed=args.seed)
        predictor = ConditionalProbabilityModel(
            cfg.num_layers, cfg.moe.num_experts, cfg.vocab_size
        ).fit(trace.experts, trace.tokens)

    tracer = None
    if args.trace_out:
        from repro.obs import SpanTracer
        tracer = SpanTracer(process_name="repro-launch-serve")
    engine = ServeEngine(cfg, params,
                         ServeConfig(strategy=args.strategy,
                                     dup_slots=args.dup_slots,
                                     max_len=args.seq + args.new_tokens),
                         mesh=mesh, ep_ranks=ep_ranks, predictor=predictor,
                         tracer=tracer)

    sched = BatchScheduler(args.batch, args.seq)
    gen = token_batches(args.seed, cfg.vocab_size, 1, args.seq)
    for rid in range(args.requests):
        toks = next(gen)["tokens"][0]
        sched.submit(Request(rid, toks, max_new_tokens=args.new_tokens))

    t0 = time.perf_counter()
    batches = 0
    while sched.has_work():
        batch = sched.next_batch()
        out, tele = engine.generate({"tokens": jnp.asarray(batch["tokens"])},
                                    max_new_tokens=args.new_tokens)
        sched.finish(batch["requests"], np.asarray(out))
        batches += 1
        if cfg.is_moe and tele:
            print(f"batch {batches}: measured routing skew={tele['skew']:.2f}")
    dt = time.perf_counter() - t0
    done = len(sched.completed)
    print(f"served {done} requests in {batches} batches, {dt:.1f}s "
          f"({done * args.new_tokens / dt:.1f} tok/s)")
    if tracer is not None:
        tracer.export(args.trace_out,
                      extra={"pred_accuracy": engine.accuracy.to_obj()
                             if engine.accuracy else []})
        print(f"trace written to {args.trace_out}")
    return 0 if done == args.requests else 1


if __name__ == "__main__":
    raise SystemExit(main())
