"""Production mesh construction.

The target is a TPU v5e pod: 256 chips as a (data=16, model=16) mesh, or
two pods as (pod=2, data=16, model=16). ``model`` carries tensor
parallelism for attention/dense-FFN/vocab and expert parallelism for MoE;
``data``/``pod`` shard the batch (and, with fsdp, parameter storage).

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first
jax init, and smoke tests must keep seeing 1 CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh((data, model), ("data", "model"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def batch_shards(mesh) -> int:
    n = 1
    for name in ("pod", "data"):
        if name in mesh.axis_names:
            n *= mesh.shape[name]
    return n
