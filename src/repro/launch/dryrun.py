import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST run before any other import (jax locks the
device count at first init); 512 fake CPU devices back the production
meshes. Nothing is executed — steps are lowered from ShapeDtypeStructs
(no allocation) and compiled; we record memory_analysis / cost_analysis /
collective bytes for EXPERIMENTS.md (Dry-run + Roofline sections).

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
  python -m repro.launch.dryrun ... --out experiments/dryrun
"""

import argparse
import json
import sys
import time
import traceback
from functools import partial

import jax

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs.registry import ASSIGNED_ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_cache, abstract_opt_state,
                                abstract_params, input_specs, plan_args,
                                runtime_for)
from repro.roofline import analyze, save_report
from repro.train.steps import (make_decode_step, make_prefill_step,
                               make_train_step)


def skip_reason(cfg: ModelConfig, shape: InputShape) -> str:
    """Combination-level skips, all documented in DESIGN.md Sec 4."""
    return ""        # every assigned combo runs (windowed decode for dense)


def lower_one(cfg: ModelConfig, shape: InputShape, mesh, *,
              use_kernel: bool = False, fsdp: bool = True,
              donate: bool = True, remat: bool = False,
              microbatches: int = 1, expert_tp: bool = False,
              train_dtype: str = "float32"):
    """Returns (lowered, compiled, elapsed_s) for one combination."""
    rt = runtime_for(cfg, mesh, shape, use_kernel=use_kernel,
                     decode_expert_tp=expert_tp)
    params, pspecs = abstract_params(cfg, mesh, fsdp=fsdp,
                                     expert_tp=expert_tp)
    plan = plan_args(cfg, rt.ep_ranks)
    t0 = time.perf_counter()

    with mesh:
        if shape.kind == "train":
            import jax.numpy as jnp
            params, pspecs = abstract_params(
                cfg, mesh, dtype=jnp.dtype(train_dtype), fsdp=fsdp)
            opt = abstract_opt_state(params, pspecs, mesh)
            step = make_train_step(cfg, rt, remat=remat,
                                   microbatches=microbatches)
            fn = jax.jit(partial(step, plan=plan),
                         donate_argnums=(0, 1) if donate else ())
            lowered = fn.lower(params, opt, input_specs(cfg, shape, mesh))
        elif shape.kind == "prefill":
            cache = abstract_cache(cfg, rt, shape, mesh)
            step = make_prefill_step(cfg, rt)
            fn = jax.jit(partial(step, plan=plan),
                         donate_argnums=(2,) if donate else ())
            lowered = fn.lower(params, input_specs(cfg, shape, mesh), cache)
        else:  # decode
            cache = abstract_cache(cfg, rt, shape, mesh)
            step = make_decode_step(cfg, rt)
            cache_len = shape.seq_len - 1
            fn = jax.jit(lambda p, t, c: step(p, t, c, cache_len, plan),
                         donate_argnums=(2,) if donate else ())
            lowered = fn.lower(params, input_specs(cfg, shape, mesh)["tokens"],
                               cache)
        compiled = lowered.compile()
    return lowered, compiled, time.perf_counter() - t0


def run_combo(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
              use_kernel: bool = False, fsdp: bool = True,
              tag: str = "", remat: bool = False,
              microbatches: int = 1, pad_vocab: int = 0,
              expert_tp: bool = False, train_dtype: str = "float32") -> dict:
    cfg = get_config(arch)
    if pad_vocab:
        # Megatron-style vocab padding: round the vocab up so the
        # embedding/LM-head shard evenly over the model axis (otherwise an
        # odd vocab like minicpm's 122753 replicates and the logits psum
        # dominates the collective term)
        import dataclasses as _dc
        v = (cfg.vocab_size + pad_vocab - 1) // pad_vocab * pad_vocab
        cfg = _dc.replace(cfg, vocab_size=v)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    lowered, compiled, dt = lower_one(cfg, shape, mesh,
                                      use_kernel=use_kernel, fsdp=fsdp,
                                      remat=remat, microbatches=microbatches,
                                      expert_tp=expert_tp,
                                      train_dtype=train_dtype)
    rep = analyze(arch, shape, mesh_name, chips, compiled, cfg=cfg)
    row = rep.row()
    row.update(status="ok", compile_s=round(dt, 1))
    if out_dir:
        suffix = f"_{tag}" if tag else ""
        save_report(f"{out_dir}/{arch}_{shape_name}_{mesh_name}{suffix}.json",
                    rep)
        with open(f"{out_dir}/{arch}_{shape_name}_{mesh_name}{suffix}.json",
                  "r+") as f:
            d = json.load(f)
            d.update(status="ok", compile_s=round(dt, 1))
            f.seek(0)
            json.dump(d, f, indent=1)
            f.truncate()
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--pad-vocab", type=int, default=0,
                    help="round vocab up to a multiple (Megatron-style)")
    ap.add_argument("--expert-tp", action="store_true",
                    help="2D expert sharding (EP x f-TP) for decode")
    ap.add_argument("--train-dtype", default="float32",
                    help="parameter dtype for train lowering "
                         "(bfloat16 halves ZeRO gather bytes)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]

    failures = 0
    for arch in archs:
        for shape in shapes:
            try:
                row = run_combo(arch, shape, args.multi_pod, args.out,
                                use_kernel=args.use_kernel,
                                fsdp=not args.no_fsdp, tag=args.tag,
                                remat=args.remat,
                                microbatches=args.microbatches,
                                pad_vocab=args.pad_vocab,
                                expert_tp=args.expert_tp,
                                train_dtype=args.train_dtype)
                if row["status"] == "ok":
                    print(f"OK   {arch:22s} {shape:12s} {row['mesh']:8s} "
                          f"compile={row['compile_s']}s "
                          f"c={row['compute_s']:.2e}s "
                          f"m={row['memory_s']:.2e}s "
                          f"n={row['collective_s']:.2e}s "
                          f"dom={row['dominant']}")
                else:
                    print(f"SKIP {arch:22s} {shape:12s} ({row['reason']})")
            except Exception as e:
                failures += 1
                print(f"FAIL {arch:22s} {shape:12s}: "
                      f"{type(e).__name__}: {e}")
                traceback.print_exc(limit=3)
            sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
