"""Greedy waterfill scheduler.

Starts from the even round-robin split and repeatedly moves share of some
expert from a copy on the most-loaded rank to a same-expert copy on the
least-loaded rank, subject to per-slot capacity. Each move levels the pair
of ranks as far as the donor copy and the receiver slot's spare capacity
allow, so the max rank load is non-increasing and the loop terminates when
no expert bridges the extreme ranks (or the gap is negligible).

O(iters * E * C) host-side work per layer — microseconds at config scale,
run once per replan window.
"""

from __future__ import annotations

import numpy as np

from repro.schedule.base import TokenScheduler, even_shares


def _loads(tok: np.ndarray, rank_of: np.ndarray, ep_ranks: int) -> np.ndarray:
    out = np.zeros((ep_ranks,), np.float64)
    np.add.at(out, rank_of.reshape(-1), tok.reshape(-1))
    return out


class GreedyWaterfill(TokenScheduler):
    name = "greedy"

    def __init__(self, max_iters: int = 128, tol: float = 1e-6):
        self.max_iters = max_iters
        self.tol = tol

    def shares(self, counts: np.ndarray, n_rep: np.ndarray,
               rank_of: np.ndarray, *, ep_ranks: int,
               cap: float) -> np.ndarray:
        E, C = rank_of.shape
        cols = np.arange(C)[None, :]
        live = cols < np.maximum(n_rep, 1)[:, None]
        sh = even_shares(n_rep, C)
        tok = sh * counts[:, None]                        # (E, C) tokens
        # a copy may legally hold up to `cap`, except when even split
        # already exceeds it (then capacity can't be met; keep even level).
        cap_ec = np.where(live, np.maximum(cap, tok), 0.0)

        for _ in range(self.max_iters):
            loads = _loads(tok, rank_of, ep_ranks)
            tol = self.tol * max(loads.max(), 1.0)
            moved = False
            # donors from most-loaded down, receivers from least-loaded up;
            # take the first donor/receiver pair bridged by some expert
            for r_hi in np.argsort(-loads):
                r_hi = int(r_hi)
                on_hi = live & (rank_of == r_hi) & (tok > 1e-9)
                if not on_hi.any():
                    continue
                for r_lo in np.argsort(loads):
                    r_lo = int(r_lo)
                    gap = loads[r_hi] - loads[r_lo]
                    if gap <= tol:
                        break                      # receivers only get worse
                    on_lo = live & (rank_of == r_lo) & (cap_ec - tok > 1e-9)
                    cand = np.where(on_hi.any(axis=1) & on_lo.any(axis=1))[0]
                    if cand.size == 0:
                        continue
                    # move from the candidate whose donor copy is largest
                    give = np.where(on_hi[cand], tok[cand], 0.0)
                    e = int(cand[np.argmax(give.max(axis=1))])
                    c_hi = int(np.argmax(np.where(on_hi[e], tok[e], -1.0)))
                    spare = np.where(on_lo[e], cap_ec[e] - tok[e], 0.0)
                    c_lo = int(np.argmax(spare))
                    delta = min(gap / 2.0, tok[e, c_hi], spare[c_lo])
                    if delta <= tol:
                        continue
                    tok[e, c_hi] -= delta
                    tok[e, c_lo] += delta
                    moved = True
                    break
                if moved:
                    break
            if not moved:
                break

        safe = np.maximum(counts, 1e-12)[:, None]
        out = np.where(live, tok / safe, 0.0)
        # zero-traffic experts keep the even split
        return np.where(counts[:, None] > 0, out, even_shares(n_rep, C))
