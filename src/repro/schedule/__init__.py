"""Token-level rescheduling — the second balancing lever next to expert
duplication (ROADMAP "combined strategy space"; MicroMoE / HarMoEny refs in
PAPERS.md).

Duplication moves *weights* toward hot experts; rescheduling moves *tokens*
toward spare capacity. The subsystem has two halves:

* a host-side scheduler (this package) that turns the per-expert token
  histogram into per-copy **quotas** — fractional shares of each expert's
  traffic per replica, chosen to minimise the max EP-rank load subject to
  per-slot capacity. Two implementations behind one interface:
  ``greedy`` (waterfill over the expert x rank histogram) and ``lp``
  (transportation-problem refinement via binary search on the load bound
  + max-flow feasibility, dependency-free).
* an in-graph consumer (``repro.moe.dispatch.choose_replica_quota``) that
  reads the fixed-shape quantised quota tensor ``(E, C_max) int32`` and a
  per-(token, k) salt to pick replicas — plus a *rescue round* that
  re-dispatches capacity-overflow tokens to an alternate copy, which is
  what absorbs drops at dispatch time.

Quotas are *data*, never shapes: the jitted path compiles once and every
replan window just feeds new tensors.
"""

from repro.schedule.base import (RESCHED_Q, RescheduleResult, TokenScheduler,
                                 even_quota, even_quota_stack, even_shares,
                                 make_scheduler, quota_realized_shares,
                                 shares_to_quota)
from repro.schedule.greedy import GreedyWaterfill
from repro.schedule.lp import TransportLP

__all__ = [
    "RESCHED_Q", "RescheduleResult", "TokenScheduler", "GreedyWaterfill",
    "TransportLP", "even_quota", "even_quota_stack", "even_shares",
    "make_scheduler", "quota_realized_shares", "shares_to_quota",
]
