"""Transportation-LP scheduler (refinement of the greedy waterfill).

The exact problem — minimise the max EP-rank load subject to per-slot
capacity and per-expert conservation — is a transportation LP over the
(expert x rank) histogram. We solve it dependency-free by binary-searching
the load bound ``z`` and checking feasibility with a max-flow:

    source --counts[e]--> expert e --cap(e,r)--> rank r --z--> sink

where ``cap(e, r)`` sums the slot capacities of ``e``'s live copies on
``r``. A bound is feasible iff the max flow saturates every source edge.
The smallest feasible ``z`` (to ``tol`` x total tokens) gives the optimal
assignment; per-copy shares are recovered by filling each rank's copies in
table order. Greedy's solution seeds the upper bound, so the LP never
returns a worse max load than the waterfill.

Edmonds–Karp on a ``2 + E + R`` node graph; ~30 feasibility probes per
layer per replan window — host-side microseconds at config scale.
"""

from __future__ import annotations

from collections import deque

import numpy as np

from repro.schedule.base import TokenScheduler, even_shares
from repro.schedule.greedy import GreedyWaterfill, _loads


def _max_flow(cap: np.ndarray, s: int, t: int) -> np.ndarray:
    """Edmonds–Karp. cap: (V, V) float capacities. Returns the flow matrix."""
    V = cap.shape[0]
    flow = np.zeros_like(cap)
    while True:
        # BFS for a shortest augmenting path in the residual graph
        parent = np.full((V,), -1, np.int64)
        parent[s] = s
        q = deque([s])
        while q and parent[t] < 0:
            u = q.popleft()
            resid = cap[u] - flow[u]
            for v in np.where((resid > 1e-12) & (parent < 0))[0]:
                parent[v] = u
                q.append(int(v))
        if parent[t] < 0:
            return flow
        # bottleneck along the path, then augment
        push = np.inf
        v = t
        while v != s:
            u = int(parent[v])
            push = min(push, cap[u, v] - flow[u, v])
            v = u
        v = t
        while v != s:
            u = int(parent[v])
            flow[u, v] += push
            flow[v, u] -= push
            v = u


class TransportLP(TokenScheduler):
    name = "lp"

    def __init__(self, tol: float = 1e-3, max_probes: int = 30):
        self.tol = tol
        self.max_probes = max_probes
        self._greedy = GreedyWaterfill()

    def shares(self, counts: np.ndarray, n_rep: np.ndarray,
               rank_of: np.ndarray, *, ep_ranks: int,
               cap: float) -> np.ndarray:
        E, C = rank_of.shape
        total = float(counts.sum())
        if total <= 0:
            return even_shares(n_rep, C)
        cols = np.arange(C)[None, :]
        live = cols < np.maximum(n_rep, 1)[:, None]
        even_tok = even_shares(n_rep, C) * counts[:, None]
        cap_ec = np.where(live, np.maximum(cap, even_tok), 0.0)  # per copy

        # aggregate copy capacity per (expert, rank)
        cap_er = np.zeros((E, ep_ranks), np.float64)
        for e in range(E):
            for c in range(int(max(n_rep[e], 1))):
                cap_er[e, int(rank_of[e, c])] += cap_ec[e, c]

        greedy_sh = self._greedy.shares(counts, n_rep, rank_of,
                                        ep_ranks=ep_ranks, cap=cap)
        greedy_tok = greedy_sh * counts[:, None]
        hi = float(_loads(greedy_tok, rank_of, ep_ranks).max())
        lo = total / ep_ranks

        # node ids: 0 = source, 1..E = experts, E+1..E+R = ranks, last = sink
        V = 2 + E + ep_ranks
        s, t = 0, V - 1
        base = np.zeros((V, V), np.float64)
        base[s, 1:1 + E] = counts
        base[1:1 + E, 1 + E:1 + E + ep_ranks] = cap_er

        best_flow = None
        for _ in range(self.max_probes):
            if hi - lo <= self.tol * total:
                break
            z = 0.5 * (lo + hi)
            g = base.copy()
            g[1 + E:1 + E + ep_ranks, t] = z
            f = _max_flow(g, s, t)
            if f[s].sum() >= total - 1e-6 * total:
                hi = z
                best_flow = f
            else:
                lo = z

        if best_flow is None:
            return greedy_sh                      # LP couldn't beat greedy
        flow_er = best_flow[1:1 + E, 1 + E:1 + E + ep_ranks]  # (E, R)

        # recover per-copy tokens: fill each rank's copies in table order
        tok = np.zeros((E, C), np.float64)
        for e in range(E):
            remaining = flow_er[e].copy()
            for c in range(int(max(n_rep[e], 1))):
                r = int(rank_of[e, c])
                take = min(cap_ec[e, c], remaining[r])
                tok[e, c] = take
                remaining[r] -= take
        safe = np.maximum(counts, 1e-12)[:, None]
        out = np.where(live, tok / safe, 0.0)
        return np.where(counts[:, None] > 0, out, even_shares(n_rep, C))
