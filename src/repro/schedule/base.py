"""Scheduler interface + quota representation.

A *quota* row for expert ``e`` is the quantised cumulative distribution of
its per-copy shares: ``quota[e, c]`` is the threshold (in ``[0, RESCHED_Q]``)
below which a uniform draw lands on copy ``<= c``. Dead copy columns
(``c >= n_replicas[e]``) sit at ``RESCHED_Q`` so they can never be chosen.
The in-graph consumer draws ``u = hash(salt, expert) % RESCHED_Q`` and picks
``choice = #{c : quota[e, c] <= u}`` — an odd multiplicative hash makes the
draws equidistributed, so realized shares track quotas to O(1/T).

Shapes are static: ``(E, C_max) int32`` per layer, stacked to
``(L, E, C_max)`` for the scanned forward. Even quotas reproduce the legacy
round-robin split exactly in expectation, which is what engines pass when
the reschedule lever is off but the compiled signature must not change.
"""

from __future__ import annotations

import dataclasses
from abc import ABC, abstractmethod
from typing import Optional, Sequence

import numpy as np

RESCHED_Q = 1 << 16          # quota quantisation denominator
_HASH_MULT = 40503           # odd -> coprime with RESCHED_Q -> equidistributed
_HASH_EXPERT = 131           # decorrelates same-salt draws across experts


@dataclasses.dataclass(frozen=True)
class RescheduleResult:
    """One layer's scheduling decision + predicted effect.

    ``shares`` rows hold fractional per-copy splits (sum to 1 over live
    copies); ``quota`` is their quantised cumulative form consumed by
    dispatch. Overflow numbers are in tokens, measured against the per-slot
    capacity the scheduler was given.
    """
    quota: np.ndarray                # (E, C_max) int32 in [0, RESCHED_Q]
    shares: np.ndarray               # (E, C_max) float64, rows sum to 1
    overflow_even: float             # tokens over slot cap at even split
    overflow_sched: float            # tokens over slot cap at scheduled split
    moved_tokens: float              # tokens redirected vs the even split
    rank_loads_even: np.ndarray      # (R,) tokens per EP rank, even split
    rank_loads_sched: np.ndarray     # (R,) tokens per EP rank, scheduled

    @property
    def imbalance_even(self) -> float:
        m = float(self.rank_loads_even.mean())
        return float(self.rank_loads_even.max() / m) if m > 0 else 1.0

    @property
    def imbalance_sched(self) -> float:
        m = float(self.rank_loads_sched.mean())
        return float(self.rank_loads_sched.max() / m) if m > 0 else 1.0

    @property
    def overflow_absorbed_frac(self) -> float:
        """Predicted fraction of even-split slot overflow the scheduled
        split removes; 1.0 when there was nothing to absorb."""
        if self.overflow_even <= 0:
            return 1.0
        return max(0.0, 1.0 - self.overflow_sched / self.overflow_even)


def _plan_host(plan):
    """(n_rep, table) as host arrays from a (possibly traced-free) plan."""
    return (np.asarray(plan.n_replicas, np.int64),
            np.asarray(plan.replica_table, np.int64))


def shares_to_quota(shares: np.ndarray, n_rep: np.ndarray) -> np.ndarray:
    """Quantise fractional shares to cumulative int32 thresholds.

    Dead columns are pinned to RESCHED_Q; the last live column is pinned to
    RESCHED_Q too so rounding can never leak probability mass off the end.
    """
    E, C = shares.shape
    cum = np.cumsum(shares, axis=1)
    q = np.rint(cum * RESCHED_Q).astype(np.int64)
    cols = np.arange(C)[None, :]
    live_last = np.maximum(n_rep, 1)[:, None] - 1
    q = np.where(cols >= live_last, RESCHED_Q, q)
    return np.clip(q, 0, RESCHED_Q).astype(np.int32)


def even_shares(n_rep: np.ndarray, max_copies: int) -> np.ndarray:
    """The legacy round-robin split: 1/n_rep on each live copy."""
    E = n_rep.shape[0]
    cols = np.arange(max_copies)[None, :]
    live = cols < np.maximum(n_rep, 1)[:, None]
    return np.where(live, 1.0 / np.maximum(n_rep, 1)[:, None], 0.0)


def even_quota(plan) -> np.ndarray:
    """(E, C_max) int32 quota reproducing the even round-robin split."""
    n_rep, table = _plan_host(plan)
    return shares_to_quota(even_shares(n_rep, table.shape[1]), n_rep)


def even_quota_stack(num_layers: int, plan) -> np.ndarray:
    """(L, E, C_max) even quotas — the lever-off tensor engines feed so the
    jitted signature stays fixed across lever switches."""
    q = even_quota(plan)
    return np.broadcast_to(q, (num_layers,) + q.shape).copy()


def quota_realized_shares(quota: np.ndarray) -> np.ndarray:
    """Invert a quota row back to fractional shares (for tests/audit)."""
    q = quota.astype(np.float64) / RESCHED_Q
    return np.diff(np.concatenate([np.zeros((q.shape[0], 1)), q], axis=1),
                   axis=1)


def rank_loads(shares: np.ndarray, counts: np.ndarray, rank_of: np.ndarray,
               ep_ranks: int) -> np.ndarray:
    """(R,) tokens landing on each EP rank under fractional shares."""
    tok = shares * counts[:, None]                       # (E, C)
    out = np.zeros((ep_ranks,), np.float64)
    np.add.at(out, rank_of.reshape(-1), tok.reshape(-1))
    return out


def slot_overflow(shares: np.ndarray, counts: np.ndarray, n_rep: np.ndarray,
                  cap: float) -> float:
    """Tokens exceeding per-slot capacity, summed over live copies."""
    tok = shares * counts[:, None]
    cols = np.arange(shares.shape[1])[None, :]
    live = cols < np.maximum(n_rep, 1)[:, None]
    return float(np.maximum(np.where(live, tok, 0.0) - cap, 0.0).sum())


class TokenScheduler(ABC):
    """One-layer scheduling interface: histogram in, quota + prediction out.

    ``cap`` is the aggregate per-slot token capacity for the window being
    planned (source-rank capacity x EP ranks on the sharded prefill path).
    """

    name: str = "base"

    @abstractmethod
    def shares(self, counts: np.ndarray, n_rep: np.ndarray,
               rank_of: np.ndarray, *, ep_ranks: int,
               cap: float) -> np.ndarray:
        """Return (E, C_max) fractional per-copy shares (rows sum to 1)."""

    def plan_layer(self, counts: np.ndarray, plan, *, ep_ranks: int,
                   dup_slots: int, cap: float) -> RescheduleResult:
        counts = np.asarray(counts, np.float64)
        n_rep, table = _plan_host(plan)
        n_slots = counts.shape[0] // ep_ranks + dup_slots
        # rank hosting each copy; dead columns alias the home rank (share 0)
        rank_of = (table // n_slots).astype(np.int64)

        ev = even_shares(n_rep, table.shape[1])
        sh = self.shares(counts, n_rep, rank_of, ep_ranks=ep_ranks, cap=cap)
        # normalise defensively: rows must be a distribution over live copies
        cols = np.arange(sh.shape[1])[None, :]
        live = cols < np.maximum(n_rep, 1)[:, None]
        sh = np.where(live, np.maximum(sh, 0.0), 0.0)
        norm = sh.sum(axis=1, keepdims=True)
        sh = np.where(norm > 0, sh / np.maximum(norm, 1e-12), ev)

        moved = 0.5 * float((np.abs(sh - ev) * counts[:, None]).sum())
        return RescheduleResult(
            quota=shares_to_quota(sh, n_rep),
            shares=sh,
            overflow_even=slot_overflow(ev, counts, n_rep, cap),
            overflow_sched=slot_overflow(sh, counts, n_rep, cap),
            moved_tokens=moved,
            rank_loads_even=rank_loads(ev, counts, rank_of, ep_ranks),
            rank_loads_sched=rank_loads(sh, counts, rank_of, ep_ranks),
        )

    def plan_stack(self, counts: np.ndarray, plans: Sequence, *,
                   ep_ranks: int, dup_slots: int, cap: float):
        """Plan L layers: counts (L, E), per-layer plans. Returns the
        stacked (L, E, C_max) int32 quota plus per-layer results."""
        results = [self.plan_layer(counts[l], plans[l], ep_ranks=ep_ranks,
                                   dup_slots=dup_slots, cap=cap)
                   for l in range(counts.shape[0])]
        return np.stack([r.quota for r in results]), results


def make_scheduler(impl: str) -> TokenScheduler:
    from repro.schedule.greedy import GreedyWaterfill
    from repro.schedule.lp import TransportLP
    impls = {"greedy": GreedyWaterfill, "lp": TransportLP}
    if impl not in impls:
        raise ValueError(f"unknown scheduler impl {impl!r}; "
                         f"choose from {sorted(impls)}")
    return impls[impl]()
