"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md Sec
Roofline):

  compute    = HLO_FLOPs_per_device  / peak_FLOPs
  memory     = HLO_bytes_per_device  / HBM_bw
  collective = collective_bytes_per_device / ICI_bw

``compiled.cost_analysis()`` reports the per-device (SPMD-partitioned)
program, so all terms are per-device already — equivalent to the global
form divided by chips. collective_bytes comes from parsing the optimized
HLO: we sum the RESULT-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async -start variants
counted once, -done skipped).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~45 GB/s
effective per ICI link x 2 links per torus axis (configurable below).
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict

# --------------------------------------------------------------------------
# hardware constants (TPU v5e)
# --------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_LINK_BW = 45e9           # effective bytes/s per link
ICI_LINKS = 2                # usable links per torus axis for a collective
ICI_BW = ICI_LINKS * ICI_LINK_BW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over all shapes in an HLO result type (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALL_REF = re.compile(r"(?:body|condition|to_apply|calls|"
                       r"branch_computations=\{[^}]*)=?%?([\w.\-]+)")


def _computation_bodies(hlo_text: str) -> Dict[str, str]:
    """Split HLO module text into {computation_name: body_text}."""
    comps: Dict[str, str] = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_HEADER.match(stripped)
        if m and stripped.endswith("{"):
            name = m.group(1)
            buf = []
            continue
        if name is not None:
            if stripped == "}":
                comps[name] = "\n".join(buf)
                name = None
            else:
                buf.append(line)
    return comps


def _loop_body_computations(comps: Dict[str, str]) -> set:
    """Names of computations reachable from any while-op body."""
    # direct while bodies
    roots = set()
    calls: Dict[str, set] = {n: set() for n in comps}
    for cname, body in comps.items():
        for line in body.splitlines():
            if " while(" in line or "=while(" in line:
                m = re.search(r"body=%?([\w.\-]+)", line)
                if m:
                    roots.add(m.group(1))
            for ref in re.findall(r"(?:to_apply|calls|body|condition)=%?"
                                  r"([\w.\-]+)", line):
                calls[cname].add(ref)
    # transitive closure from roots
    seen = set()
    stack = list(roots)
    while stack:
        n = stack.pop()
        if n in seen:
            continue
        seen.add(n)
        stack.extend(calls.get(n, ()))
    return seen


def collective_bytes(hlo_text: str, loop_trips: int = 1) -> Dict[str, int]:
    """Per-collective-kind result bytes from optimized HLO (per device).

    XLA's static analyses (and a flat text scan) count while-loop bodies
    ONCE; collectives inside a loop body (the layer scan) are multiplied
    by ``loop_trips`` (= num_layers for our models — the layer scan is
    the only loop containing collectives)."""
    comps = _computation_bodies(hlo_text)
    in_loop = _loop_body_computations(comps)

    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0

    def scan_text(text: str, mult: int):
        for line in text.splitlines():
            if "=" not in line:
                continue
            _, _, rest = line.partition("=")
            rest = rest.strip()
            m = re.match(r"^((?:\([^)]*\))|(?:[\w\[\],{}: /#*]+?))\s+"
                         r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                         r"collective-permute)(-start)?\(", rest)
            if not m:
                continue
            type_str, kind = m.group(1), m.group(2)
            out[kind] += _shape_bytes(type_str) * mult
            out["count"] += 1

    if comps:
        for cname, body in comps.items():
            scan_text(body, loop_trips if cname in in_loop else 1)
    else:                      # fallback: flat scan, no loop correction
        scan_text(hlo_text, 1)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# --------------------------------------------------------------------------
# analytic op model (primary source for compute/memory terms)
# --------------------------------------------------------------------------
#
# XLA's cost_analysis counts while-loop bodies ONCE, so the raw HLO flops /
# bytes undercount scanned layers (and seq scans) by up to the trip count.
# The roofline table therefore uses this analytic model for the compute and
# memory terms — validated against HLO on unrolled (hybrid) configs — and
# keeps the raw HLO numbers alongside for reference.

def analytic_flops(cfg, shape) -> float:
    """Per-STEP total (all devices) FLOPs for the step a shape lowers."""
    from repro.core.simulator import (attention_flops, dense_ffn_flops_per_token,
                                      ffn_flops_per_token)
    L, d, V = cfg.num_layers, cfg.d_model, cfg.vocab_size

    if shape.kind == "decode":
        tokens = shape.global_batch
        ctx = shape.seq_len
        w = cfg.sliding_window or (4096 if shape.name == "long_500k" else 0)
        s_eff = min(ctx, w) if w else ctx
        if cfg.family == "ssm":
            per_tok_layer = 14 * d * d            # rwkv6 time+channel mix
            attn = per_tok_layer * tokens * L
        elif cfg.family == "hybrid":
            dr = cfg.rnn_width or d
            rec_l = (4 * d * dr + 3 * dr) * 2 * tokens   # gates + out proj
            loc_l = attention_flops(cfg, tokens, min(ctx, cfg.local_window))
            n_rec = sum(1 for i in range(L)
                        if cfg.block_pattern[i % len(cfg.block_pattern)]
                        == "recurrent") if cfg.block_pattern else 0
            attn = rec_l * n_rec + loc_l * (L - n_rec)
        else:
            attn = attention_flops(cfg, tokens, s_eff, causal=False) * L
        ffn = (ffn_flops_per_token(cfg)
               + dense_ffn_flops_per_token(cfg)) * tokens * L
        head = 2 * tokens * d * V
        return attn + ffn + head

    tokens = shape.global_batch * shape.seq_len
    if cfg.input_mode == "mixed" and cfg.num_prefix_embeddings:
        tokens = shape.global_batch * (shape.seq_len + cfg.num_prefix_embeddings)
    if cfg.family == "ssm":
        attn = 14 * d * d * tokens * L
    elif cfg.family == "hybrid":
        dr = cfg.rnn_width or d
        rec_l = (4 * d * dr + 3 * dr) * 2 * tokens
        loc_l = attention_flops(cfg, tokens, min(shape.seq_len, cfg.local_window))
        n_rec = sum(1 for i in range(L)
                    if cfg.block_pattern[i % len(cfg.block_pattern)] == "recurrent")
        attn = rec_l * n_rec + loc_l * (L - n_rec)
    else:
        attn = attention_flops(cfg, tokens, shape.seq_len) * L
    ffn = (ffn_flops_per_token(cfg) + dense_ffn_flops_per_token(cfg)) * tokens * L
    head = 2 * tokens * d * V
    enc = 0.0
    if cfg.is_encdec:
        e = cfg.encoder
        etoks = shape.global_batch * e.max_source_len
        enc = (attention_flops(cfg, etoks, e.max_source_len)
               + 2 * 3 * e.d_model * e.d_ff * etoks) * e.num_layers
    fwd = attn + ffn + head + enc
    return 3.0 * fwd if shape.kind == "train" else fwd


def analytic_hbm_bytes(cfg, shape, chips: int, *, act_coeff: float = 10.0
                       ) -> float:
    """Per-DEVICE HBM traffic per step (weights + activations + cache/opt).

    Coefficients are deliberately simple and documented:
      * weights: each device reads its resident shard once per step
        (train: + grad write + fp32 Adam moments read+write).
      * duplication: with ``duplication_slots > 0`` the persistent replica
        store (a second copy of the home experts plus the replica slots,
        ``repro.runtime.ReplicaStore``) adds one read of the extra slot
        entries per MoE layer per step — the memory-side price of serving
        without a per-step weight collective. ``MoEConfig.
        store_hbm_budget_gb`` caps the slots this term may grow to
        (``core.placement.clamp_dup_slots``).
      * activations: ~act_coeff residency round-trips per layer
        (norms, attention in/out, FFN in/out, residuals).
      * decode: full KV-cache shard read per step (the decode bottleneck).
    """
    B = 2  # bf16
    params = cfg.num_params()
    w = params * B / chips
    if (cfg.moe is not None and cfg.moe.duplication_slots > 0
            and shape.kind != "train"):
        e = cfg.moe
        ff_mult = 3 if cfg.activation == "swiglu" else 2
        expert_bytes = ff_mult * cfg.d_model * e.d_ff_expert * B
        w += e.duplication_slots * expert_bytes * cfg.num_layers
    if shape.kind == "train":
        # fwd read + bwd read + grad write (bf16) + moments r/w (fp32 x2 x2)
        w = params * (4 * 3 + 2 * 2 + 4 * 4) / chips / 2  # fp32 params
    tokens_local = shape.global_batch * shape.seq_len / chips
    if shape.kind == "decode":
        tokens_local = max(shape.global_batch / chips, 1.0 / chips)
    act = act_coeff * tokens_local * cfg.d_model * B * cfg.num_layers
    if shape.kind == "train":
        act *= 2.0        # bwd re-reads activations
    cache = 0.0
    if shape.kind == "decode":
        w_win = cfg.sliding_window or (4096 if shape.name == "long_500k" else 0)
        clen = min(shape.seq_len, w_win) if w_win else shape.seq_len
        if cfg.family == "ssm":
            state = cfg.num_heads * cfg.head_dim * cfg.head_dim * 4
            cache = shape.global_batch * state * cfg.num_layers / chips
        elif cfg.family == "hybrid":
            dr = cfg.rnn_width or cfg.d_model
            cache = shape.global_batch * (dr * 4 + cfg.local_window
                                          * cfg.num_kv_heads * cfg.head_dim
                                          * B) * cfg.num_layers / chips
        elif cfg.attention == "mla" and cfg.mla is not None:
            cache = (shape.global_batch * clen
                     * (cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim) * B
                     * cfg.num_layers / chips)
        else:
            cache = (shape.global_batch * clen * 2 * cfg.num_kv_heads
                     * cfg.head_dim * B * cfg.num_layers / chips)
        cache = max(cache, 0.0)
    return w + act + cache


# --------------------------------------------------------------------------
# model flops (the "useful compute" yardstick)
# --------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """6·N_active·D for training, 2·N_active·D for inference (per step).

    N_active counts only activated experts for MoE (paper/industry
    convention); D = tokens processed by the step (decode: one per seq).
    """
    n = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 new token/seq


# --------------------------------------------------------------------------
# report
# --------------------------------------------------------------------------

@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # analytic op model (primary: compute/memory terms)
    analytic_flops_per_device: float
    analytic_hbm_per_device: float
    # raw HLO static analysis (reference; while bodies counted once)
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    # loop-corrected collective bytes from compiled HLO (primary)
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops_total: float = 0.0
    # memory analysis (bytes, per device)
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    peak_bytes: int = 0

    @property
    def compute_s(self) -> float:
        return self.analytic_flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.analytic_hbm_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        """Roofline step time = max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / analytic FLOPs: how much of executed compute is
        'useful' 6ND/2ND work (catches attention-quadratic, vocab-head,
        remat and capacity-padding overheads)."""
        total = self.analytic_flops_per_device * self.chips
        return self.model_flops_total / total if total else 0.0

    def row(self) -> Dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 total_s=self.total_s,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def analyze(arch: str, shape, mesh_name: str, chips: int, compiled,
            cfg=None) -> RooflineReport:
    """Build a report from a jax ``compiled`` object."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):       # jax <= 0.4.x: list per device
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    trips = cfg.num_layers if cfg is not None else 1
    coll = collective_bytes(hlo, loop_trips=trips)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = dict(
                argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
                output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
                temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
                peak_bytes=int(getattr(ma, "peak_memory_in_bytes", 0)
                               or getattr(ma, "temp_size_in_bytes", 0)),
            )
    except Exception:
        pass

    mf = model_flops(cfg, shape) if cfg is not None else 0.0
    af = analytic_flops(cfg, shape) / chips if cfg is not None else flops
    ab = analytic_hbm_bytes(cfg, shape, chips) if cfg is not None else hbm
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        analytic_flops_per_device=af, analytic_hbm_per_device=ab,
        hlo_flops_per_device=flops, hlo_bytes_per_device=hbm,
        collective_bytes_per_device=coll["total"],
        collective_breakdown={k: v for k, v in coll.items()
                              if k in _COLLECTIVES or k == "count"},
        model_flops_total=mf, **mem)


def save_report(path: str, report: RooflineReport) -> None:
    import os
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(report.row(), f, indent=1)
