"""Sharding rules: parameter-path -> PartitionSpec mapping.

Mesh axes: ("pod", "data", "model") multi-pod or ("data", "model")
single-pod. Batch is sharded over (pod, data); the "model" axis carries
tensor parallelism for attention/FFN/vocab and expert parallelism for MoE.

Conventions (dims refer to the *unstacked* parameter; scanned layer stacks
prepend an unsharded L dim which is handled automatically):

  embedding table (V, d)        -> (model, None)        vocab-sharded
  attention wq/wk/wv (d, H*hd)  -> (None, model)        head-sharded
  attention wo (H*hd, d)        -> (model, None)
  dense ffn w_gate/w_up (d, f)  -> (None, model)
  dense ffn w_down (f, d)       -> (model, None)
  moe experts (E, d, f)         -> (model, None, None)  expert-parallel
  router, norms, biases, small  -> replicated
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex over '/'-joined path, spec for the LAST ndim dims of the leaf)
_RULES = [
    (r"embed/table$", ("model", None)),
    (r"(wq|wk|wv|w_q)/w$", (None, "model")),
    (r"(wo|w_o)/w$", ("model", None)),
    (r"(w_uk|w_uv)/w$", (None, "model")),          # MLA up-projections: head-sharded
    (r"(w_dkv|w_krope)/w$", (None, None)),
    (r"experts/w_gate$", ("model", None, None)),
    (r"experts/w_up$", ("model", None, None)),
    (r"experts/w_down$", ("model", None, None)),
    (r"(ffn|shared|dense|channel_mix)/w_(gate|up|k)/w$", (None, "model")),
    (r"(ffn|shared|dense|channel_mix)/w_(down|v)/w$", ("model", None)),
    (r"(shared|dense)/w_(gate|up)$", (None, "model")),
    (r"(shared|dense)/w_down$", ("model", None)),
    # rwkv time-mix projections
    (r"time_mix/w_(r|k|v|g)/w$", (None, "model")),
    (r"time_mix/w_o/w$", ("model", None)),
    # griffin recurrent block
    (r"(w_gate|w_main)/w$", (None, "model")),
    (r"w_out/w$", ("model", None)),
    (r"(w_a|w_x)/w$", ("model", "model_diag")),    # placeholder; replaced below
]

# RG-LRU per-channel maps (dr -> dr) stay model-sharded on output only.
_RULES = [(p, s) for p, s in _RULES if s != ("model", "model_diag")]
_RULES.append((r"(w_a|w_x)/w$", (None, "model")))


def spec_for_path(path: str, ndim: int, stacked: bool) -> P:
    """PartitionSpec for a parameter. ``stacked``: leading scan-layer dim."""
    body_ndim = ndim - (1 if stacked else 0)
    for pat, spec in _RULES:
        if re.search(pat, path):
            spec = tuple(spec)
            if len(spec) < body_ndim:            # e.g. biases under matched scope
                spec = (None,) * (body_ndim - len(spec)) + spec
            if len(spec) != body_ndim:
                break
            full = ((None,) if stacked else ()) + spec
            return P(*full)
    return P()                                    # replicated


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out, treedef


def _add_fsdp(spec: P, shape, fsdp_axes, fsdp_size: int,
              stacked: bool) -> P:
    """ZeRO-style extension: shard the largest still-unsharded dim of a
    >=2D weight over the batch axes, when evenly divisible. Parameters are
    then stored fully sharded and all-gathered at use (XLA inserts the
    gathers); this is the standard MaxText-style fsdp axis. The scanned
    layer dim (leading dim of stacked params) is never fsdp-sharded."""
    if not fsdp_axes or len(shape) < 2:
        return spec
    used = set()
    for s in spec:
        for a in (s if isinstance(s, tuple) else (s,)):
            if a:
                used.add(a)
    if used & set(fsdp_axes):            # axis already carried by the spec
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    first = 1 if stacked else 0
    free = [(d, i) for i, (d, s) in enumerate(zip(shape, parts))
            if i >= first and s is None and d % fsdp_size == 0
            and d >= fsdp_size]
    if not free:
        return spec
    _, idx = max(free)
    parts[idx] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    return P(*parts)


def _sanitize(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """Drop mesh axes from dims they don't evenly divide (e.g. odd vocab
    sizes like minicpm's 122753 can't be sharded 16-way)."""
    if mesh is None:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, s in zip(shape, parts):
        if s is None:
            out.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(s if d % n == 0 else None)
    return P(*out)


_EXPERT_TP_RULES = [
    (r"experts/w_gate$", ("model", None, "TP")),
    (r"experts/w_up$", ("model", None, "TP")),
    (r"experts/w_down$", ("model", "TP", None)),
]


def param_specs(params, stacked_prefixes=("layers", "enc_layers", "dec_layers"),
                fsdp_axes=(), fsdp_size: int = 1, mesh: Optional[Mesh] = None,
                expert_tp_axes=()):
    """PartitionSpec pytree matching ``params``. Leaves under a stacked
    prefix are treated as having a leading layer dim. ``fsdp_axes``: also
    shard weights over these batch axes (ZeRO-3 storage). ``mesh``: when
    given, axes are dropped from dims they don't evenly divide.
    ``expert_tp_axes``: resident 2D expert layout (EP x f-TP, for decode)."""
    flat, treedef = _flatten_with_paths(params)
    specs = []
    for path, leaf in flat:
        stacked = any(path.startswith(p + "/") or ("/" + p + "/") in path
                      for p in stacked_prefixes)
        spec = spec_for_path(path, np.ndim(leaf), stacked)
        if expert_tp_axes:
            for pat, tpl in _EXPERT_TP_RULES:
                if re.search(pat, path):
                    body = tuple(expert_tp_axes if s == "TP" else s
                                 for s in tpl)
                    spec = P(*(((None,) if stacked else ()) + body))
                    break
        spec = _sanitize(spec, np.shape(leaf), mesh)
        spec = _add_fsdp(spec, np.shape(leaf), tuple(fsdp_axes), fsdp_size,
                         stacked)
        spec = _sanitize(spec, np.shape(leaf), mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def make_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh):
    """Mesh axis names that shard the batch dimension."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))


def act_spec(mesh: Mesh, *, seq_over_model: bool = False) -> P:
    """Activation spec for (B, S, d) tensors."""
    b = batch_axes(mesh)
    return P(b, "model" if seq_over_model else None, None)
