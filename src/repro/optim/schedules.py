"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM arXiv:2404.06395)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac=0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, total: int, decay_frac=0.1,
                 min_frac=0.01):
    """Warmup -> Stable (constant) -> Decay (last decay_frac of training)."""
    decay_start = int(total * (1 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
        dec = base_lr * (min_frac ** t)          # exponential anneal
        stable = jnp.asarray(base_lr, jnp.float32)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < decay_start, stable, dec))
        return out
    return lr
