"""AdamW with decoupled weight decay and global-norm clipping (pure JAX)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(params, grads, state: AdamWState, lr,
                 *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 max_grad_norm=1.0):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        # decay only matrices (ndim >= 2), standard practice
        wd = weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), gnorm
