"""Griffin / RecurrentGemma [arXiv:2402.19427] — RG-LRU recurrent blocks
interleaved with local (sliding-window) attention at a 1:2 ratio.

Recurrent block:  x -> (gate branch: linear+gelu) * (main branch:
linear -> temporal conv1d(4) -> RG-LRU) -> out projection.

RG-LRU:  r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
         a_t = exp(c * softplus(Lambda) * (-r_t))         (c = 8)
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The scan over time uses ``jax.lax.associative_scan`` on (a, b) pairs —
the TPU-native parallel-prefix adaptation of the paper's linear-scan CUDA
kernel (log-depth, MXU/VPU friendly) — with an explicit carried state for
streaming decode.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense, init_dense, truncated_normal_init

CONV_WIDTH = 4
RGLRU_C = 8.0


def init_recurrent_block(key, cfg: ModelConfig):
    d, dr = cfg.d_model, cfg.rnn_width or cfg.d_model
    keys = jax.random.split(key, 6)
    return {
        "w_gate": init_dense(keys[0], d, dr),
        "w_main": init_dense(keys[1], d, dr),
        "conv_w": truncated_normal_init(keys[2], (CONV_WIDTH, dr), 0.1),
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "w_a": init_dense(keys[3], dr, dr),
        "w_x": init_dense(keys[4], dr, dr),
        "lam": truncated_normal_init(jax.random.fold_in(key, 9), (dr,), 0.5) + 4.0,
        "w_out": init_dense(keys[5], dr, d),
    }


def _causal_conv(params, x, conv_state):
    """Depthwise causal conv1d(width=4). x: (B,S,dr); conv_state: (B,W-1,dr)."""
    w = params["conv_w"].astype(x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    out = sum(xp[:, i:i + S, :] * w[i] for i in range(CONV_WIDTH))
    new_state = xp[:, -(CONV_WIDTH - 1):, :]
    return out + params["conv_b"].astype(x.dtype), new_state


def rg_lru(params, x, h0):
    """x: (B,S,dr); h0: (B,dr) float32. Returns (y, h_last)."""
    f32 = jnp.float32
    r = jax.nn.sigmoid(dense(params["w_a"], x).astype(f32))
    i = jax.nn.sigmoid(dense(params["w_x"], x).astype(f32))
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"].astype(f32)) * r  # (B,S,dr)
    a = jnp.exp(log_a)
    gated = i * x.astype(f32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    # h_t = a_t h_{t-1} + b_t  via associative scan on (a, b):
    #   (a2, b2) . (a1, b1) = (a1*a2, a2*b1 + b2)
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    # fold initial state into the first step
    b = b.at[:, 0].add(a[:, 0] * h0.astype(f32))
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(params, x, h0):
    """Single-token step. x: (B,1,dr); h0: (B,dr)."""
    f32 = jnp.float32
    r = jax.nn.sigmoid(dense(params["w_a"], x).astype(f32))[:, 0]
    i = jax.nn.sigmoid(dense(params["w_x"], x).astype(f32))[:, 0]
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"].astype(f32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * x[:, 0].astype(f32))
    h = a * h0.astype(f32) + b
    return h[:, None].astype(x.dtype), h


def recurrent_block(params, cfg: ModelConfig, x, state) -> Tuple[jnp.ndarray, dict]:
    """state: {"h": (B,dr) f32, "conv": (B,W-1,dr)}."""
    gate = jax.nn.gelu(dense(params["w_gate"], x))
    main = dense(params["w_main"], x)
    main, new_conv = _causal_conv(params, main, state["conv"])
    if x.shape[1] == 1:
        y, new_h = rg_lru_step(params, main, state["h"])
    else:
        y, new_h = rg_lru(params, main, state["h"])
    out = dense(params["w_out"], y * gate)
    return out, {"h": new_h, "conv": new_conv}


def init_recurrent_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    dr = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, dr), dtype),
    }
