"""Core neural building blocks (pure-functional: init_* -> params dict,
apply functions take params explicitly).

Conventions
-----------
* params are nested dicts of jnp arrays; leaves are float32 at init and
  cast to the compute dtype inside apply (weights stay in param dtype,
  activations in ``cfg`` compute dtype — callers pass already-cast params
  when running bf16).
* all apply fns are shape-polymorphic over leading batch dims.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dtype)


def nonparametric_layernorm(x, eps: float = 1e-5):
    """OLMo-style LN without learnable affine parameters [arXiv:2402.00838]."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dtype)


def init_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return init_rmsnorm(d)
    if kind == "nonparametric":
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, params, x):
    if kind == "rmsnorm":
        return rmsnorm(params, x)
    if kind == "nonparametric":
        return nonparametric_layernorm(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, bias: bool = False, scale: float = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": truncated_normal_init(key, (d_in, d_out), scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(params, x):
    y = jnp.einsum("...i,io->...o", x, params["w"].astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def init_embedding(key, vocab: int, d: int):
    return {"table": truncated_normal_init(key, (vocab, d), 0.02)}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def unembed(params, x):
    """Tied unembedding from an embedding table."""
    return jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    angles = angles[..., None, :]                                  # (..., seq, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def init_ffn(key, d_model: int, d_ff: int, activation: str):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "w_gate": truncated_normal_init(k1, (d_model, d_ff), 1 / math.sqrt(d_model)),
            "w_up": truncated_normal_init(k2, (d_model, d_ff), 1 / math.sqrt(d_model)),
            "w_down": truncated_normal_init(k3, (d_ff, d_model), 1 / math.sqrt(d_ff)),
        }
    return {
        "w_up": truncated_normal_init(k1, (d_model, d_ff), 1 / math.sqrt(d_model)),
        "w_down": truncated_normal_init(k2, (d_ff, d_model), 1 / math.sqrt(d_ff)),
    }


def ffn(params, x, activation: str):
    if activation == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
        if activation == "gelu":
            h = jax.nn.gelu(h)
        elif activation == "relu":
            h = jax.nn.relu(h)
        elif activation == "relu2":
            h = jnp.square(jax.nn.relu(h))
        else:
            raise ValueError(activation)
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))
