"""Attention: GQA (optional bias, RoPE, full / sliding-window) and
DeepSeek-style MLA (multi-head latent attention, compressed KV cache).

Prefill/train uses a chunked online-softmax (flash-style) implementation in
pure JAX (``lax.scan`` over KV blocks) so the S x S score matrix is never
materialised — required for the 32k-prefill shapes to fit HBM.
Decode (Sq == 1) attends directly over the cache.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense, init_dense

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked online-softmax attention core
# ---------------------------------------------------------------------------

def _block_mask(q_pos, k_pos, causal: bool, window: int):
    """(Sq_blk, Skv_blk) boolean mask. window==0 -> full causal."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      kv_valid_len=None, q_block=512, kv_block=512):
    """Flash-style attention without materialising (Sq, Skv) for full seqs.

    q: (B, Sq, H, hd); k, v: (B, Skv, K, hd) with H % K == 0 (GQA).
    q_offset: absolute position of q[0] (for decode / continued prefill).
    kv_valid_len: optional scalar — keys at positions >= this are masked.
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    scale = 1.0 / math.sqrt(hd)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    # pad to block multiples
    pq = (-Sq) % q_block
    pkv = (-Skv) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    Sq_p, Skv_p = Sq + pq, Skv + pkv
    nq, nkv = Sq_p // q_block, Skv_p // kv_block

    # reshape to blocks; put head grouping explicit for GQA
    qb = q.reshape(B, nq, q_block, K, G, hd)
    kb = k.reshape(B, nkv, kv_block, K, hd)
    vb = v.reshape(B, nkv, kv_block, K, hd)

    valid = Skv if kv_valid_len is None else kv_valid_len

    def per_q_block(qi, q_blk):
        # q_blk: (B, q_block, K, G, hd)
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)

        def kv_step(carry, inputs):
            m_run, l_run, acc = carry
            ki, k_blk, v_blk = inputs
            k_pos = ki * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqkgh,bckh->bkgqc", q_blk, k_blk) * scale
            mask = _block_mask(q_pos, k_pos, causal, window)
            mask &= (k_pos < valid)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckh->bkgqh", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, K, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_block, hd), jnp.float32)
        ks = jnp.arange(nkv)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks, jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = (acc / jnp.maximum(l_f, 1e-20)[..., None]).astype(q.dtype)
        # (B, K, G, q_block, hd) -> (B, q_block, K, G, hd)
        return jnp.transpose(out, (0, 3, 1, 2, 4))

    outs = jax.lax.map(lambda args: per_q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq_p, H, hd)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, *, cache_len, window=0):
    """Single-token attention over a cache. q: (B, 1, H, hd);
    k_cache/v_cache: (B, S_max, K, hd); cache_len: current length (incl. new
    token) — a scalar, or a (B,) vector for continuous batching where every
    slot sits at its own position."""
    B, _, H, hd = q.shape
    _, S_max, K, _ = k_cache.shape
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache) * scale
    pos = jnp.arange(S_max)
    cl = jnp.reshape(jnp.asarray(cache_len, jnp.int32), (-1, 1))  # (1|B, 1)
    mask = pos[None, :] < cl                                      # (1|B, S)
    if window > 0:
        mask = mask & (pos[None, :] >= cl - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    # keep the probs in f32 for the PV product (matches the paged fused
    # kernel's f32 accumulator, so linear and paged decode agree to
    # summation-order noise instead of bf16-cast noise)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype).reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, d_model=None, num_heads=None, num_kv=None):
    d = d_model or cfg.d_model
    H = num_heads or cfg.num_heads
    K = num_kv or cfg.num_kv_heads
    hd = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_dense(k1, d, H * hd, bias=cfg.qkv_bias),
        "wk": init_dense(k2, d, K * hd, bias=cfg.qkv_bias),
        "wv": init_dense(k3, d, K * hd, bias=cfg.qkv_bias),
        "wo": init_dense(k4, H * hd, d),
    }


def gqa_project(params, cfg: ModelConfig, x, positions, num_heads=None, num_kv=None):
    B, S, _ = x.shape
    H = num_heads or cfg.num_heads
    K = num_kv or cfg.num_kv_heads
    hd = cfg.head_dim
    q = dense(params["wq"], x).reshape(B, S, H, hd)
    k = dense(params["wk"], x).reshape(B, S, K, hd)
    v = dense(params["wv"], x).reshape(B, S, K, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(params, cfg: ModelConfig, x, positions, *, window=0,
                  num_heads=None, num_kv=None):
    """Train/prefill self-attention (causal)."""
    q, k, v = gqa_project(params, cfg, x, positions, num_heads, num_kv)
    out = chunked_attention(q, k, v, causal=True, window=window)
    B, S = x.shape[:2]
    return dense(params["wo"], out.reshape(B, S, -1))


def gqa_prefill(params, cfg: ModelConfig, x, positions, cache, *, window=0):
    """Prefill: run attention AND write k/v into the cache (from position 0)."""
    q, k, v = gqa_project(params, cfg, x, positions)
    out = chunked_attention(q, k, v, causal=True, window=window)
    B, S = x.shape[:2]
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    return dense(params["wo"], out.reshape(B, S, -1)), cache


def gqa_decode(params, cfg: ModelConfig, x, cache, cache_len, *, window=0):
    """Decode one token. x: (B, 1, d). cache_len: length BEFORE this token."""
    B = x.shape[0]
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    q, k, v = gqa_project(params, cfg, x, positions)
    cache = dict(cache)
    # write new kv at cache_len
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
    out = decode_attention(q, cache["k"], cache["v"],
                           cache_len=cache_len + 1, window=window)
    return dense(params["wo"], out.reshape(B, 1, -1)), cache


def gqa_decode_multi(params, cfg: ModelConfig, x, cache, lengths, *, window=0):
    """Continuous-batching decode over a slotted linear cache.

    Every slot decodes at its OWN position: x: (B, 1, d); cache k/v:
    (B, S_max, K, hd); lengths: (B,) int32 current length per slot (the new
    token is written at ``lengths[b]``). Inactive slots decode garbage that
    the caller masks out; their cache writes land at their own (stale)
    position and are overwritten when the slot is re-prefilled.
    """
    B = x.shape[0]
    positions = jnp.asarray(lengths, jnp.int32)[:, None]          # (B, 1)
    q, k, v = gqa_project(params, cfg, x, positions)
    cache = dict(cache)
    b_idx = jnp.arange(B)
    cache["k"] = cache["k"].at[b_idx, positions[:, 0]].set(
        k[:, 0].astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[b_idx, positions[:, 0]].set(
        v[:, 0].astype(cache["v"].dtype))
    out = decode_attention(q, cache["k"], cache["v"],
                           cache_len=lengths + 1, window=window)
    return dense(params["wo"], out.reshape(B, 1, -1)), cache


def gqa_decode_paged(params, cfg: ModelConfig, x, pool, block_tables, lengths,
                     *, window: int = 0):
    """Continuous-batching decode over a paged KV block pool.

    pool k/v: (N_blocks, block, K, hd) — one shared fixed-shape pool, so
    jit never recompiles as requests join/leave. block_tables: (B, M) int32
    maps each slot's logical block m to a physical block (entries beyond a
    slot's allocation point at the reserved null block 0 and are masked by
    ``lengths``). lengths: (B,) — the new token is written at logical
    position ``lengths[b]``, whose physical block MUST already be allocated
    (the scheduler grows tables before calling); ``lengths[b] == 0`` marks
    a released/idle slot whose KV write is suppressed so dead slots never
    dirty the null block. ``window``: architectural sliding window, applied
    as a mask (blocks stay allocated — the pool is linear in logical
    positions; correctness first, reclaim later).

    ``cfg.paged_attn_impl`` selects the attention path: "fused" runs the
    Pallas kernel straight off the pool (no gathered intermediate);
    "gather" materializes the logical view and runs the identical blockwise
    online-softmax in pure jnp (fp32 bit-exact oracle).
    """
    from repro.kernels import ops as _kernel_ops
    from repro.kernels import ref as _kernel_ref

    B = x.shape[0]
    N, bs, K, hd = pool["k"].shape
    lengths = jnp.asarray(lengths, jnp.int32)
    positions = lengths[:, None]                                  # (B, 1)
    q, k, v = gqa_project(params, cfg, x, positions)
    b_idx = jnp.arange(B)
    blk = block_tables[b_idx, positions[:, 0] // bs]              # (B,)
    off = positions[:, 0] % bs                                    # (B,)
    # slots own disjoint blocks, so cross-slot collisions only happen on the
    # null block; inactive slots (lengths == 0 after release) keep the old
    # value — their table rows all point at the null block, which must stay
    # clean for every other slot's masked reads
    active = (lengths > 0)[:, None, None]                         # (B, 1, 1)
    k_pool = pool["k"].at[blk, off].set(
        jnp.where(active, k[:, 0].astype(pool["k"].dtype),
                  pool["k"][blk, off]))
    v_pool = pool["v"].at[blk, off].set(
        jnp.where(active, v[:, 0].astype(pool["v"].dtype),
                  pool["v"][blk, off]))
    G = q.shape[2] // K
    qg = q.reshape(B, K, G, hd)
    impl = getattr(cfg, "paged_attn_impl", "fused")
    if impl == "fused":
        out = _kernel_ops.paged_decode_attention(
            qg, k_pool, v_pool, block_tables, lengths, window=window)
    else:
        # gather each slot's view: (B, M, bs, K, hd) -> (B, M*bs, K, hd)
        k_view = k_pool[block_tables].reshape(B, -1, K, hd)
        v_view = v_pool[block_tables].reshape(B, -1, K, hd)
        out = _kernel_ref.paged_decode_ref(qg, k_view, v_view, lengths,
                                           window=window, block_size=bs)
    pool = {**pool, "k": k_pool, "v": v_pool}
    return dense(params["wo"], out.reshape(B, 1, -1)), pool


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
                   num_kv=None):
    K = num_kv or cfg.num_kv_heads
    shape = (batch, max_len, K, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# Rotating-window caches (sliding-window archs: cache buffer == window size,
# slot = absolute_position % window; RoPE is applied at absolute positions at
# write time so relative attention is preserved regardless of slot order).
# ---------------------------------------------------------------------------

def gqa_prefill_windowed(params, cfg: ModelConfig, x, positions, cache, *,
                         window: int):
    """Prefill with a rotating window cache (buffer length == window)."""
    W = cache["k"].shape[1]
    if W > window:
        return gqa_prefill(params, cfg, x, positions, cache, window=window)
    q, k, v = gqa_project(params, cfg, x, positions)
    out = chunked_attention(q, k, v, causal=True, window=window)
    B, S = x.shape[:2]
    n = min(S, W)
    tail_pos = np.arange(S - n, S)
    slots = tail_pos % W
    cache = dict(cache)
    cache["k"] = cache["k"].at[:, slots].set(k[:, tail_pos].astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[:, slots].set(v[:, tail_pos].astype(cache["v"].dtype))
    return dense(params["wo"], out.reshape(B, S, -1)), cache


def gqa_decode_windowed(params, cfg: ModelConfig, x, cache, cache_len, *,
                        window: int = 0):
    """Decode against either a linear cache (window == 0 or full-length
    buffer) or a rotating window buffer."""
    W = cache["k"].shape[1]
    if window == 0 or W > window:
        return gqa_decode(params, cfg, x, cache, cache_len, window=window)
    B = x.shape[0]
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    q, k, v = gqa_project(params, cfg, x, positions)
    slot = cache_len % W
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    valid = jnp.minimum(cache_len + 1, W)          # buffer only holds window
    out = decode_attention(q, cache["k"], cache["v"], cache_len=valid, window=0)
    return dense(params["wo"], out.reshape(B, 1, -1)), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — compressed-latent KV cache
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    keys = jax.random.split(key, 6)
    p = {
        "w_dkv": init_dense(keys[0], d, m.kv_lora_rank),          # KV down-proj
        "w_krope": init_dense(keys[1], d, m.rope_head_dim),       # shared rope key
        "w_uk": init_dense(keys[2], m.kv_lora_rank, H * m.nope_head_dim),
        "w_uv": init_dense(keys[3], m.kv_lora_rank, H * m.v_head_dim),
        "w_q": init_dense(keys[4], d, H * (m.nope_head_dim + m.rope_head_dim)),
        "wo": init_dense(keys[5], H * m.v_head_dim, d),
    }
    return p


def _mla_qkv(params, cfg, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q = dense(params["w_q"], x).reshape(B, S, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = dense(params["w_dkv"], x)                               # (B,S,r)
    k_rope = dense(params["w_krope"], x).reshape(B, S, 1, m.rope_head_dim)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand(params, cfg, c_kv):
    m = cfg.mla
    B, S, _ = c_kv.shape
    H = cfg.num_heads
    k_nope = dense(params["w_uk"], c_kv).reshape(B, S, H, m.nope_head_dim)
    v = dense(params["w_uv"], c_kv).reshape(B, S, H, m.v_head_dim)
    return k_nope, v


def mla_attention(params, cfg: ModelConfig, x, positions, *, window=0):
    """Train/prefill MLA. Concatenated (nope‖rope) q/k fed to the shared
    chunked-attention core; the rope key is broadcast across heads."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    k_nope, v = _mla_expand(params, cfg, c_kv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, H, m.rope_head_dim))], axis=-1)
    # pad v to match head_dim for the shared core? core allows hd_v != hd_qk?
    # chunked_attention assumes same hd for q/k and v shape (..., hd): we pass
    # v with its own dim by calling the core with matching K=H (no GQA here).
    out = chunked_attention(q, k, _pad_like(v, q.shape[-1]),
                            causal=True, window=window)[..., :m.v_head_dim]
    return dense(params["wo"], out.reshape(B, S, H * m.v_head_dim))


def _pad_like(v, hd):
    if v.shape[-1] == hd:
        return v
    return jnp.pad(v, ((0, 0),) * (v.ndim - 1) + ((0, hd - v.shape[-1]),))


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
    }


def mla_prefill(params, cfg: ModelConfig, x, positions, cache, *, window=0):
    out = mla_attention(params, cfg, x, positions, window=window)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    cache = dict(cache)
    cache["c_kv"] = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype), (0, 0, 0))
    return out, cache


def mla_decode(params, cfg: ModelConfig, x, cache, cache_len, *, window=0):
    """Decode with the compressed cache, expanding K/V on the fly."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    positions = jnp.full((B, 1), cache_len, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(params, cfg, x, positions)
    cache = dict(cache)
    cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), cache_len, axis=1)
    cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new[:, :, 0].astype(cache["k_rope"].dtype),
        cache_len, axis=1)
    S_max = cache["c_kv"].shape[1]
    k_nope, v = _mla_expand(params, cfg, cache["c_kv"].astype(x.dtype))
    k_rope_all = jnp.broadcast_to(cache["k_rope"][:, :, None, :].astype(x.dtype),
                                  (B, S_max, H, m.rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_all], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1).reshape(B, 1, H, -1)
    out = decode_attention(q, k, _pad_like(v, q.shape[-1]),
                           cache_len=cache_len + 1, window=window)
    out = out[..., :m.v_head_dim]
    return dense(params["wo"], out.reshape(B, 1, H * m.v_head_dim)), cache
