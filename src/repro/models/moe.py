"""MoE FFN block: router + (shared experts | dense residual) + routed experts.

Two execution paths share one parameter layout:

* ``moe_ffn_dense`` — reference path: every expert computed on every token,
  combined by gates. Exact (no capacity drops); used on single-device smoke
  tests and as the oracle for the distributed path and the Pallas kernel.
* ``moe_ffn_ep`` — the production expert-parallel path via
  ``repro.moe.dispatch.ep_moe_ffn`` (called inside shard_map).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ffn, init_ffn, truncated_normal_init
from repro.moe.router import RouterOutput, init_router, route


def init_moe_block(key, cfg: ModelConfig):
    moe = cfg.moe
    d = cfg.d_model
    keys = jax.random.split(key, 4)
    import math
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(moe.d_ff_expert)
    E = moe.num_experts

    def ew(k, shape, scale):
        return truncated_normal_init(k, shape, scale)

    ks = jax.random.split(keys[0], 3)
    params = {
        "router": init_router(keys[1], d, moe),
        "experts": {
            "w_gate": ew(ks[0], (E, d, moe.d_ff_expert), scale_in),
            "w_up": ew(ks[1], (E, d, moe.d_ff_expert), scale_in),
            "w_down": ew(ks[2], (E, moe.d_ff_expert, d), scale_out),
        },
    }
    if moe.num_shared_experts > 0:
        params["shared"] = init_ffn(
            keys[2], d, moe.d_ff_expert * moe.num_shared_experts, cfg.activation)
    if moe.dense_residual:
        params["dense"] = init_ffn(
            keys[3], d, moe.d_ff_dense or cfg.d_ff, cfg.activation)
    return params


def routed_dense(params_experts, router_out: RouterOutput, x, activation: str):
    """Reference routed computation: all experts on all tokens. x: (T, d)."""
    we = params_experts
    if activation == "swiglu":
        g = jnp.einsum("td,edf->etf", x, we["w_gate"].astype(x.dtype))
        u = jnp.einsum("td,edf->etf", x, we["w_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("td,edf->etf", x, we["w_up"].astype(x.dtype))
        h = jax.nn.gelu(h) if activation == "gelu" else jax.nn.relu(h)
    y_all = jnp.einsum("etf,efd->etd", h, we["w_down"].astype(x.dtype))  # (E,T,d)
    E = we["w_gate"].shape[0]
    # combine: sum_k gate_k * y_all[idx_k]
    gates_full = jnp.zeros((x.shape[0], E), x.dtype)
    gates_full = gates_full.at[
        jnp.arange(x.shape[0])[:, None], router_out.expert_idx
    ].add(router_out.gates.astype(x.dtype))
    return jnp.einsum("te,etd->td", gates_full, y_all)


def moe_ffn_dense(params, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, RouterOutput]:
    """Single-device exact MoE FFN. x: (..., d) -> same shape."""
    moe = cfg.moe
    shape = x.shape
    xt = x.reshape(-1, shape[-1])
    router_out = route(params["router"], moe, xt)
    y = routed_dense(params["experts"], router_out, xt, cfg.activation)
    if "shared" in params:
        y = y + ffn(params["shared"], xt, cfg.activation)
    if "dense" in params:
        y = y + ffn(params["dense"], xt, cfg.activation)
    return y.reshape(shape), router_out
