"""Model assembly for every assigned architecture family.

Uniform-stack families (dense / moe / vlm / ssm / audio-encoder+decoder)
scan over stacked layer params so trace/compile time is depth-independent.
The hybrid family (recurrentgemma) has heterogeneous blocks and unrolls a
python loop over its (short) layer stack.

Execution modes:
  train    — full causal pass, logits over the whole sequence, no cache.
  prefill  — causal pass that also fills the cache; returns last-position logits.
  decode   — one token against the cache (the ``serve_step`` of the assignment).

MoE layers run one of three paths, selected by ``Runtime``:
  dense (reference, single device), EP shard_map all_to_all dispatch
  (train/prefill; placement-aware duplication), or EP replicated-token
  dispatch (decode, tokens replicated over the model axis, psum combine).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                      # jax >= 0.5 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                       # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect
_SHARD_MAP_PARAMS = _inspect.signature(_shard_map).parameters


def shard_map(f, **kw):
    """Version-portable shard_map: new jax names the replication-check knob
    ``check_vma``; 0.4.x called it ``check_rep``."""
    if "check_vma" in kw and "check_vma" not in _SHARD_MAP_PARAMS:
        kw["check_rep"] = kw.pop("check_vma")
    return _shard_map(f, **kw)

from repro.configs.base import ModelConfig
from repro.core.placement import PlacementPlan, identity_plan
from repro.models import attention as attn
from repro.models import griffin, rwkv6
from repro.models.layers import (apply_norm, dense, embed, ffn, init_dense,
                                 init_embedding, init_ffn, init_norm, unembed)
from repro.models.moe import init_moe_block, moe_ffn_dense
from repro.moe import dispatch as ep
from repro.moe.router import route


class Runtime(NamedTuple):
    """Execution-context knobs (static except plan/predicted)."""
    mesh: Optional[Mesh] = None
    ep: bool = False                     # expert-parallel shard_map dispatch
    ep_axis: str = "model"
    ep_ranks: int = 1
    use_duplication: bool = False
    plan: Optional[PlacementPlan] = None          # stacked (L, ...) plan arrays
    predicted_idx: Optional[jnp.ndarray] = None   # (L, T, K) token-to-expert preds
    use_kernel: bool = False
    window_override: int = 0             # force sliding window (long-context decode)
    decode_expert_tp: bool = False       # 2D expert sharding (EP x f-TP) for decode

    def window(self, cfg: ModelConfig) -> int:
        return self.window_override or cfg.sliding_window


def _batch_axes(mesh):
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def constrain_acts(x, rt: "Runtime", seq_shard: bool = False):
    """Pin (B, S, d) activations to batch-sharded/replicated-d layout.

    Without an explicit constraint GSPMD is free to replicate activations
    across the batch axes inside the layer scan — measured as an
    8.6 GB/layer all-gather on qwen train_4k (EXPERIMENTS.md §Perf #2).

    ``seq_shard``: additionally shard the sequence dim over "model"
    (sequence parallelism). Used for MoE archs in train/prefill, whose EP
    dispatch shard_map consumes seq-sharded tokens — a batch-only
    constraint would force a full-activation reshard each layer (measured
    as a 6.6 -> 10.1s collective REGRESSION on arctic, §Perf sweep).
    """
    if rt.mesh is None or x.ndim != 3:
        return x
    b = _batch_axes(rt.mesh)
    if not b:
        return x
    n_b = 1
    for a in b:
        n_b *= rt.mesh.shape[a]
    if x.shape[0] % n_b != 0:
        return x
    seq = None
    if seq_shard and x.shape[1] % rt.mesh.shape["model"] == 0:
        seq = "model"
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rt.mesh, P(b, seq, None)))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: str):
    keys = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": init_norm(cfg.norm, cfg.d_model),
                         "ln2": init_norm(cfg.norm, cfg.d_model)}
    if kind in ("attn", "encoder", "decoder"):
        if cfg.attention == "mla":
            p["attn"] = attn.init_mla(keys[0], cfg)
        else:
            p["attn"] = attn.init_gqa(keys[0], cfg)
        if kind == "decoder":
            p["cross"] = attn.init_gqa(keys[1], cfg)
            p["ln_cross"] = init_norm(cfg.norm, cfg.d_model)
        if cfg.is_moe:
            p["moe"] = init_moe_block(keys[2], cfg)
        else:
            p["ffn"] = init_ffn(keys[2], cfg.d_model, cfg.d_ff, cfg.activation)
    elif kind == "rwkv":
        p["time_mix"] = rwkv6.init_time_mix(keys[0], cfg)
        p["channel_mix"] = rwkv6.init_channel_mix(keys[2], cfg)
    elif kind == "recurrent":
        p["rec"] = griffin.init_recurrent_block(keys[0], cfg)
        p["ffn"] = init_ffn(keys[2], cfg.d_model, cfg.d_ff, cfg.activation)
    elif kind == "local":
        p["attn"] = attn.init_gqa(keys[0], cfg)
        p["ffn"] = init_ffn(keys[2], cfg.d_model, cfg.d_ff, cfg.activation)
    else:
        raise ValueError(kind)
    return p


def _layer_kind(cfg: ModelConfig, layer_idx: int) -> str:
    if cfg.family == "ssm":
        return "rwkv"
    if cfg.family == "hybrid":
        return cfg.block_pattern[layer_idx % len(cfg.block_pattern)]
    return "attn"


def init_model(key, cfg: ModelConfig):
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": init_embedding(keys[0], cfg.vocab_size,
                                                      cfg.d_model)}
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[1], cfg.d_model, cfg.vocab_size)

    if cfg.family == "hybrid":
        layer_keys = jax.random.split(keys[2], cfg.num_layers)
        params["hybrid_layers"] = [
            _init_layer(layer_keys[i], cfg, _layer_kind(cfg, i))
            for i in range(cfg.num_layers)]
    else:
        kind = _layer_kind(cfg, 0)
        layer_keys = jax.random.split(keys[2], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, kind))(layer_keys)

    if cfg.is_encdec:
        enc = cfg.encoder
        import dataclasses
        enc_cfg = dataclasses.replace(
            cfg, num_layers=enc.num_layers, d_model=enc.d_model,
            num_heads=enc.num_heads, num_kv_heads=enc.num_kv_heads,
            d_ff=enc.d_ff, moe=None, encoder=None, attention="gqa",
            head_dim=enc.d_model // enc.num_heads)
        ekeys = jax.random.split(keys[3], enc.num_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_layer(k, enc_cfg, "encoder"))(ekeys)
        params["enc_norm"] = init_norm(cfg.norm, enc.d_model)
        # decoder layers get cross-attention
        dkeys = jax.random.split(keys[2], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, "decoder"))(dkeys)
    return params


# ---------------------------------------------------------------------------
# caches / states
# ---------------------------------------------------------------------------

def cache_len_for(cfg: ModelConfig, rt: Runtime, max_len: int) -> int:
    w = rt.window(cfg)
    return min(max_len, w) if w else max_len


def init_cache(cfg: ModelConfig, rt: Runtime, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Stacked (over layers) cache pytree for prefill/decode."""
    L = cfg.num_layers
    clen = cache_len_for(cfg, rt, max_len)

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), tree)

    if cfg.family == "ssm":
        return stack(rwkv6.init_rwkv_state(cfg, batch))
    if cfg.family == "hybrid":
        caches = []
        for i in range(L):
            kind = _layer_kind(cfg, i)
            if kind == "recurrent":
                caches.append(griffin.init_recurrent_state(cfg, batch, dtype))
            else:
                caches.append(attn.init_gqa_cache(
                    cfg, batch, min(max_len, cfg.local_window), dtype))
        return caches
    if cfg.attention == "mla":
        return stack(attn.init_mla_cache(cfg, batch, clen, dtype))
    c = stack(attn.init_gqa_cache(cfg, batch, clen, dtype))
    if cfg.is_encdec:
        enc = cfg.encoder
        c = {"self": c,
             "cross_k": jnp.zeros((L, batch, enc.max_source_len,
                                   cfg.num_kv_heads, cfg.head_dim), dtype),
             "cross_v": jnp.zeros((L, batch, enc.max_source_len,
                                   cfg.num_kv_heads, cfg.head_dim), dtype)}
    return c


# ---------------------------------------------------------------------------
# MoE layer execution paths
# ---------------------------------------------------------------------------

def _moe_apply(layer_p, cfg: ModelConfig, x, rt: Runtime, plan_l,
               predicted_l, decode: bool, token_weight=None,
               slot_w_l=None, resched_l=None):
    """x: (B, S, d). Returns (y, expert_counts (E,), slot_counts, aux, z,
    dropped, overflow).

    ``resched_l``: optional (E, C_max) int32 reschedule quota for this
    layer (``repro.schedule``) — replica choice follows the scheduler's
    per-copy shares and capacity-overflow tokens get a rescue dispatch
    round. Traced, so quota refreshes never recompile.

    ``token_weight``: optional (B, S) per-token weight for the expert
    histogram — the continuous-batching engine passes the active/padding
    mask so estimator inputs only count REAL tokens (padded prefill
    positions and idle decode slots still flow through the FFN but must
    not skew the observed distribution).

    ``slot_w_l``: optional {name: (S_global, ...)} resident slot weights
    for this layer (one ``repro.runtime.ReplicaStore`` layer slice) —
    sharded over the EP axis so dispatch reads replica weights from
    device memory instead of re-gathering a pool every step.
    """
    moe = cfg.moe
    B, S, d = x.shape
    if not rt.ep:
        y, router_out = moe_ffn_dense(layer_p["moe"], cfg, x)
        w = (jnp.ones((B * S * moe.top_k,), jnp.float32)
             if token_weight is None
             else jnp.repeat(token_weight.reshape(-1).astype(jnp.float32),
                             moe.top_k))
        counts = jnp.zeros((moe.num_experts,), jnp.float32).at[
            router_out.expert_idx.reshape(-1)].add(w)
        return (y, counts, counts, router_out.aux_loss, router_out.z_loss,
                jnp.asarray(0, jnp.int32),    # dense path never drops
                jnp.asarray(0, jnp.int32))

    mesh = rt.mesh
    baxes = _batch_axes(mesh)
    # small batches (e.g. long-context decode, B=1) replicate over the
    # batch axes instead of sharding them
    n_b = 1
    for a in baxes:
        n_b *= mesh.shape[a]
    if B % n_b != 0:
        baxes = ()
    plan_l = plan_l if plan_l is not None else identity_plan(
        moe.num_experts, rt.ep_ranks, moe.duplication_slots, moe.max_copies)

    # 2D expert sharding for decode (EXPERIMENTS.md §Perf cycle 2):
    # d_ff additionally shards over the batch axes so weights stay
    # resident (no ZeRO re-gather per token); tokens replicate and one
    # psum over (batch axes + model) combines f-partials + slot results.
    # Works regardless of batch divisibility (tokens replicate anyway),
    # so use the FULL batch axes, not the divisibility-filtered ones.
    tp_axes = _batch_axes(mesh)
    n_tp = 1
    for a in tp_axes:
        n_tp *= mesh.shape[a]
    tp_mode = (decode and rt.decode_expert_tp and bool(tp_axes)
               and moe.d_ff_expert % n_tp == 0)
    if tp_mode:
        slot_w_l = None       # 2D expert sharding keeps the gather path
    expert_specs = P("model", None, None)
    if decode:
        if tp_mode:
            x_spec = P(None, None, None)
            expert_specs = {"w_gate": P("model", None, tp_axes),
                            "w_up": P("model", None, tp_axes),
                            "w_down": P("model", tp_axes, None)}
        else:
            x_spec = P(baxes if baxes else None, None, None)
        from functools import partial as _partial
        dispatch_fn = _partial(ep.ep_moe_ffn_replicated,
                               tp_axis=tp_axes if tp_mode else ())
    else:
        x_spec = P(baxes if baxes else None, "model", None)
        dispatch_fn = ep.ep_moe_ffn

    # kernel runs fuse routing (softmax/top-k/histogram) into one Pallas
    # pass when the sort dispatch pipeline is active
    router_impl = ("fused" if rt.use_kernel and moe.dispatch_impl == "sort"
                   else "dense")

    def inner(x_blk, router_w, experts_w, plan, pred, w_blk, slot_blk, quota):
        t = x_blk.reshape(-1, x_blk.shape[-1])
        router_out = route(router_w, moe, t, impl=router_impl)
        y, stats = dispatch_fn(
            t, router_out, experts_w, plan, moe,
            axis_name=rt.ep_axis, ep_ranks=rt.ep_ranks,
            activation=cfg.activation,
            use_duplication=rt.use_duplication,
            predicted_idx=pred.reshape(-1, moe.top_k) if pred is not None else None,
            use_kernel=rt.use_kernel,
            slot_weights=slot_blk,
            resched_quota=quota)
        counts, slots = stats.expert_counts, stats.slot_counts
        aux, z, dropped = stats.aux_loss, stats.z_loss, stats.dropped
        overflow = stats.overflow
        if w_blk is not None:
            # weighted histogram replaces the dispatch count (padding /
            # idle-slot tokens carry weight 0). Prefill tokens are
            # seq-sharded over the model axis, so re-psum there; decode
            # tokens are replicated over it (counts already global).
            wk = jnp.repeat(w_blk.reshape(-1).astype(jnp.float32), moe.top_k)
            counts = jnp.zeros((moe.num_experts,), jnp.float32).at[
                router_out.expert_idx.reshape(-1)].add(wk)
            if not decode:
                counts = jax.lax.psum(counts, rt.ep_axis)
        if baxes and not tp_mode:
            # stats are psum'd over "model" inside dispatch only; in
            # tp_mode tokens are replicated so stats are already global
            counts = jax.lax.psum(counts, baxes)
            slots = jax.lax.psum(slots, baxes)
            aux = jax.lax.pmean(aux, baxes)
            z = jax.lax.pmean(z, baxes)
            dropped = jax.lax.psum(dropped, baxes)
            overflow = jax.lax.psum(overflow, baxes)
        return y.reshape(x_blk.shape), counts, slots, aux, z, dropped, overflow

    plan_specs = PlacementPlan(P(), P(), P(), P())
    pred_spec = None if predicted_l is None else x_spec
    w_spec = None if token_weight is None else P(*x_spec[:-1])
    slot_spec = None if slot_w_l is None else P("model", None, None)
    resched_spec = None if resched_l is None else P()
    y, counts, slot_counts, aux, z, dropped, overflow = shard_map(
        inner, mesh=mesh,
        in_specs=(x_spec, P(), expert_specs, plan_specs, pred_spec, w_spec,
                  slot_spec, resched_spec),
        out_specs=(x_spec, P(), P(), P(), P(), P(), P()),
        check_vma=False,
    )(x, layer_p["moe"]["router"], layer_p["moe"]["experts"], plan_l,
      predicted_l, token_weight, slot_w_l, resched_l)

    if "shared" in layer_p["moe"]:
        y = y + ffn(layer_p["moe"]["shared"], x, cfg.activation)
    if "dense" in layer_p["moe"]:
        y = y + ffn(layer_p["moe"]["dense"], x, cfg.activation)
    return y, counts, slot_counts, aux, z, dropped, overflow


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _zero_stats(cfg):
    E = cfg.moe.num_experts if cfg.is_moe else 1
    return (jnp.zeros((E,), jnp.float32), jnp.zeros((E,), jnp.float32),
            jnp.asarray(0.0, jnp.float32), jnp.asarray(0.0, jnp.float32),
            jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32))


def _attn_layer(layer_p, cfg, x, positions, rt, *, cache=None, cache_len=None,
                mode="train", enc_out=None, plan_l=None, predicted_l=None,
                block_tables=None, token_weight=None, slot_w_l=None,
                resched_l=None):
    """Generic attention+FFN layer for dense/moe/vlm/audio-decoder."""
    window = rt.window(cfg)
    h = apply_norm(cfg.norm, layer_p["ln1"], x)
    new_cache = cache
    if mode == "train":
        if cfg.attention == "mla":
            a = attn.mla_attention(layer_p["attn"], cfg, h, positions,
                                   window=window)
        else:
            a = attn.gqa_attention(layer_p["attn"], cfg, h, positions,
                                   window=window)
    elif mode == "prefill":
        sub = cache["self"] if cfg.is_encdec else cache
        if cfg.attention == "mla":
            a, sub = attn.mla_prefill(layer_p["attn"], cfg, h, positions, sub,
                                      window=window)
        else:
            a, sub = attn.gqa_prefill(layer_p["attn"], cfg, h, positions, sub,
                                      window=window)
        new_cache = dict(cache, self=sub) if cfg.is_encdec else sub
    else:  # decode
        sub = cache["self"] if cfg.is_encdec else cache
        if block_tables is not None:
            # continuous batching keeps caches linear (window_override =
            # max_len for sizing) but must still MASK to the architectural
            # sliding window, or paged decode diverges from windowed
            # serving. cfg.paged_attn_impl selects the fused Pallas
            # one-pass kernel or the materialize-then-attend gather oracle
            a, sub = attn.gqa_decode_paged(layer_p["attn"], cfg, h, sub,
                                           block_tables, cache_len,
                                           window=cfg.sliding_window)
        elif cfg.attention == "mla":
            a, sub = attn.mla_decode(layer_p["attn"], cfg, h, sub, cache_len,
                                     window=window)
        elif jnp.ndim(cache_len) == 1:
            # continuous batching: per-slot positions over a slotted cache
            a, sub = attn.gqa_decode_multi(layer_p["attn"], cfg, h, sub,
                                           cache_len,
                                           window=cfg.sliding_window)
        else:
            a, sub = attn.gqa_decode_windowed(layer_p["attn"], cfg, h, sub,
                                              cache_len, window=window)
        new_cache = dict(cache, self=sub) if cfg.is_encdec else sub
    x = x + a

    if cfg.is_encdec and "cross" in layer_p:
        h = apply_norm(cfg.norm, layer_p["ln_cross"], x)
        if mode == "decode":
            ck, cv = new_cache["cross_k"], new_cache["cross_v"]
            B = x.shape[0]
            q = dense(layer_p["cross"]["wq"], h).reshape(
                B, 1, cfg.num_heads, cfg.head_dim)
            c = attn.decode_attention(q, ck, cv, cache_len=ck.shape[1])
            c = dense(layer_p["cross"]["wo"], c.reshape(B, 1, -1))
        else:
            c, ck, cv = cross_attention(layer_p["cross"], cfg, h, enc_out)
            if mode == "prefill":
                new_cache = dict(new_cache, cross_k=ck, cross_v=cv)
        x = x + c

    h = apply_norm(cfg.norm, layer_p["ln2"], x)
    if cfg.is_moe:
        y, counts, slots, aux, z, dropped, overflow = _moe_apply(
            layer_p, cfg, h, rt, plan_l, predicted_l,
            decode=(mode == "decode"), token_weight=token_weight,
            slot_w_l=slot_w_l, resched_l=resched_l)
        stats = (counts, slots, aux, z, dropped, overflow)
    else:
        y = ffn(layer_p["ffn"], h, cfg.activation)
        stats = _zero_stats(cfg)
    return x + y, new_cache, stats


def cross_attention(params, cfg: ModelConfig, x, enc_out):
    """Full (non-causal) cross attention. Returns (out, k, v) for caching."""
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    q = dense(params["wq"], x).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = dense(params["wk"], enc_out).reshape(B, Se, cfg.num_kv_heads, cfg.head_dim)
    v = dense(params["wv"], enc_out).reshape(B, Se, cfg.num_kv_heads, cfg.head_dim)
    out = attn.chunked_attention(q, k, v, causal=False)
    return dense(params["wo"], out.reshape(B, S, -1)), k, v


def _rwkv_layer(layer_p, cfg, x, state):
    h = apply_norm(cfg.norm, layer_p["ln1"], x)
    a, new_tm = rwkv6.time_mix(layer_p["time_mix"], cfg, h,
                               {"shift_tm": state["shift_tm"],
                                "wkv": state["wkv"]})
    x = x + a
    h = apply_norm(cfg.norm, layer_p["ln2"], x)
    y, new_shift_cm = rwkv6.channel_mix(layer_p["channel_mix"], h,
                                        state["shift_cm"])
    new_state = {"shift_tm": new_tm["shift_tm"], "wkv": new_tm["wkv"],
                 "shift_cm": new_shift_cm}
    return x + y, new_state


def _hybrid_layer(layer_p, cfg, x, positions, kind, state, rt, mode, cache_len):
    h = apply_norm(cfg.norm, layer_p["ln1"], x)
    if kind == "recurrent":
        a, new_state = griffin.recurrent_block(layer_p["rec"], cfg, h, state)
    else:  # local attention
        if mode == "train":
            a = attn.gqa_attention(layer_p["attn"], cfg, h, positions,
                                   window=cfg.local_window)
            new_state = state
        elif mode == "prefill":
            a, new_state = attn.gqa_prefill_windowed(
                layer_p["attn"], cfg, h, positions, state,
                window=cfg.local_window)
        else:
            a, new_state = attn.gqa_decode_windowed(
                layer_p["attn"], cfg, h, state, cache_len,
                window=cfg.local_window)
    x = x + a
    h = apply_norm(cfg.norm, layer_p["ln2"], x)
    return x + ffn(layer_p["ffn"], h, cfg.activation), new_state


# ---------------------------------------------------------------------------
# full model forward
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch):
    """tokens (+ optional prefix embeddings) -> (B, S, d), positions."""
    tok = embed(params["embed"], batch["tokens"])
    if cfg.input_mode == "mixed" and "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(tok.dtype), tok],
                            axis=1)
    else:
        x = tok
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x.astype(jnp.bfloat16), positions


def _encode(params, cfg: ModelConfig, frames, rt: Runtime):
    """Audio encoder: bidirectional transformer over stub frame embeddings."""
    enc = cfg.encoder
    x = frames.astype(jnp.bfloat16)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    import dataclasses
    enc_cfg = dataclasses.replace(
        cfg, num_layers=enc.num_layers, d_model=enc.d_model,
        num_heads=enc.num_heads, num_kv_heads=enc.num_kv_heads, d_ff=enc.d_ff,
        moe=None, encoder=None, attention="gqa",
        head_dim=enc.d_model // enc.num_heads)

    def body(h, layer_p):
        z = apply_norm(cfg.norm, layer_p["ln1"], h)
        q, k, v = attn.gqa_project(layer_p["attn"], enc_cfg, z, positions)
        a = attn.chunked_attention(q, k, v, causal=False)
        a = dense(layer_p["attn"]["wo"], a.reshape(B, S, -1))
        h = h + a
        z = apply_norm(cfg.norm, layer_p["ln2"], h)
        return h + ffn(layer_p["ffn"], z, cfg.activation), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(cfg.norm, params["enc_norm"], x)


def _logits(params, cfg: ModelConfig, x):
    h = apply_norm(cfg.norm, params["final_norm"], x)
    if cfg.tie_embeddings:
        return unembed(params["embed"], h)
    return dense(params["lm_head"], h)


def _migration_view(ready_l, plan_l, slot_l, tplan_l, back_l):
    """Per-layer double-buffer select for overlapped migration: once a
    layer's staged fill is READY, dispatch reads the back buffer under the
    target plan row; until then it reads the live (old-plan) pair. A
    ``lax.cond`` (not ``where``) so the unselected buffer is never
    materialized — idle steps (ready all-False) cost nothing."""
    return jax.lax.cond(ready_l,
                        lambda: (tplan_l, back_l),
                        lambda: (plan_l, slot_l))


def forward(params, cfg: ModelConfig, batch, rt: Runtime, *, mode: str,
            cache=None, cache_len=None, plan=None, predicted_idx=None,
            block_tables=None, last_pos=None, token_weight=None,
            slot_weights=None, slot_weights_back=None, slot_ready=None,
            target_plan=None, resched=None):
    """Unified entry. Returns (logits, new_cache, stats_dict).

    mode=train:   logits (B, S, V) over the full sequence.
    mode=prefill: logits (B, 1, V) for the last position; fills cache.
    mode=decode:  batch={"tokens": (B, 1)}; logits (B, 1, V).

    ``plan`` / ``predicted_idx`` override rt.plan / rt.predicted_idx so the
    serving loop can swap placement plans per prediction interval without
    recompiling (they are traced arguments, not closure constants).

    Continuous-batching extensions (all traced, all optional):
      ``cache_len``     — decode position; a scalar (legacy synchronous
                          batch) or a (B,) vector of per-slot lengths.
      ``block_tables``  — (B, M) physical-block map; selects the paged-KV
                          decode path (cache = block pool).
      ``last_pos``      — (B,) index of each request's last REAL prompt
                          token; prefill logits are gathered there instead
                          of at the padded end.
      ``token_weight``  — (B, S) weight for MoE expert histograms (0 for
                          padding / idle slots).
      ``slot_weights``  — stacked {name: (L, S_global, ...)} resident
                          replica slot weights (``ReplicaStore.weights``);
                          when given, EP dispatch reads replica weights
                          from device memory instead of all_gathering a
                          pool every step. Traced, so migration commits
                          (new contents, same shapes) never recompile.

    Overlapped-migration extensions (``MoEConfig.overlap_migration``; all
    traced, engines pass live==back + all-False ready when no migration is
    in flight so the jit signature never changes):
      ``slot_weights_back`` — the in-flight double buffer the
                          ``LayerStagedExecutor`` is filling toward the
                          target plan.
      ``slot_ready``    — (L,) bool per-layer ready-version vector: True
                          once layer l's staged fill committed.
      ``target_plan``   — stacked plan the migration is moving toward.
    Each scanned layer picks (plan_l, slots_l) from the OLD pair until its
    ready bit flips, then from the target pair — every layer always sees a
    consistent plan/weights view, so the async path is bit-exact with the
    synchronous one at every intermediate state.
    """
    enc_out = None
    if cfg.is_encdec and mode != "decode":
        enc_out = _encode(params, cfg, batch["frames"], rt)

    if mode == "decode":
        B = batch["tokens"].shape[0]
        x = embed(params["embed"], batch["tokens"]).astype(jnp.bfloat16)
        cl = jnp.asarray(cache_len, jnp.int32)
        positions = (cl[:, None] if cl.ndim == 1
                     else jnp.full((B, 1), cache_len, jnp.int32))
    else:
        x, positions = _embed_inputs(params, cfg, batch)
    x = constrain_acts(x, rt)

    L = cfg.num_layers
    stats = {"expert_counts": None, "aux_loss": 0.0, "z_loss": 0.0}

    if cfg.family == "hybrid":
        new_caches = []
        for i in range(L):
            kind = _layer_kind(cfg, i)
            st = None if cache is None else cache[i]
            if mode == "train":
                st = (griffin.init_recurrent_state(cfg, x.shape[0])
                      if kind == "recurrent" else
                      attn.init_gqa_cache(cfg, x.shape[0], 1))
            x, new_st = _hybrid_layer(params["hybrid_layers"][i], cfg, x,
                                      positions, kind, st, rt, mode, cache_len)
            x = constrain_acts(x, rt)
            new_caches.append(new_st)
        new_cache = None if mode == "train" else new_caches

    elif cfg.family == "ssm":
        if cache is None:
            state0 = rwkv6.init_rwkv_state(cfg, x.shape[0])
            cache_l = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (L,) + a.shape), state0)
        else:
            cache_l = cache

        # constraints gain 7.2x at train but cost 12% at prefill (the
        # state-scan layout differs) — apply them for training only
        # (EXPERIMENTS.md §Perf sweep note)
        use_c = mode == "train"

        def body(h, xs):
            layer_p, st = xs
            h = constrain_acts(h, rt) if use_c else h
            h, new_st = _rwkv_layer(layer_p, cfg, h, st)
            return constrain_acts(h, rt) if use_c else h, new_st

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache_l))
        if mode == "train":
            new_cache = None

    else:
        plan = plan if plan is not None else rt.plan
        pred = predicted_idx if predicted_idx is not None else rt.predicted_idx

        seq_shard = cfg.is_moe and mode != "decode"
        overlap = (cfg.is_moe and cfg.moe.overlap_migration
                   and slot_weights is not None
                   and slot_weights_back is not None
                   and slot_ready is not None and target_plan is not None
                   and plan is not None)

        def body(h, xs):
            (layer_p, cache_l, plan_l, pred_l, slot_l, back_l, ready_l,
             tplan_l, resched_l) = xs
            if overlap:
                plan_l, slot_l = _migration_view(ready_l, plan_l, slot_l,
                                                 tplan_l, back_l)
            h = constrain_acts(h, rt, seq_shard)
            h, new_c, st = _attn_layer(
                layer_p, cfg, h, positions, rt, cache=cache_l,
                cache_len=cache_len, mode=mode, enc_out=enc_out,
                plan_l=plan_l, predicted_l=pred_l,
                block_tables=block_tables, token_weight=token_weight,
                slot_w_l=slot_l, resched_l=resched_l)
            return constrain_acts(h, rt, seq_shard), (new_c, st)

        xs = (params["layers"], cache,
              plan if plan is not None else _none_stack(L),
              pred if pred is not None else _none_stack(L),
              slot_weights if slot_weights is not None else _none_stack(L),
              slot_weights_back if overlap else _none_stack(L),
              slot_ready if overlap else _none_stack(L),
              target_plan if overlap else _none_stack(L),
              resched if resched is not None else _none_stack(L))
        x, (new_cache, layer_stats) = jax.lax.scan(body, x, xs)
        if cfg.is_moe:
            counts, slots, aux, z, dropped, overflow = layer_stats
            stats = {"expert_counts": counts, "slot_counts": slots,
                     "aux_loss": aux.sum(), "z_loss": z.sum(),
                     "dropped": dropped,       # (L,) per-layer drop counts
                     "overflow": overflow}     # (L,) round-1 overflows
        if mode == "train":
            new_cache = None

    if mode == "prefill":
        if last_pos is not None:
            B = x.shape[0]
            x_last = x[jnp.arange(B), jnp.asarray(last_pos, jnp.int32)][:, None]
            logits = _logits(params, cfg, x_last)
        else:
            logits = _logits(params, cfg, x[:, -1:])
    elif mode == "decode":
        logits = _logits(params, cfg, x)
    else:
        logits = _logits(params, cfg, x)
    return logits, new_cache, stats


class _NoneStack:
    """Sentinel scanned alongside xs when a plan/prediction is absent."""

def _none_stack(L):
    return None
