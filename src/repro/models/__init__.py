"""Model definitions. Import submodules directly (repro.models.transformer
etc.) — no eager re-exports, to keep the import graph acyclic."""
