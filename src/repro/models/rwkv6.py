"""RWKV-6 "Finch" [arXiv:2404.05892] — attention-free time-mix with
data-dependent decay, plus relu^2 channel-mix.

State per layer/head: S in R^{head_dim x head_dim} (plus the token-shift
buffer x_{t-1}) — O(1) in sequence length, which is why rwkv6 runs the
long_500k decode shape natively.

Recurrence (per head; diag acts on the key dimension):

    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t

Sequence processing uses a chunked formulation: within a chunk of length C
the recurrence is expanded with cumulative decay products so the chunk is
two matmuls (strict-lower-triangular intra-chunk term + inter-chunk state
term), and a lax.scan carries S across chunks. This is the TPU-native
adaptation of the CUDA wkv kernel: MXU-sized matmuls instead of a
per-token scalar loop.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (dense, init_dense, init_rmsnorm, rmsnorm,
                                 truncated_normal_init)

LORA_DIM = 64
CHUNK = 32
# Max per-step decay rate: w_t = exp(-rate), rate clipped to <= MAX_RATE so the
# intra-chunk rescaling exp(-cum) stays < exp(MAX_RATE*CHUNK) ~ 3e12 (f32-safe).
MAX_RATE = 0.9


def init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    H, hd = cfg.num_heads, cfg.head_dim
    keys = jax.random.split(key, 11)
    return {
        # data-dependent token-shift (ddlerp) base mixes + low-rank modulators
        "mu": truncated_normal_init(keys[0], (5, d), 0.02),
        "lora_a": truncated_normal_init(keys[1], (d, LORA_DIM * 5), 0.01),
        "lora_b": truncated_normal_init(keys[2], (5, LORA_DIM, d), 0.01),
        # projections
        "w_r": init_dense(keys[3], d, H * hd),
        "w_k": init_dense(keys[4], d, H * hd),
        "w_v": init_dense(keys[5], d, H * hd),
        "w_g": init_dense(keys[6], d, H * hd),
        "w_o": init_dense(keys[7], H * hd, d),
        # data-dependent decay rate: softplus-ish via exp(base + lora)
        "decay_base": jnp.full((H * hd,), -6.0, jnp.float32),
        "decay_lora_a": truncated_normal_init(keys[8], (d, LORA_DIM), 0.01),
        "decay_lora_b": truncated_normal_init(keys[9], (LORA_DIM, H * hd), 0.01),
        "bonus": truncated_normal_init(keys[10], (H, hd), 0.5),
        "ln_out": init_rmsnorm(H * hd),
    }


def _token_shift(params, x, x_prev):
    """x: (B,S,d); x_prev: (B,d) = last token of the previous segment.
    Returns 5 mixed streams (r,k,v,w,g) and the new shift state."""
    B, S, d = x.shape
    shifted = jnp.concatenate([x_prev[:, None, :].astype(x.dtype), x[:, :-1, :]],
                              axis=1)
    delta = shifted - x
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", x, params["lora_a"].astype(x.dtype)))
    lora = lora.reshape(B, S, 5, LORA_DIM)
    mod = jnp.einsum("bsir,ird->bsid", lora, params["lora_b"].astype(x.dtype))
    mix = params["mu"].astype(x.dtype)[None, None] + mod          # (B,S,5,d)
    streams = x[:, :, None, :] + delta[:, :, None, :] * mix
    return streams, x[:, -1, :]


def _log_decay(params, xw):
    """Per-channel log decay (negative), clamped for chunk stability."""
    lw = jnp.tanh(xw @ params["decay_lora_a"].astype(xw.dtype)) \
        @ params["decay_lora_b"].astype(xw.dtype)
    rate = jnp.exp(jnp.clip(
        params["decay_base"].astype(jnp.float32) + lw.astype(jnp.float32),
        -20.0, jnp.log(MAX_RATE)))
    return -rate                                                  # logw in [-0.9, 0)


def wkv_chunked(r, k, v, logw, u, state, chunk: int = CHUNK):
    """r,k,v: (B,S,H,hd); logw: (B,S,H,hd) negative log-decay; u: (H,hd);
    state: (B,H,hd,hd) float32.  Returns (y: (B,S,H,hd), new_state)."""
    B, S, H, hd = r.shape
    chunk = min(chunk, max(S, 1))
    pad = (-S) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = zp(r), zp(k), zp(v), zp(logw)  # logw pad 0 => w=1
    Sp = S + pad
    n = Sp // chunk
    f32 = jnp.float32
    shape_c = (B, n, chunk, H, hd)
    rc = r.reshape(shape_c).astype(f32)
    kc = k.reshape(shape_c).astype(f32)
    vc = v.reshape(shape_c).astype(f32)
    lw = logw.reshape(shape_c).astype(f32)

    cum = jnp.cumsum(lw, axis=2)                   # inclusive: cum[t]=sum_{j<=t}
    dec_in = jnp.exp(cum - lw)                     # exp(cum[t-1]) <= 1
    dec_all = jnp.exp(cum[:, :, -1])               # full-chunk decay (B,n,H,hd)
    dec_out = jnp.exp(cum[:, :, -1:] - cum)        # prod_{j>s} w_j <= 1
    k_resc = kc * jnp.exp(-cum)                    # k_s * exp(-cum[s]) (bounded, see MAX_RATE)

    def chunk_step(S_state, inputs):
        rci, kci, vci, dec_ini, dec_alli, dec_outi, k_ri = inputs
        r_sc = rci * dec_ini                                # r_t exp(cum[t-1])
        a = jnp.einsum("thd,shd->hts", r_sc, k_ri)          # (H,C,C)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        a = jnp.where(tri[None], a, 0.0)
        y = jnp.einsum("hts,she->the", a, vci)              # intra-chunk history
        bonus = jnp.einsum("thd,thd->th", rci, kci * u[None].astype(f32))
        y += bonus[:, :, None] * vci                        # current-token bonus
        y += jnp.einsum("thd,hde->the", r_sc, S_state)      # inter-chunk state
        k_state = kci * dec_outi
        S_new = dec_alli[:, :, None] * S_state + jnp.einsum(
            "shd,she->hde", k_state, vci)
        return S_new, y

    def run_batch(state_b, seqs):
        return jax.lax.scan(chunk_step, state_b, seqs)

    inputs = tuple(jnp.moveaxis(a, 1, 0) for a in
                   (rc, kc, vc, dec_in, dec_all, dec_out, k_resc))
    new_state, y = jax.vmap(run_batch, in_axes=(0, 1), out_axes=(0, 1))(
        state.astype(f32), inputs)
    y = jnp.moveaxis(y, 1, 0).reshape(B, Sp, H, hd)[:, :S]
    return y.astype(r.dtype), new_state


def wkv_step(r, k, v, logw, u, state):
    """Single-token decode step. r,k,v,logw: (B,H,hd); state: (B,H,hd,hd)."""
    f32 = jnp.float32
    r, k, v, logw = (a.astype(f32) for a in (r, k, v, logw))
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    y = jnp.einsum("bhd,bhde->bhe", r, state + u[None, :, :, None].astype(f32) * kv)
    new_state = jnp.exp(logw)[..., None] * state + kv
    return y, new_state


def time_mix(params, cfg: ModelConfig, x, state) -> Tuple[jnp.ndarray, dict]:
    """state: {"shift_tm": (B,d), "wkv": (B,H,hd,hd)}."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    streams, new_shift = _token_shift(params, x, state["shift_tm"])
    xr, xk, xv, xw, xg = [streams[:, :, i] for i in range(5)]
    r = dense(params["w_r"], xr).reshape(B, S, H, hd)
    k = dense(params["w_k"], xk).reshape(B, S, H, hd)
    v = dense(params["w_v"], xv).reshape(B, S, H, hd)
    g = jax.nn.silu(dense(params["w_g"], xg))
    logw = _log_decay(params, xw).reshape(B, S, H, hd)
    if S == 1:
        y, new_wkv = wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0],
                              params["bonus"], state["wkv"])
        y = y[:, None].astype(x.dtype)
    else:
        y, new_wkv = wkv_chunked(r, k, v, logw, params["bonus"], state["wkv"])
    y = rmsnorm(params["ln_out"], y.reshape(B, S, H * hd).astype(x.dtype))
    out = dense(params["w_o"], y * g)
    return out, {"shift_tm": new_shift, "wkv": new_wkv}


def init_channel_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "mu": truncated_normal_init(k1, (2, d), 0.02),
        "w_k": init_dense(k2, d, cfg.d_ff),
        "w_v": init_dense(k3, cfg.d_ff, d),
        "w_r": init_dense(k4, d, d),
    }


def channel_mix(params, x, x_prev):
    """relu^2 channel mix with token shift. x_prev: (B,d)."""
    shifted = jnp.concatenate([x_prev[:, None, :].astype(x.dtype), x[:, :-1, :]],
                              axis=1)
    mu = params["mu"].astype(x.dtype)
    xk = x + (shifted - x) * mu[0]
    xr = x + (shifted - x) * mu[1]
    h = jnp.square(jax.nn.relu(dense(params["w_k"], xk)))
    rgate = jax.nn.sigmoid(dense(params["w_r"], xr))
    return rgate * dense(params["w_v"], h), x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Per-layer recurrent state (stacked over layers by the caller)."""
    return {
        "shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, cfg.num_heads, cfg.head_dim, cfg.head_dim),
                         jnp.float32),
    }
