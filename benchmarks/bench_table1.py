"""Table 1: skewness vs Distribution-Only estimation error rate.

The paper measures MMLU (skew 1.39), Alpaca Eval (1.40), SST2 (1.99) on
Mixtral. Offline we synthesize corpora with those exact skews (DESIGN.md
Sec 3) on the Mixtral routing geometry (8 experts), estimate p by MLE on
an 80% train split, and report the paper's error-rate metric on the test
split. Expected: error grows with skewness (cold experts starve).
"""

from __future__ import annotations

import numpy as np

from repro.core.balance import error_rate
from repro.core.predictors import DistributionEstimator
from repro.data.synthetic import make_routing_trace

DATASETS = [                      # paper Table 1 analogues
    ("mmlu-like", 1.39),
    ("alpaca-like", 1.40),
    ("sst2-like", 1.99),
]
E, L, V = 8, 4, 2048


def run(verbose: bool = True):
    rows = []
    for name, skew in DATASETS:
        # Paper setup: MLE on the train split, error against the test
        # split's empirical distribution. The paper's error comes from
        # train->test DISTRIBUTION SHIFT on real datasets (skewed datasets
        # drift more — the very premise of dynamic duplication); the
        # corpus generator's `drift` knob encodes that, scaled by skew.
        drift = 1.1 * max(skew - 1.28, 0.0)
        tr = make_routing_trace(num_sequences=64, seq_len=512, vocab=V,
                                num_experts=E, num_layers=L, skew=skew,
                                predictability=0.0,    # pure multinomial
                                drift=drift, seed=hash(name) % 1000)
        n = int(tr.tokens.shape[0] * 0.8)
        est = DistributionEstimator(L, E, ema=0.9)
        for b in range(n):                         # batch-wise moving avg
            counts = np.stack([
                np.bincount(tr.experts[l, b].reshape(-1), minlength=E)
                for l in range(L)]).astype(np.float64)
            est.update(counts)
        p_test = np.stack([
            np.bincount(tr.experts[l, n:].reshape(-1), minlength=E)
            for l in range(L)]).astype(np.float64)
        p_test /= p_test.sum(axis=1, keepdims=True)
        err = error_rate(est.predict(), p_test)
        meas_skew = float((tr.dist.max(1) * E).mean())
        rows.append(dict(dataset=name, target_skew=skew,
                         measured_skew=round(meas_skew, 3),
                         error_rate_pct=round(100 * err, 2)))
    if verbose:
        print(f"{'dataset':12s} {'skew':>6s} {'error%':>7s}")
        for r in rows:
            print(f"{r['dataset']:12s} {r['measured_skew']:6.2f} "
                  f"{r['error_rate_pct']:7.2f}")
    # derived metric: error at high skew minus error at low skew (>0 = Table
    # 1 trend reproduced)
    derived = rows[-1]["error_rate_pct"] - rows[0]["error_rate_pct"]
    return rows, derived


if __name__ == "__main__":
    run()
