"""Replica runtime benchmark: persistent store vs per-step pool gather.

Two quantities per (ep_ranks, dup_slots) point, measured on a real EP
mesh (8 fake host devices, spawned in a subprocess so the main process
keeps its single-device view):

* steady-state prefill step time with a FIXED duplicated plan — the
  ``replica_impl="gather"`` path pays the pool all_gather every step of
  every MoE layer, the ``"store"`` path reads resident slot weights;
  ``store_speedup = gather / store`` is the key derived quantity (the
  per-step overhead the paper's Sec 5 transfer model says should not
  exist at all).
* plan-switch stall — wall time of a full chunked migration between two
  different duplication plans, plus the bytes it moves (the one-off cost
  the store pays INSTEAD of the per-step collective).

Writes ``BENCH_migration.json``; the CI gate fails when the store path is
slower than the gather path it replaces (``check_regression``).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, math, time
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.core.duplication import duplicate_experts_host
from repro.core.placement import stack_plans
from repro.data.synthetic import skewed_distribution
from repro.models.transformer import Runtime, forward, init_cache, init_model
from repro.runtime import (MigrationExecutor, ReplicaStore, migration_stall_s,
                           make_migrate_step, plan_diff)
from repro.train.steps import make_prefill_step

COMBOS = {combos}
ITERS = {iters}
B, S = 2, 64

def bench_point(ranks, dup):
    base = get_config("mixtral-8x7b").reduced()
    # heavy expert weights vs light token work: the regime where the
    # per-step pool gather dominates (weight bytes >> activation bytes)
    cfg = dataclasses.replace(base, num_layers=2, moe=dataclasses.replace(
        base.moe, d_ff_expert=2048, duplication_slots=dup))
    E = cfg.moe.num_experts
    mesh = jax.make_mesh((8 // ranks, ranks), ("data", "model"))
    rt = Runtime(mesh=mesh, ep=True, ep_ranks=ranks, use_duplication=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    experts = params["layers"]["moe"]["experts"]
    batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                           cfg.vocab_size)}}
    plan_a = stack_plans([duplicate_experts_host(
        skewed_distribution(E, 3.0 + l), ranks, dup, 4).plan
        for l in range(cfg.num_layers)])
    plan_b = stack_plans([duplicate_experts_host(
        skewed_distribution(E, 6.0 - l), ranks, dup, 4).plan
        for l in range(cfg.num_layers)])
    store = ReplicaStore.from_params(experts, plan_a, num_experts=E,
                                     ep_ranks=ranks, dup_slots=dup, mesh=mesh)
    cache = init_cache(cfg, rt, B, S)
    step = jax.jit(make_prefill_step(cfg, rt))

    def timed_pair(fa, fb):
        # best-of-ITERS, INTERLEAVED round by round so machine drift
        # (CPU contention, allocator state) hits both paths equally
        jax.block_until_ready(fa())               # compile + warm
        jax.block_until_ready(fb())
        best_a = best_b = math.inf
        for _ in range(ITERS):
            t0 = time.perf_counter()
            jax.block_until_ready(fa())
            best_a = min(best_a, time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fb())
            best_b = min(best_b, time.perf_counter() - t0)
        return best_a, best_b

    with mesh:
        t_gather, t_store = timed_pair(
            lambda: step(params, batch, cache, plan_a),
            lambda: step(params, batch, cache, plan_a, None, store.weights))
        # plan switch: chunked migration A -> B (wall time of the fill)
        mig = make_migrate_step(mesh, num_experts=E, ep_ranks=ranks,
                                dup_slots=dup)
        diff = plan_diff(plan_a, plan_b, ranks, dup)
        t_switch, moved = 0.0, 0
        if diff.num_entries:
            ex = MigrationExecutor(mig, experts, store.entry_bytes, chunk=4)
            ex.begin(store.weights, diff, plan_b)
            ex._run_chunk()                       # compile the chunk step
            jax.block_until_ready(ex._back)
            ex.begin(store.weights, diff, plan_b)
            t0 = time.perf_counter()
            (weights, _, _), moved = ex.tick()
            jax.block_until_ready(weights)
            t_switch = time.perf_counter() - t0
    return dict(ranks=ranks, dup_slots=dup,
                gather_step_us=t_gather * 1e6, store_step_us=t_store * 1e6,
                store_speedup=t_gather / max(t_store, 1e-12),
                switch_entries=diff.num_entries, switch_bytes=int(moved),
                switch_wall_us=t_switch * 1e6)

print(json.dumps([bench_point(r, d) for r, d in COMBOS]))
"""


def run(verbose: bool = True, smoke: bool = None):
    import repro

    if smoke is None:
        smoke = _smoke()
    combos = [(4, 1), (4, 2)] if smoke else [(4, 1), (4, 2), (8, 1), (8, 2)]
    iters = 5 if smoke else 10
    # repro is a namespace package (no __init__.py): locate src via __path__
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    prog = textwrap.dedent(_SUB).format(combos=combos, iters=iters)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=1800,
                         env=dict(os.environ, PYTHONPATH=src_root))
    if out.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{out.stderr[-2000:]}")
    rows = json.loads(out.stdout.strip().splitlines()[-1])

    doc = {"schema": 1, "smoke": smoke, "rows": rows}
    out_dir = os.environ.get("REPRO_BENCH_OUT", ".")
    path = os.path.join(out_dir, "BENCH_migration.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)

    if verbose:
        print(f"{'ranks':>5s} {'dup':>4s} {'gather':>10s} {'store':>10s} "
              f"{'speedup':>8s} {'switch':>10s} {'moved':>10s}")
        for r in rows:
            print(f"{r['ranks']:5d} {r['dup_slots']:4d} "
                  f"{r['gather_step_us']:9.0f}us {r['store_step_us']:9.0f}us "
                  f"{r['store_speedup']:7.2f}x {r['switch_wall_us']:9.0f}us "
                  f"{r['switch_bytes'] / 1e6:8.1f}MB")
        print(f"wrote {path}")

    head = rows[0]
    summary = {
        "store_speedup": head["store_speedup"],
        "gather_step_us": head["gather_step_us"],
        "store_step_us": head["store_step_us"],
        "switch_wall_us": head["switch_wall_us"],
        "switch_bytes": float(head["switch_bytes"]),
        "min_store_speedup": min(r["store_speedup"] for r in rows),
    }
    derived = (f"store_speedup={head['store_speedup']:.2f}x "
               f"switch_stall={head['switch_wall_us']:.0f}us "
               f"moved={head['switch_bytes'] / 1e6:.1f}MB")
    return summary, derived


if __name__ == "__main__":
    run(verbose=True)
