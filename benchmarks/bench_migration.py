"""Replica runtime benchmark: persistent store vs per-step pool gather.

Two quantities per (ep_ranks, dup_slots) point, measured on a real EP
mesh (8 fake host devices, spawned in a subprocess so the main process
keeps its single-device view):

* steady-state prefill step time with a FIXED duplicated plan — the
  ``replica_impl="gather"`` path pays the pool all_gather every step of
  every MoE layer, the ``"store"`` path reads resident slot weights;
  ``store_speedup = gather / store`` is the key derived quantity (the
  per-step overhead the paper's Sec 5 transfer model says should not
  exist at all).
* plan-switch stall — wall time of a full chunked migration between two
  different duplication plans, plus the bytes it moves (the one-off cost
  the store pays INSTEAD of the per-step collective).
* overlap on/off — the SAME plan switch executed synchronously (serving
  blocked while the diff drains) vs layer-staged and overlapped with
  prefill steps (``LayerStagedExecutor`` + the per-layer ready select in
  ``forward``): reports the serving-blocked wall seconds each path
  exposes, the steps-to-adopt, the modeled ``hidden_fraction`` of the
  transfer stall, and a bit-exactness check of the final store.

Writes ``BENCH_migration.json``; the CI gate fails when the store path is
slower than the gather path it replaces, when overlap hides less than
half the plan-switch stall, or when the async path diverges from the
synchronous one (``check_regression``).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, math, time
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.core.duplication import duplicate_experts_host
from repro.core.placement import stack_plans
from repro.data.synthetic import skewed_distribution
from repro.models.transformer import Runtime, forward, init_cache, init_model
from repro.runtime import (MigrationExecutor, ReplicaStore, migration_stall_s,
                           make_migrate_step, plan_diff)
from repro.train.steps import make_prefill_step

COMBOS = {combos}
ITERS = {iters}
B, S = 2, 64

def bench_point(ranks, dup):
    base = get_config("mixtral-8x7b").reduced()
    # heavy expert weights vs light token work: the regime where the
    # per-step pool gather dominates (weight bytes >> activation bytes)
    cfg = dataclasses.replace(base, num_layers=2, moe=dataclasses.replace(
        base.moe, d_ff_expert=2048, duplication_slots=dup))
    E = cfg.moe.num_experts
    mesh = jax.make_mesh((8 // ranks, ranks), ("data", "model"))
    rt = Runtime(mesh=mesh, ep=True, ep_ranks=ranks, use_duplication=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    experts = params["layers"]["moe"]["experts"]
    batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                           cfg.vocab_size)}}
    plan_a = stack_plans([duplicate_experts_host(
        skewed_distribution(E, 3.0 + l), ranks, dup, 4).plan
        for l in range(cfg.num_layers)])
    plan_b = stack_plans([duplicate_experts_host(
        skewed_distribution(E, 6.0 - l), ranks, dup, 4).plan
        for l in range(cfg.num_layers)])
    store = ReplicaStore.from_params(experts, plan_a, num_experts=E,
                                     ep_ranks=ranks, dup_slots=dup, mesh=mesh)
    cache = init_cache(cfg, rt, B, S)
    step = jax.jit(make_prefill_step(cfg, rt))

    def timed_pair(fa, fb):
        # best-of-ITERS, INTERLEAVED round by round so machine drift
        # (CPU contention, allocator state) hits both paths equally
        jax.block_until_ready(fa())               # compile + warm
        jax.block_until_ready(fb())
        best_a = best_b = math.inf
        for _ in range(ITERS):
            t0 = time.perf_counter()
            jax.block_until_ready(fa())
            best_a = min(best_a, time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fb())
            best_b = min(best_b, time.perf_counter() - t0)
        return best_a, best_b

    with mesh:
        t_gather, t_store = timed_pair(
            lambda: step(params, batch, cache, plan_a),
            lambda: step(params, batch, cache, plan_a, None, store.weights))
        # plan switch: chunked migration A -> B (wall time of the fill)
        mig = make_migrate_step(mesh, num_experts=E, ep_ranks=ranks,
                                dup_slots=dup)
        diff = plan_diff(plan_a, plan_b, ranks, dup)
        t_switch, moved = 0.0, 0
        if diff.num_entries:
            ex = MigrationExecutor(mig, experts, store.entry_bytes, chunk=4)
            ex.begin(store.weights, diff, plan_b)
            ex._run_chunk()                       # compile the chunk step
            jax.block_until_ready(ex._back)
            ex.begin(store.weights, diff, plan_b)
            t0 = time.perf_counter()
            (weights, _, _), moved = ex.tick()
            jax.block_until_ready(weights)
            t_switch = time.perf_counter() - t0
    return dict(ranks=ranks, dup_slots=dup,
                gather_step_us=t_gather * 1e6, store_step_us=t_store * 1e6,
                store_speedup=t_gather / max(t_store, 1e-12),
                switch_entries=diff.num_entries, switch_bytes=int(moved),
                switch_wall_us=t_switch * 1e6)


def bench_overlap(ranks, dup):
    \"\"\"Same plan switch, synchronous vs overlapped: serving-blocked wall
    seconds, steps-to-adopt, modeled hidden fraction, bit-exactness.\"\"\"
    from repro.core.simulator import A100_PCIE
    from repro.runtime import (LayerStagedExecutor, migrate_all,
                               overlap_chunk_budget, split_hidden_exposed)
    base = get_config("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(base, num_layers=2, moe=dataclasses.replace(
        base.moe, d_ff_expert=2048, duplication_slots=dup))
    E = cfg.moe.num_experts
    mesh = jax.make_mesh((8 // ranks, ranks), ("data", "model"))
    rt = Runtime(mesh=mesh, ep=True, ep_ranks=ranks, use_duplication=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    experts = params["layers"]["moe"]["experts"]
    batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                           cfg.vocab_size)}}
    plan_a = stack_plans([duplicate_experts_host(
        skewed_distribution(E, 3.0 + l), ranks, dup, 4).plan
        for l in range(cfg.num_layers)])
    plan_b = stack_plans([duplicate_experts_host(
        skewed_distribution(E, 6.0 - l), ranks, dup, 4).plan
        for l in range(cfg.num_layers)])
    store = ReplicaStore.from_params(experts, plan_a, num_experts=E,
                                     ep_ranks=ranks, dup_slots=dup, mesh=mesh)
    cache = init_cache(cfg, rt, B, S)
    step = jax.jit(make_prefill_step(cfg, rt))
    mig = make_migrate_step(mesh, num_experts=E, ep_ranks=ranks,
                            dup_slots=dup)
    diff = plan_diff(plan_a, plan_b, ranks, dup)
    entry = store.entry_bytes
    hw = A100_PCIE
    chunk = 4
    L = cfg.num_layers
    zeros_ready = jnp.zeros((L,), bool)

    with mesh:
        # warm everything: prefill (idle-overlap signature) + one chunk
        jax.block_until_ready(step(params, batch, cache, plan_a, None,
                                   store.weights, store.weights,
                                   zeros_ready, plan_a))
        ex = LayerStagedExecutor(mig, experts, entry, num_layers=L,
                                 chunk=chunk)
        ex.begin(store.weights, diff, plan_b)
        ex._run_chunk()
        jax.block_until_ready(ex._back)
        # baseline: migration-free step wall (the overlap window)
        t0 = time.perf_counter()
        jax.block_until_ready(step(params, batch, cache, plan_a, None,
                                   store.weights, store.weights,
                                   zeros_ready, plan_a))
        window = time.perf_counter() - t0

        # --- synchronous: serving BLOCKED while the whole diff drains
        sync_weights = migrate_all(mig, store.weights, experts, diff,
                                   chunk=chunk)
        ex.cancel()
        ex.begin(store.weights, diff, plan_b)
        t0 = time.perf_counter()
        (w_drain, _, _), _ = ex.tick()
        jax.block_until_ready(w_drain)
        sync_blocked = time.perf_counter() - t0

        # --- overlapped: chunks enqueued per step, serving never blocked;
        # the serving step reads (live, back, ready, target) per layer
        ex.cancel()
        ex.begin(store.weights, diff, plan_b)
        budget = overlap_chunk_budget(window, chunk_entries=chunk,
                                      entry_bytes=entry, hw=hw,
                                      max_chunks=1)   # stretch the drain
        steps = 0
        blocked = hidden_model = exposed_model = 0.0
        commit = None
        while commit is None and steps < 64:
            t0 = time.perf_counter()
            commit, moved = ex.tick(budget)       # enqueue only, no block
            blocked += time.perf_counter() - t0
            if moved:
                stall = moved / hw.link_bw
                h, e = split_hidden_exposed(stall, window)
                hidden_model += h
                exposed_model += e
            ready = (jnp.asarray(ex.ready_mask()) if ex.active
                     else jnp.ones((L,), bool))
            back = ex.back_weights if ex.active else store.weights
            tplan = plan_b if ex.active else plan_a
            out = step(params, batch, cache, plan_a, None, store.weights,
                       back, ready, tplan)
            jax.block_until_ready(out[0])         # serving critical path
            steps += 1
        weights, _, se = commit
        store.adopt(weights, se)
        bitexact = all(bool(jnp.array_equal(store.weights[k],
                                            sync_weights[k]))
                       for k in sync_weights)
    total = hidden_model + exposed_model
    # the GATED hidden fraction is MEASURED: how much of the serving-
    # blocked wall the synchronous drain pays does the overlapped path
    # avoid. (The modeled split is reported alongside but is 1.0 by
    # construction whenever the budget fits the window, so it cannot
    # catch an overlap regression — a tick that started blocking would.)
    measured = max(0.0, 1.0 - blocked / max(sync_blocked, 1e-12))
    return dict(ranks=ranks, dup_slots=dup,
                window_us=window * 1e6,
                sync_blocked_us=sync_blocked * 1e6,
                overlap_blocked_us=blocked * 1e6,
                steps_to_adopt=steps,
                hidden_fraction=measured,
                hidden_fraction_model=hidden_model / total if total else 1.0,
                bitexact=int(bitexact))

rows = [bench_point(r, d) for r, d in COMBOS]
overlap = bench_overlap(*COMBOS[0])
print(json.dumps({{"rows": rows, "overlap": overlap}}))
"""


def run(verbose: bool = True, smoke: bool = None):
    import repro

    if smoke is None:
        smoke = _smoke()
    combos = [(4, 1), (4, 2)] if smoke else [(4, 1), (4, 2), (8, 1), (8, 2)]
    iters = 5 if smoke else 10
    # repro is a namespace package (no __init__.py): locate src via __path__
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    prog = textwrap.dedent(_SUB).format(combos=combos, iters=iters)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=1800,
                         env=dict(os.environ, PYTHONPATH=src_root))
    if out.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{out.stderr[-2000:]}")
    doc_in = json.loads(out.stdout.strip().splitlines()[-1])
    rows, overlap = doc_in["rows"], doc_in["overlap"]

    doc = {"schema": 1, "smoke": smoke, "rows": rows, "overlap": overlap}
    out_dir = os.environ.get("REPRO_BENCH_OUT", ".")
    path = os.path.join(out_dir, "BENCH_migration.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)

    if verbose:
        print(f"{'ranks':>5s} {'dup':>4s} {'gather':>10s} {'store':>10s} "
              f"{'speedup':>8s} {'switch':>10s} {'moved':>10s}")
        for r in rows:
            print(f"{r['ranks']:5d} {r['dup_slots']:4d} "
                  f"{r['gather_step_us']:9.0f}us {r['store_step_us']:9.0f}us "
                  f"{r['store_speedup']:7.2f}x {r['switch_wall_us']:9.0f}us "
                  f"{r['switch_bytes'] / 1e6:8.1f}MB")
        o = overlap
        print(f"plan-switch overlap (ranks={o['ranks']} dup={o['dup_slots']}"
              f", window={o['window_us']:.0f}us):")
        print(f"  sync    blocked {o['sync_blocked_us']:9.0f}us  "
              f"steps_to_adopt=1")
        print(f"  overlap blocked {o['overlap_blocked_us']:9.0f}us  "
              f"steps_to_adopt={o['steps_to_adopt']}  "
              f"hidden={100 * o['hidden_fraction']:.0f}%  "
              f"bitexact={bool(o['bitexact'])}")
        print(f"wrote {path}")

    head = rows[0]
    summary = {
        "store_speedup": head["store_speedup"],
        "gather_step_us": head["gather_step_us"],
        "store_step_us": head["store_step_us"],
        "switch_wall_us": head["switch_wall_us"],
        "switch_bytes": float(head["switch_bytes"]),
        "min_store_speedup": min(r["store_speedup"] for r in rows),
        "overlap_hidden_fraction": overlap["hidden_fraction"],
        "overlap_hidden_fraction_model": overlap["hidden_fraction_model"],
        "overlap_steps_to_adopt": float(overlap["steps_to_adopt"]),
        "overlap_blocked_us": overlap["overlap_blocked_us"],
        "sync_blocked_us": overlap["sync_blocked_us"],
        "overlap_bitexact": float(overlap["bitexact"]),
    }
    derived = (f"store_speedup={head['store_speedup']:.2f}x "
               f"switch_stall={head['switch_wall_us']:.0f}us "
               f"moved={head['switch_bytes'] / 1e6:.1f}MB "
               f"overlap_hidden={100 * overlap['hidden_fraction']:.0f}%")
    return summary, derived


if __name__ == "__main__":
    run(verbose=True)
