"""CI bench-regression gate.

Usage:
  python -m benchmarks.check_regression BENCH_smoke.json \
      [--baseline benchmarks/baseline.json] [--tol 0.25]

QUALITATIVE regression gate: checks the invariants that must hold on any
machine (wall-time and per-metric bands live in
``benchmarks/check_trend.py`` against ``benchmarks/references.json``).
Compares a fresh ``benchmarks/run.py --smoke --json`` document against
the committed baseline and FAILS (exit 1) when:

  * the current document is structurally empty (missing/empty ``benches``
    or no positive ``total_wall_s`` — a truncated or failed run must
    never read as a pass),
  * any bench that passed in the baseline now fails,
  * the dispatch bench's measured pack speedup fell below 1.0 (the sort
    hot path must never be slower than the one-hot oracle it replaced),
  * the migration bench's store speedup fell below 1.0 (persistent replica
    buffers must never be slower than the per-step pool gather),
  * overlapped migration hides less than half the plan-switch stall, or
    its final store diverges from the synchronous path (bit-exactness),
  * the meshed continuous-serving smoke recompiled after warmup or missed
    its step-time SLO,
  * the serving trace artifact failed schema validation / lost required
    spans (``trace_ok``), or the disabled tracer's estimated per-step
    cost reached 1% of a meshed serving step, or
  * the serving bench's fused-vs-gather paged-attention roofline ratio
    fell below 1.0 (allocated / live KV blocks — the fused kernel must
    never compute more blocks than the gather view materializes).

Escape hatch: set ``REPRO_BENCH_REFRESH_BASELINE=1`` to overwrite the
baseline with the current measurement instead of gating (use when a
deliberate change moves the floor; commit the refreshed file).
"""

from __future__ import annotations

import json
import os
import sys


def structurally_empty(doc: dict) -> list:
    """Failures for a truncated/failed document. A run that crashed before
    writing any bench (``"benches": {}`` and no ``total_wall_s``) used to
    sail through every per-bench comparison and exit 0; an empty document
    must be a loud failure, never a pass."""
    failures = []
    if not isinstance(doc.get("benches"), dict) or not doc.get("benches"):
        failures.append("document is structurally empty: no benches "
                        "recorded (truncated or failed run)")
    total = doc.get("total_wall_s")
    if not isinstance(total, (int, float)) or total <= 0:
        failures.append("document has no positive total_wall_s "
                        f"(got {total!r})")
    return failures


def compare(current: dict, baseline: dict, tol: float = 0.0) -> list:
    """Returns a list of human-readable failures (empty = gate passes)."""
    failures = structurally_empty(current)
    if structurally_empty(baseline):
        failures.append("committed baseline is structurally empty — "
                        "refresh it from a healthy run")
    for name, base_rec in baseline.get("benches", {}).items():
        cur_rec = current.get("benches", {}).get(name)
        if cur_rec is None:
            failures.append(f"bench disappeared from the suite: {name}")
            continue
        if base_rec.get("ok") and not cur_rec.get("ok"):
            failures.append(f"bench now failing: {name}: "
                            f"{cur_rec.get('derived')}")
    disp = (current.get("benches", {})
            .get("dispatch_phase_breakdown", {}).get("summary") or {})
    speedup = disp.get("pack_speedup")
    if speedup is not None and speedup < 1.0:
        failures.append(
            f"sort dispatch slower than the one-hot oracle: "
            f"pack_speedup={speedup:.2f}x")
    mig = (current.get("benches", {})
           .get("migration_store_vs_gather", {}).get("summary") or {})
    store_speedup = mig.get("min_store_speedup", mig.get("store_speedup"))
    if store_speedup is not None and store_speedup < 1.0:
        failures.append(
            f"replica store slower than the per-step gather it replaces: "
            f"store_speedup={store_speedup:.2f}x")
    hidden = mig.get("overlap_hidden_fraction")
    if hidden is not None and hidden < 0.5:
        failures.append(
            f"overlapped migration hides <50% of the plan-switch stall: "
            f"hidden_fraction={hidden:.2f}")
    bitexact = mig.get("overlap_bitexact")
    if bitexact is not None and bitexact != 1.0:
        failures.append(
            "overlapped migration diverged from the synchronous path "
            "(bit-exactness check failed)")
    serve = (current.get("benches", {})
             .get("serve_traces_continuous", {}).get("summary") or {})
    if serve.get("meshed_recompiled", 0.0):
        failures.append(
            "meshed continuous serving recompiled after warmup")
    if serve.get("meshed_slo_ok", 1.0) != 1.0:
        failures.append(
            f"meshed serving step-time SLO missed: "
            f"p50={serve.get('meshed_step_p50_ms', 0):.0f}ms > "
            f"{serve.get('meshed_slo_ms', 0):.0f}ms")
    if serve.get("trace_ok", 1.0) != 1.0:
        failures.append(
            "serve trace artifact failed Chrome trace-event schema "
            "validation or is missing required spans (trace_ok != 1)")
    off_frac = serve.get("tracer_off_overhead_frac")
    if off_frac is not None and off_frac >= 0.01:
        failures.append(
            f"disabled tracer costs {100 * off_frac:.1f}% of a meshed "
            f"serving step (budget 1%)")
    attn_speedup = serve.get("fused_vs_gather_speedup")
    if attn_speedup is not None and attn_speedup < 1.0:
        failures.append(
            f"fused paged-attention roofline below the gather oracle: "
            f"fused_vs_gather_speedup={attn_speedup:.2f}x (the fused "
            f"kernel can never cover MORE blocks than the gather view)")
    return failures


def report(current: dict, baseline: dict):
    print(f"{'bench':32s} {'baseline':>12s} {'current':>12s} {'delta':>8s}")
    for name, base_rec in baseline.get("benches", {}).items():
        cur_rec = current.get("benches", {}).get(name, {})
        b, c = base_rec.get("wall_us", 0.0), cur_rec.get("wall_us", 0.0)
        delta = f"{100 * (c / b - 1):+5.0f}%" if b else "n/a"
        print(f"{name:32s} {b / 1e6:11.1f}s {c / 1e6:11.1f}s {delta:>8s}")
    print(f"{'TOTAL':32s} {baseline.get('total_wall_s', 0.0):11.1f}s "
          f"{current.get('total_wall_s', 0.0):11.1f}s")


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    tol = 0.25
    baseline_path = os.path.join(os.path.dirname(__file__), "baseline.json")
    if "--tol" in argv:
        i = argv.index("--tol")
        tol = float(argv[i + 1])
        del argv[i:i + 2]
    if "--baseline" in argv:
        i = argv.index("--baseline")
        baseline_path = argv[i + 1]
        del argv[i:i + 2]
    if len(argv) != 1:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        current = json.load(f)

    if os.environ.get("REPRO_BENCH_REFRESH_BASELINE") == "1":
        empty = structurally_empty(current)
        if empty:
            print("refusing to refresh the baseline from a broken "
                  "document:", file=sys.stderr)
            for msg in empty:
                print(f"  - {msg}", file=sys.stderr)
            return 1
        with open(baseline_path, "w") as f:
            json.dump(current, f, indent=2)
        print(f"baseline refreshed from {argv[0]} -> {baseline_path} "
              "(commit the updated file)")
        return 0

    if not os.path.exists(baseline_path):
        print(f"no baseline at {baseline_path}; run with "
              "REPRO_BENCH_REFRESH_BASELINE=1 to create one", file=sys.stderr)
        return 2
    with open(baseline_path) as f:
        baseline = json.load(f)

    report(current, baseline)
    failures = compare(current, baseline, tol)
    if failures:
        print("\nBENCH REGRESSION GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        print("  (deliberate change? refresh with "
              "REPRO_BENCH_REFRESH_BASELINE=1 and commit baseline.json)",
              file=sys.stderr)
        return 1
    print("\nbench-regression gate: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
