"""End-to-end trace-driven serving: continuous batching + online GPS.

Replays a bursty, skew-shifting request trace (repro.workloads) through
the continuous-batching engine with the online GPS controller attached,
on CPU with the dense reference MoE path. Reports SLO metrics (TTFT /
TPOT / p99 latency, goodput), per-window measured skew and the per-rank
load imbalance the engine's ACTIVE duplication plan would produce on a
4-rank EP deployment, and the controller's strategy-switch log.

Checked invariants (this benchmark doubles as the subsystem's
acceptance test — tests/test_continuous_serve.py calls ``run`` too):
  * every request in the trace completes;
  * the controller switches strategy at least once as the trace's topic
    mixture (and hence measured skew) shifts;
  * zero XLA recompilation after ``warmup()``.
"""

from __future__ import annotations

import os

import jax
import numpy as np


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def run(verbose: bool = True, smoke: bool = None):
    from repro.configs.registry import get_config
    from repro.core.predictors import ConditionalProbabilityModel
    from repro.core.simulator import A100_PCIE
    from repro.data.synthetic import make_routing_trace
    from repro.models.transformer import init_model
    from repro.serve import (ContinuousConfig, ContinuousEngine,
                             ControllerConfig, OnlineGPSController)
    from repro.workloads import skew_shift_trace, to_serve_requests

    if smoke is None:
        smoke = _smoke()
    cfg = get_config("mixtral-8x7b").reduced()
    full_cfg = get_config("mixtral-8x7b")      # controller simulates the
    params = init_model(jax.random.PRNGKey(0), cfg)   # production point

    horizon, rate = (24.0, 2.0) if smoke else (90.0, 1.5)
    trace = skew_shift_trace(cfg.vocab_size, horizon=horizon, rate=rate,
                             seed=0)

    # Token-to-Expert predictor (conditional-frequency ladder rung), fit on
    # a synthetic routing profile — its presence unlocks the t2e strategy.
    prof = make_routing_trace(num_sequences=32, seq_len=32,
                              vocab=cfg.vocab_size,
                              num_experts=cfg.moe.num_experts,
                              num_layers=cfg.num_layers, skew=1.8, seed=0)
    predictor = ConditionalProbabilityModel(
        cfg.num_layers, cfg.moe.num_experts, cfg.vocab_size
    ).fit(prof.experts, prof.tokens)

    controller = OnlineGPSController(
        full_cfg,
        ControllerConfig(
            hardware=A100_PCIE, window_iters=8, patience=1, min_saving=0.02,
            # skew is measured on the reduced smoke model but the guideline
            # is evaluated at the production point: transfer the scales
            skew_cap_observed=cfg.moe.num_experts / cfg.moe.top_k,
            skew_cap_target=full_cfg.moe.num_experts / full_cfg.moe.top_k),
        predictor_available=True, initial_strategy="dist_only")

    ccfg = ContinuousConfig(max_slots=8, prefill_len=64, block_size=16,
                            max_len=96, strategy="dist_only",
                            predict_interval=4, dup_slots=1,
                            metrics_window=8)
    eng = ContinuousEngine(cfg, params, ccfg, ep_ranks=4,
                           predictor=predictor, controller=controller)
    eng.warmup()
    end = eng.run_trace(to_serve_requests(trace), time_scale=20.0)
    eng.assert_no_recompiles()

    phases = eng.profile_phases(iters=2 if smoke else 5)
    s = eng.metrics.summary()
    n_completed = int(s["completed"])
    n_switches = controller.num_switches

    if verbose:
        print(f"trace: {len(trace)} requests over {horizon:.0f}s (virtual), "
              f"served by {end:.1f}s | iterations={eng.iterations}")
        print(f"TTFT   p50={s['ttft_p50']*1e3:7.1f}ms  "
              f"p99={s['ttft_p99']*1e3:7.1f}ms")
        print(f"TPOT  mean={s['tpot_mean']*1e3:7.1f}ms  "
              f"p99={s['tpot_p99']*1e3:7.1f}ms")
        print(f"E2E    p50={s['latency_p50']*1e3:7.1f}ms  "
              f"p99={s['latency_p99']*1e3:7.1f}ms | "
              f"{s['throughput_tok_s']:.0f} tok/s, "
              f"{s['throughput_req_s']:.2f} req/s, "
              f"preemptions={int(s['preemptions'])}")
        print("\nwindow  t_end   skew  imbalance  strategy")
        for w in eng.metrics.windows:
            print(f"  {w.t_end:8.1f}s {w.skew:5.2f}  {w.imbalance:9.2f}  "
                  f"{w.strategy}")
        print("\ncontroller switches:")
        for line in controller.switch_log():
            print("  " + line)
        print(f"\nreplica migration: replans={int(s['migration_replans'])} "
              f"planned={s['migration_planned_bytes'] / 1e6:.2f}MB "
              f"moved={s['migration_bytes_moved'] / 1e6:.2f}MB "
              f"stall={s['migration_stall_us']:.0f}us "
              f"rejected={int(s['migration_rejected'])}")
        if phases:
            print("\ndispatch phase breakdown (prefill shape, "
                  f"impl={eng.moe_cfg.dispatch_impl}):")
            total = phases.get("total", 0.0) or 1.0
            for k in ("route", "pack", "a2a", "ffn", "combine"):
                print(f"  {k:8s} {phases[k]*1e6:9.0f}us "
                      f"({100.0 * phases[k] / total:4.1f}%)")
            if "migrate" in phases:
                print(f"  {'migrate':8s} {phases['migrate']*1e6:9.0f}us "
                      "(per plan-switch chunk, not per step)")

    assert n_completed == len(trace), (n_completed, len(trace))
    if not smoke:
        assert n_switches >= 1, "controller never switched strategy"

    derived = (f"completed={n_completed}/{len(trace)} "
               f"switches={n_switches} "
               f"ttft_p99={s['ttft_p99']*1e3:.0f}ms "
               f"tpot_p99={s['tpot_p99']*1e3:.0f}ms")
    return s, derived


if __name__ == "__main__":
    run(verbose=True)
