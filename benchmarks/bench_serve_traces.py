"""End-to-end trace-driven serving: continuous batching + online GPS.

Replays a bursty, skew-shifting request trace (repro.workloads) through
the continuous-batching engine with the online GPS controller attached,
on CPU with the dense reference MoE path. Reports SLO metrics (TTFT /
TPOT / p99 latency, goodput), per-window measured skew and the per-rank
load imbalance the engine's ACTIVE duplication plan would produce on a
4-rank EP deployment, and the controller's strategy-switch log.

Checked invariants (this benchmark doubles as the subsystem's
acceptance test — tests/test_continuous_serve.py calls ``run`` too):
  * every request in the trace completes;
  * the controller switches strategy at least once as the trace's topic
    mixture (and hence measured skew) shifts;
  * zero XLA recompilation after ``warmup()``.

A second, MESHED smoke section (subprocess, 8 fake host devices) runs the
ContinuousEngine on a real EP mesh in store mode with overlapped
migration, and reports a step-time SLO column: ``meshed_step_p50_ms``
against ``meshed_slo_ms``, plus the backend-compile count after warmup.
``check_regression`` gates both (no recompiles, SLO met).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


# Step-time SLO for the meshed smoke deployment (p50, generous: CPU CI
# machines vary ~2x; a recompile-per-step regression blows through it by
# an order of magnitude, which is what the column is there to catch).
MESHED_SLO_MS = 2500.0

_MESHED_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, numpy as np
from repro.configs.registry import get_config
from repro.models.transformer import init_model
from repro.serve import ContinuousConfig, ContinuousEngine
from repro.serve.scheduler import ServeRequest

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("mixtral-8x7b").reduced()
params = init_model(jax.random.PRNGKey(0), cfg)
ccfg = ContinuousConfig(max_slots=4, prefill_len=32, block_size=16,
                        max_len=48, strategy="dist_only",
                        predict_interval=4, dup_slots=1, metrics_window=4)
eng = ContinuousEngine(cfg, params, ccfg, mesh=mesh, ep_ranks=4)
eng.warmup()
rng = np.random.default_rng(0)
for i in range(6):
    eng.submit(ServeRequest(rid=i, arrival=0.0,
                            tokens=rng.integers(0, cfg.vocab_size,
                                                16).tolist(),
                            max_new_tokens=4))
walls = []
n = 0
while eng.has_work() and n < 40:
    t0 = time.perf_counter()
    eng.step(float(n))
    walls.append(time.perf_counter() - t0)
    n += 1
recompiled = 0
try:
    eng.assert_no_recompiles()
except AssertionError:
    recompiled = 1
eng.metrics.flush(eng._plan_stack, eng.ep_ranks, 1)
s = eng.metrics.summary()
print(json.dumps({
    "step_p50_ms": float(np.percentile(walls, 50) * 1e3),
    "step_p99_ms": float(np.percentile(walls, 99) * 1e3),
    "iterations": n,
    "recompiled": recompiled,
    "completed": int(s["completed"]),
    "migration_commits": s["migration_commits"],
    "migration_hidden_s": s["migration_hidden_s"],
}))
"""


def _run_meshed() -> dict:
    import repro
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_MESHED_SUB)],
        capture_output=True, text=True, timeout=1800,
        env=dict(os.environ, PYTHONPATH=src_root))
    if out.returncode != 0:
        raise RuntimeError(
            f"meshed serve subprocess failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(verbose: bool = True, smoke: bool = None):
    from repro.configs.registry import get_config
    from repro.core.predictors import ConditionalProbabilityModel
    from repro.core.simulator import A100_PCIE
    from repro.data.synthetic import make_routing_trace
    from repro.models.transformer import init_model
    from repro.serve import (ContinuousConfig, ContinuousEngine,
                             ControllerConfig, OnlineGPSController)
    from repro.workloads import skew_shift_trace, to_serve_requests

    if smoke is None:
        smoke = _smoke()
    cfg = get_config("mixtral-8x7b").reduced()
    full_cfg = get_config("mixtral-8x7b")      # controller simulates the
    params = init_model(jax.random.PRNGKey(0), cfg)   # production point

    horizon, rate = (24.0, 2.0) if smoke else (90.0, 1.5)
    trace = skew_shift_trace(cfg.vocab_size, horizon=horizon, rate=rate,
                             seed=0)

    # Token-to-Expert predictor (conditional-frequency ladder rung), fit on
    # a synthetic routing profile — its presence unlocks the t2e strategy.
    prof = make_routing_trace(num_sequences=32, seq_len=32,
                              vocab=cfg.vocab_size,
                              num_experts=cfg.moe.num_experts,
                              num_layers=cfg.num_layers, skew=1.8, seed=0)
    predictor = ConditionalProbabilityModel(
        cfg.num_layers, cfg.moe.num_experts, cfg.vocab_size
    ).fit(prof.experts, prof.tokens)

    controller = OnlineGPSController(
        full_cfg,
        ControllerConfig(
            hardware=A100_PCIE, window_iters=8, patience=1, min_saving=0.02,
            # skew is measured on the reduced smoke model but the guideline
            # is evaluated at the production point: transfer the scales
            skew_cap_observed=cfg.moe.num_experts / cfg.moe.top_k,
            skew_cap_target=full_cfg.moe.num_experts / full_cfg.moe.top_k),
        predictor_available=True, initial_strategy="dist_only")

    ccfg = ContinuousConfig(max_slots=8, prefill_len=64, block_size=16,
                            max_len=96, strategy="dist_only",
                            predict_interval=4, dup_slots=1,
                            metrics_window=8)
    eng = ContinuousEngine(cfg, params, ccfg, ep_ranks=4,
                           predictor=predictor, controller=controller)
    eng.warmup()
    end = eng.run_trace(to_serve_requests(trace), time_scale=20.0)
    eng.assert_no_recompiles()

    phases = eng.profile_phases(iters=2 if smoke else 5)
    s = eng.metrics.summary()
    n_completed = int(s["completed"])
    n_switches = controller.num_switches

    meshed = _run_meshed()
    s = dict(s,
             meshed_step_p50_ms=meshed["step_p50_ms"],
             meshed_step_p99_ms=meshed["step_p99_ms"],
             meshed_recompiled=float(meshed["recompiled"]),
             meshed_completed=float(meshed["completed"]),
             meshed_slo_ms=MESHED_SLO_MS,
             meshed_slo_ok=float(meshed["step_p50_ms"] <= MESHED_SLO_MS))

    if verbose:
        print(f"trace: {len(trace)} requests over {horizon:.0f}s (virtual), "
              f"served by {end:.1f}s | iterations={eng.iterations}")
        print(f"TTFT   p50={s['ttft_p50']*1e3:7.1f}ms  "
              f"p99={s['ttft_p99']*1e3:7.1f}ms")
        print(f"TPOT  mean={s['tpot_mean']*1e3:7.1f}ms  "
              f"p99={s['tpot_p99']*1e3:7.1f}ms")
        print(f"E2E    p50={s['latency_p50']*1e3:7.1f}ms  "
              f"p99={s['latency_p99']*1e3:7.1f}ms | "
              f"{s['throughput_tok_s']:.0f} tok/s, "
              f"{s['throughput_req_s']:.2f} req/s, "
              f"preemptions={int(s['preemptions'])}")
        print("\nwindow  t_end   skew  imbalance  strategy")
        for w in eng.metrics.windows:
            print(f"  {w.t_end:8.1f}s {w.skew:5.2f}  {w.imbalance:9.2f}  "
                  f"{w.strategy}")
        print("\ncontroller switches:")
        for line in controller.switch_log():
            print("  " + line)
        print(f"\nreplica migration: replans={int(s['migration_replans'])} "
              f"planned={s['migration_planned_bytes'] / 1e6:.2f}MB "
              f"moved={s['migration_bytes_moved'] / 1e6:.2f}MB "
              f"stall={s['migration_stall_us']:.0f}us "
              f"(hidden={s['migration_hidden_s']*1e6:.0f}us / "
              f"exposed={s['migration_exposed_s']*1e6:.0f}us) "
              f"rejected={int(s['migration_rejected'])} "
              f"prebegun={int(s['migration_prebegun'])} "
              f"cancelled={int(s['migration_cancelled'])}")
        print(f"meshed EP smoke: step p50={s['meshed_step_p50_ms']:.0f}ms "
              f"p99={s['meshed_step_p99_ms']:.0f}ms "
              f"(SLO {s['meshed_slo_ms']:.0f}ms -> "
              f"{'OK' if s['meshed_slo_ok'] else 'MISS'}), "
              f"recompiles={int(s['meshed_recompiled'])}, "
              f"completed={int(s['meshed_completed'])}")
        if phases:
            print("\ndispatch phase breakdown (prefill shape, "
                  f"impl={eng.moe_cfg.dispatch_impl}):")
            total = phases.get("total", 0.0) or 1.0
            for k in ("route", "pack", "a2a", "ffn", "combine"):
                print(f"  {k:8s} {phases[k]*1e6:9.0f}us "
                      f"({100.0 * phases[k] / total:4.1f}%)")
            if "migrate" in phases:
                print(f"  {'migrate':8s} {phases['migrate']*1e6:9.0f}us "
                      "(per plan-switch chunk, not per step)")
            if "prefetch" in phases:
                print(f"  {'prefetch':8s} {phases['prefetch']*1e6:9.0f}us "
                      "(overlapped-fill issue cost on the critical path)")

    assert n_completed == len(trace), (n_completed, len(trace))
    if not smoke:
        assert n_switches >= 1, "controller never switched strategy"

    derived = (f"completed={n_completed}/{len(trace)} "
               f"switches={n_switches} "
               f"ttft_p99={s['ttft_p99']*1e3:.0f}ms "
               f"tpot_p99={s['tpot_p99']*1e3:.0f}ms "
               f"meshed_p50={s['meshed_step_p50_ms']:.0f}ms")
    return s, derived


if __name__ == "__main__":
    run(verbose=True)
