"""End-to-end trace-driven serving: continuous batching + online GPS.

Replays a bursty, skew-shifting request trace (repro.workloads) through
the continuous-batching engine with the online GPS controller attached,
on CPU with the dense reference MoE path. Reports SLO metrics (TTFT /
TPOT / p99 latency, goodput), per-window measured skew and the per-rank
load imbalance the engine's ACTIVE duplication plan would produce on a
4-rank EP deployment, and the controller's strategy-switch log.

Observability artifacts per run (repro.obs):
  * ``BENCH_serve_trace.json`` — merged Chrome trace-event JSON (local
    driver + meshed subprocess as separate process rows; open in
    Perfetto) with admission/prefill/decode/observe spans, the
    route/pack/a2a/ffn/combine dispatch-profile track, plan-switch and
    GPS-verdict instants, and migration begin/tick/commit spans;
  * ``BENCH_gps_audit.json`` — every controller verdict with the full
    input vector ``recommend_strategy`` saw.

Checked invariants (this benchmark doubles as the subsystem's
acceptance test — tests/test_continuous_serve.py calls ``run`` too):
  * every request in the trace completes;
  * the controller switches strategy at least once as the trace's topic
    mixture (and hence measured skew) shifts;
  * zero XLA recompilation after ``warmup()``;
  * the merged trace validates against the Chrome trace-event schema and
    contains the dispatch-phase + plan-switch spans (``trace_ok``);
  * the GPS audit log carries >= 1 verdict and the predictor-accuracy
    tracker scored >= 1 prediction window;
  * the DISABLED tracer costs < 1% of a meshed serving step
    (``tracer_off_overhead_frac`` — instrumentation is unconditional, so
    its off-mode cost is a hard budget, gated by ``check_regression``).

A second, MESHED smoke section (subprocess, 8 fake host devices) runs the
ContinuousEngine on a real EP mesh in store mode with overlapped
migration, and reports a step-time SLO column: ``meshed_step_p50_ms``
against ``meshed_slo_ms``, plus the backend-compile count after warmup.
``check_regression`` gates both (no recompiles, SLO met).

A FLEET A/B section (subprocess, same fake-device mesh) hosts TWO model
instances through `repro.fleet.FleetEngine` under the ``fleet_shift``
traffic-shift trace and compares a static equal HBM split against the
cross-model arbiter: the static leg must visibly violate the hot (chat)
tenant's TTFT SLO, the arbiter leg must commit >= 1 quota move and
recover fleet SLO attainment, and both legs must hold zero post-warmup
recompiles (every move is a logical quota inside compiled shapes).
Columns: ``fleet_slo_attainment`` (arbiter leg, lower-banded),
``fleet_slo_attainment_static`` (trend), ``fleet_arbiter_moves``
(lower-banded), ``fleet_step_p50_ms`` / ``fleet_recompiled`` (gated like
the meshed smoke).

A third, DECODE-HEAVY section replays the ``decode_heavy`` workload
(sparse arrivals, short prompts, long outputs -> a long steady decode
tail after warm prefill) through fused- and gather-``paged_attn_impl``
engines on identical state, and reports the decode fast path columns:
``decode_toks_per_s`` (wall-clock decode throughput, fused leg, gated
with a lower reference band), ``fused_vs_gather_speedup`` (the
attention-compute roofline: allocated table blocks the gather oracle
attends over / live blocks the fused kernel computes, measured from
real engine block-table state — structurally >= 1.0, asserted here and
gated by ``check_regression``), ``attn_phase_decode_us`` (decode-shaped
attn kernel phase, upper-banded), and trend-only interpret-mode walls
(``attn_fused_us``/``attn_gather_us``, ``decode_ab_ratio``) — raw
interpret-mode kernel timings are not meaningful perf references on
CPU, the roofline ratio is the portable signal.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import textwrap
import time

import jax


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


# Step-time SLO for the meshed smoke deployment (p50, generous: CPU CI
# machines vary ~2x; a recompile-per-step regression blows through it by
# an order of magnitude, which is what the column is there to catch).
MESHED_SLO_MS = 2500.0

# Disabled-tracer budget: instrumentation is compiled in unconditionally,
# so with tracing OFF the per-step cost of all span/instant call sites
# must stay under 1% of a meshed serving step.
TRACER_OFF_BUDGET_FRAC = 0.01

# Conservative count of tracer call sites one engine step can hit (step +
# admission + 2 prefills + decode + observe spans, migration tick span +
# begin/commit instants, plan/gps instants, boundary counters).
_TRACER_OPS_PER_STEP = 24

_MESHED_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, numpy as np
from repro.configs.registry import get_config
from repro.models.transformer import init_model
from repro.obs import SpanTracer
from repro.serve import ContinuousConfig, ContinuousEngine
from repro.serve.scheduler import ServeRequest

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("mixtral-8x7b").reduced()
params = init_model(jax.random.PRNGKey(0), cfg)
ccfg = ContinuousConfig(max_slots=4, prefill_len=32, block_size=16,
                        max_len=48, strategy="dist_only",
                        predict_interval=4, dup_slots=1, metrics_window=4)
tracer = SpanTracer(process_name="repro-serve-meshed")
eng = ContinuousEngine(cfg, params, ccfg, mesh=mesh, ep_ranks=4,
                       tracer=tracer)
eng.warmup()
rng = np.random.default_rng(0)
for i in range(6):
    eng.submit(ServeRequest(rid=i, arrival=0.0,
                            tokens=rng.integers(0, cfg.vocab_size,
                                                16).tolist(),
                            max_new_tokens=4))
walls = []
n = 0
while eng.has_work() and n < 40:
    t0 = time.perf_counter()
    eng.step(float(n))
    walls.append(time.perf_counter() - t0)
    n += 1
recompiled = 0
try:
    eng.assert_no_recompiles()
except AssertionError:
    recompiled = 1
eng.metrics.flush(eng._plan_stack, eng.ep_ranks, 1)
s = eng.metrics.summary()
trace_out = os.environ.get("REPRO_TRACE_OUT")
if trace_out:
    tracer.export(trace_out)
print(json.dumps({
    "step_p50_ms": float(np.percentile(walls, 50) * 1e3),
    "step_p99_ms": float(np.percentile(walls, 99) * 1e3),
    "iterations": n,
    "recompiled": recompiled,
    "completed": int(s["completed"]),
    "migration_commits": s["migration_commits"],
    "migration_hidden_s": s["migration_hidden_s"],
}))
"""


# Lever A/B under genuine capacity pressure: constant-token prompts
# concentrate routing on one expert, capacity_factor 0.5 with
# prefill_len 64 over 4 EP ranks puts the hot slot well past the cap
# floor (8/rank). The duplicate-only leg measurably DROPS tokens; the
# reschedule leg must absorb every overflow via the scheduler quotas +
# rescue dispatch round, paying only extra a2a bytes.
_RESCHED_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, time
import jax, numpy as np
from repro.configs.registry import get_config
from repro.models.transformer import init_model
from repro.serve import ContinuousConfig, ContinuousEngine
from repro.serve.scheduler import ServeRequest

mesh = jax.make_mesh((2, 4), ("data", "model"))
base = get_config("mixtral-8x7b").reduced()
cfg = dataclasses.replace(base, moe=dataclasses.replace(
    base.moe, capacity_factor=0.5, duplication_slots=1))
params = init_model(jax.random.PRNGKey(0), cfg)
out = {}
for lever in ("duplicate", "reschedule"):
    ccfg = ContinuousConfig(max_slots=4, prefill_len=64, block_size=8,
                            max_len=96, strategy="dist_only",
                            predict_interval=4, dup_slots=1,
                            metrics_window=4, lever=lever)
    eng = ContinuousEngine(cfg, params, ccfg, mesh=mesh, ep_ranks=4)
    eng.warmup()
    rng = np.random.default_rng(0)
    for i in range(10):
        eng.submit(ServeRequest(rid=i, arrival=float(i) * 0.01,
                                tokens=np.full(int(rng.integers(40, 60)),
                                               7, np.int32),
                                max_new_tokens=int(rng.integers(1, 6))))
    walls, n = [], 0
    while eng.has_work() and n < 80:
        t0 = time.perf_counter()
        eng.step(float(n))
        walls.append(time.perf_counter() - t0)
        n += 1
    recompiled = 0
    try:
        eng.assert_no_recompiles()
    except AssertionError:
        recompiled = 1
    eng.metrics.flush(eng._plan_stack, eng.ep_ranks, 1)
    s = eng.metrics.summary()
    out[lever] = {
        "step_p50_ms": float(np.percentile(walls, 50) * 1e3),
        "completed": len(eng.scheduler.completed),
        "recompiled": recompiled,
        "dropped_tokens": float(s.get("dropped_tokens", -1.0)),
        "overflow_tokens": float(s.get("overflow_tokens", -1.0)),
        "overflow_absorbed_frac": float(
            s.get("overflow_absorbed_frac", -1.0)),
        "resched_a2a_bytes": float(s.get("resched_a2a_bytes", 0.0)),
        "resched_plans": float(s.get("resched_plans", 0.0)),
    }
print(json.dumps(out))
"""


# Fleet A/B under a traffic shift (fleet_shift workload: a chat tenant
# whose load ramps to 2x while a batch tenant stays flat). Both legs host
# the SAME two model instances on one 2x4 mesh with identical compiled
# shapes and a static equal KV split (12 of 24 pool blocks each, 1 of 2
# dup slots each); the arbiter leg may move quota between them, the
# static leg may not. The static split starves the chat model's KV share
# as the shift lands -> queued admissions -> TTFT SLO misses; the
# arbiter reads attainment/queue/skew pressure and moves KV-block (and
# dup-slot) quota toward it. All moves are quotas inside compiled
# shapes, so BOTH legs must hold zero post-warmup recompiles.
_FLEET_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, numpy as np
from repro.configs.registry import get_config
from repro.fleet import (ArbiterConfig, BATCH, FleetAdmission, FleetEngine,
                         FleetModelSpec, SLOClass)
from repro.models.transformer import init_model
from repro.serve import ContinuousConfig
from repro.sweep.workloads import build_workload
from repro.workloads import to_serve_requests

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("mixtral-8x7b").reduced()
params = init_model(jax.random.PRNGKey(0), cfg)
ccfg = ContinuousConfig(max_slots=4, prefill_len=32, block_size=8,
                        max_len=48, strategy="dist_only",
                        predict_interval=4, dup_slots=2, metrics_window=4,
                        max_prefills_per_step=2)
trace = build_workload("fleet_shift", cfg.vocab_size, horizon=20.0,
                       rate=1.2, seed=0)
DT = 0.25
MAX_ITERS = 320

def run_leg(enable_arbiter):
    adm = FleetAdmission(
        routes={"chat": "m-chat", "batch": "m-batch"},
        slos={"chat": SLOClass("chat", slo_ttft=2.0, slo_tpot=1.0),
              "batch": BATCH})
    specs = [FleetModelSpec(n, cfg, params, ccfg,
                            dup_slot_quota=1, kv_block_quota=12)
             for n in ("m-chat", "m-batch")]
    fleet = FleetEngine(
        specs, mesh=mesh, ep_ranks=4, admission=adm,
        arbiter_cfg=ArbiterConfig(window_iters=8, patience=2,
                                  queue_norm=4.0, kv_blocks_per_move=4,
                                  kv_floor_blocks=4),
        enable_arbiter=enable_arbiter)
    fleet.warmup()
    for r in sorted(to_serve_requests(trace), key=lambda r: r.arrival):
        fleet.submit(r)
    now, n = 0.0, 0
    while fleet.has_work() and n < MAX_ITERS:
        fleet.step(now)
        now += DT
        n += 1
    recompiled = 0
    try:
        fleet.assert_no_recompiles()
    except AssertionError:
        recompiled = 1
    for eng in fleet.engines.values():
        eng.metrics.flush(eng._plan_stack, eng.ep_ranks,
                          eng.moe_cfg.duplication_slots)
    s = fleet.summary()
    return {
        "fleet_slo_attainment": s["fleet_slo_attainment"],
        "fleet_slo_attainment_worst": s["fleet_slo_attainment_worst"],
        "fleet_arbiter_moves": s["fleet_arbiter_moves"],
        "fleet_step_p50_ms": s["fleet_step_p50_ms"],
        "fleet_step_p99_ms": s["fleet_step_p99_ms"],
        "fleet_completed": s["fleet_completed"],
        "chat_attainment": adm.model_attainment(
            fleet.engines["m-chat"].metrics, "m-chat"),
        "batch_attainment": adm.model_attainment(
            fleet.engines["m-batch"].metrics, "m-batch"),
        "chat_kv_quota": s["m-chat_kv_block_quota"],
        "batch_kv_quota": s["m-batch_kv_block_quota"],
        "chat_dup_quota": s["m-chat_dup_slot_quota"],
        "recompiled": recompiled,
        "drained": float(not fleet.has_work()),
        "iterations": n,
        "moves": (fleet.arbiter.explain().splitlines()
                  if fleet.arbiter else []),
    }

out = {"submitted": len(trace),
       "static": run_leg(False), "arbiter": run_leg(True)}
print(json.dumps(out))
"""


def _run_fleet_ab(attempts: int = 2) -> dict:
    import repro
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    last = None
    for _ in range(attempts):
        try:
            out = subprocess.run(
                [sys.executable, "-c", textwrap.dedent(_FLEET_SUB)],
                capture_output=True, text=True, timeout=1500,
                env=dict(os.environ, PYTHONPATH=src_root))
        except subprocess.TimeoutExpired as e:
            last = f"timed out after {e.timeout}s"
            continue
        if out.returncode == 0:
            return json.loads(out.stdout.strip().splitlines()[-1])
        last = out.stderr[-2000:]
    raise RuntimeError(f"fleet A/B subprocess failed:\n{last}")


def _run_resched_ab(attempts: int = 2) -> dict:
    import repro
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    # the multi-device XLA CPU client rarely deadlocks at startup under a
    # fake-device mesh; a bounded timeout + one clean retry beats hanging
    # the whole bench suite on it
    last = None
    for _ in range(attempts):
        try:
            out = subprocess.run(
                [sys.executable, "-c", textwrap.dedent(_RESCHED_SUB)],
                capture_output=True, text=True, timeout=900,
                env=dict(os.environ, PYTHONPATH=src_root))
        except subprocess.TimeoutExpired as e:
            last = f"timed out after {e.timeout}s"
            continue
        if out.returncode == 0:
            return json.loads(out.stdout.strip().splitlines()[-1])
        last = out.stderr[-2000:]
    raise RuntimeError(f"resched A/B subprocess failed:\n{last}")


def _run_decode_heavy(cfg, params, smoke: bool) -> dict:
    """Fused-vs-gather paged-attention A/B on the decode_heavy workload:
    both engines replay the SAME trace, differing only in
    ``paged_attn_impl``. Emits the fused leg's wall-clock decode
    throughput and roofline ratio, the legs' throughput ratio, and an
    interleaved best-of kernel-level impl timing at the deployment's
    pool shapes."""
    import dataclasses

    from repro.moe.profile import attn_impl_times
    from repro.serve import ContinuousConfig, ContinuousEngine
    from repro.sweep.workloads import build_workload
    from repro.workloads import to_serve_requests

    horizon = 16.0 if smoke else 40.0
    trace = build_workload("decode_heavy", cfg.vocab_size,
                           horizon=horizon, rate=1.5, seed=0)
    ccfg = ContinuousConfig(max_slots=8, prefill_len=32, block_size=16,
                            max_len=96, strategy="none", metrics_window=8)
    legs = {}
    for impl in ("fused", "gather"):
        eng = ContinuousEngine(
            dataclasses.replace(cfg, paged_attn_impl=impl), params, ccfg)
        eng.warmup()
        eng.run_trace(to_serve_requests(trace), time_scale=20.0)
        eng.assert_no_recompiles()
        legs[impl] = eng.metrics.summary()
    ab = attn_impl_times(
        batch=ccfg.max_slots, num_kv=cfg.num_kv_heads,
        gqa=max(cfg.num_heads // cfg.num_kv_heads, 1),
        head_dim=cfg.head_dim, block_size=ccfg.block_size,
        max_blocks=ccfg.max_len // ccfg.block_size,
        window=cfg.sliding_window, iters=2 if smoke else 5)
    fused, gather = legs["fused"], legs["gather"]
    return {
        "decode_toks_per_s": fused.get("decode_toks_per_s", 0.0),
        "fused_vs_gather_speedup":
            fused.get("fused_vs_gather_speedup", 0.0),
        "decode_ab_ratio": (fused.get("decode_toks_per_s", 0.0)
                            / max(gather.get("decode_toks_per_s", 0.0),
                                  1e-9)),
        "attn_fused_us": ab["fused"] * 1e6,
        "attn_gather_us": ab["gather"] * 1e6,
        "decode_completed": fused["completed"],
        "decode_completed_gather": gather["completed"],
        "decode_trace_requests": float(len(trace)),
    }


def _run_meshed(trace_out: str) -> dict:
    import repro
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_MESHED_SUB)],
        capture_output=True, text=True, timeout=1800,
        env=dict(os.environ, PYTHONPATH=src_root,
                 REPRO_TRACE_OUT=trace_out))
    if out.returncode != 0:
        raise RuntimeError(
            f"meshed serve subprocess failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def _tracer_off_overhead_frac(step_p50_s: float) -> float:
    """Microbenchmark the DISABLED tracer's per-call cost and scale it to
    one meshed serving step. A direct on/off A/B of full steps would be
    drowned by CI machine noise; the disabled path is pure Python with no
    shared state, so cost-per-op x sites-per-step is both stable and an
    upper bound (the estimate assumes every site fires every step)."""
    from repro.obs import SpanTracer
    off = SpanTracer(capacity=16, enabled=False)
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with off.span("x"):
            pass
    span_cost = (time.perf_counter() - t0) / n
    t0 = time.perf_counter()
    for _ in range(n):
        off.instant("x")
    inst_cost = (time.perf_counter() - t0) / n
    per_step = _TRACER_OPS_PER_STEP * max(span_cost, inst_cost)
    return per_step / max(step_p50_s, 1e-9)


def run(verbose: bool = True, smoke: bool = None):
    from repro.configs.registry import get_config
    from repro.core.predictors import ConditionalProbabilityModel
    from repro.core.simulator import A100_PCIE
    from repro.data.synthetic import make_routing_trace
    from repro.models.transformer import init_model
    from repro.obs import (SpanTracer, merge_traces, span_names,
                           validate_chrome_trace)
    from repro.serve import (ContinuousConfig, ContinuousEngine,
                             ControllerConfig, OnlineGPSController)
    from repro.workloads import skew_shift_trace, to_serve_requests

    if smoke is None:
        smoke = _smoke()
    cfg = get_config("mixtral-8x7b").reduced()
    full_cfg = get_config("mixtral-8x7b")      # controller simulates the
    params = init_model(jax.random.PRNGKey(0), cfg)   # production point

    horizon, rate = (24.0, 2.0) if smoke else (90.0, 1.5)
    trace = skew_shift_trace(cfg.vocab_size, horizon=horizon, rate=rate,
                             seed=0)

    # Token-to-Expert predictor (conditional-frequency ladder rung), fit on
    # a synthetic routing profile — its presence unlocks the t2e strategy.
    prof = make_routing_trace(num_sequences=32, seq_len=32,
                              vocab=cfg.vocab_size,
                              num_experts=cfg.moe.num_experts,
                              num_layers=cfg.num_layers, skew=1.8, seed=0)
    predictor = ConditionalProbabilityModel(
        cfg.num_layers, cfg.moe.num_experts, cfg.vocab_size
    ).fit(prof.experts, prof.tokens)

    controller = OnlineGPSController(
        full_cfg,
        ControllerConfig(
            hardware=A100_PCIE, window_iters=8, patience=1, min_saving=0.02,
            # skew is measured on the reduced smoke model but the guideline
            # is evaluated at the production point: transfer the scales
            skew_cap_observed=cfg.moe.num_experts / cfg.moe.top_k,
            skew_cap_target=full_cfg.moe.num_experts / full_cfg.moe.top_k),
        predictor_available=True, initial_strategy="dist_only")

    tracer = SpanTracer(process_name="repro-serve-local")
    ccfg = ContinuousConfig(max_slots=8, prefill_len=64, block_size=16,
                            max_len=96, strategy="dist_only",
                            predict_interval=4, dup_slots=1,
                            metrics_window=8)
    eng = ContinuousEngine(cfg, params, ccfg, ep_ranks=4,
                           predictor=predictor, controller=controller,
                           tracer=tracer)
    eng.warmup()
    end = eng.run_trace(to_serve_requests(trace), time_scale=20.0)
    eng.assert_no_recompiles()

    # prefill-shaped dispatch profile -> the phase_*_us columns, then
    # reset and re-profile at the decode batch shape -> decode_phase_*_us
    # (without reset_phases the second profile would double-accumulate)
    phases = eng.profile_phases(iters=2 if smoke else 5)
    s = eng.metrics.summary()
    eng.metrics.reset_phases()
    dec_phases = eng.profile_phases(iters=2 if smoke else 5,
                                    tokens=ccfg.max_slots)
    s.update({f"decode_phase_{k}_us": v * 1e6 for k, v in dec_phases.items()})

    n_completed = int(s["completed"])
    n_switches = controller.num_switches
    audit = controller.audit

    out_dir = os.environ.get("REPRO_BENCH_OUT", ".")
    with tempfile.TemporaryDirectory() as td:
        meshed_trace_path = os.path.join(td, "meshed_trace.json")
        meshed = _run_meshed(meshed_trace_path)
        with open(meshed_trace_path) as f:
            meshed_doc = json.load(f)
    resched_ab = _run_resched_ab()
    dup_leg, res_leg = resched_ab["duplicate"], resched_ab["reschedule"]
    decode_ab = _run_decode_heavy(cfg, params, smoke)
    fleet_ab = _run_fleet_ab()
    fleet_static, fleet_arb = fleet_ab["static"], fleet_ab["arbiter"]

    merged = merge_traces([tracer.to_chrome(), meshed_doc],
                          names=["repro-serve-local", "repro-serve-meshed"])
    merged["otherData"]["gps_audit"] = audit.to_obj()
    merged["otherData"]["pred_accuracy"] = eng.accuracy.to_obj()
    trace_path = os.path.join(out_dir, "BENCH_serve_trace.json")
    with open(trace_path, "w") as f:
        json.dump(merged, f)
    audit_path = os.path.join(out_dir, "BENCH_gps_audit.json")
    with open(audit_path, "w") as f:
        json.dump({"records": audit.to_obj(), "summary": audit.summary(),
                   "switches": [r.explain() for r in audit.switches]}, f,
                  indent=2)

    # schema + span-presence validation of the artifact CI uploads
    errors = validate_chrome_trace(merged)
    names = span_names(merged)
    required = {"attn", "route", "pack", "a2a", "ffn", "combine",
                "step", "plan.switch", "gps.decision"}
    if meshed["migration_commits"] > 0:
        required |= {"migration.tick", "migration.commit"}
    missing = sorted(required - names)
    trace_ok = float(not errors and not missing)

    overhead_frac = _tracer_off_overhead_frac(meshed["step_p50_ms"] / 1e3)

    s = dict(s,
             meshed_step_p50_ms=meshed["step_p50_ms"],
             meshed_step_p99_ms=meshed["step_p99_ms"],
             meshed_recompiled=float(meshed["recompiled"]),
             meshed_completed=float(meshed["completed"]),
             meshed_slo_ms=MESHED_SLO_MS,
             meshed_slo_ok=float(meshed["step_p50_ms"] <= MESHED_SLO_MS),
             trace_ok=trace_ok,
             trace_events=float(len(merged["traceEvents"])),
             tracer_off_overhead_frac=overhead_frac,
             # lever A/B at capacity pressure: duplicate-only drops, the
             # reschedule lever must absorb the same overflow dropless
             dup_dropped_tokens=dup_leg["dropped_tokens"],
             resched_dropped_tokens=res_leg["dropped_tokens"],
             overflow_tokens=res_leg["overflow_tokens"],
             overflow_absorbed_frac=res_leg["overflow_absorbed_frac"],
             resched_a2a_bytes=res_leg["resched_a2a_bytes"],
             resched_plans=res_leg["resched_plans"],
             resched_step_p50_ms=res_leg["step_p50_ms"],
             resched_recompiled=float(res_leg["recompiled"]
                                      or dup_leg["recompiled"]),
             # fleet A/B under traffic shift: static equal split vs
             # cross-model arbiter, two resident models on one mesh
             fleet_slo_attainment=fleet_arb["fleet_slo_attainment"],
             fleet_slo_attainment_static=fleet_static[
                 "fleet_slo_attainment"],
             fleet_arbiter_moves=fleet_arb["fleet_arbiter_moves"],
             fleet_step_p50_ms=fleet_arb["fleet_step_p50_ms"],
             fleet_chat_attainment=fleet_arb["chat_attainment"],
             fleet_chat_attainment_static=fleet_static["chat_attainment"],
             fleet_recompiled=float(fleet_arb["recompiled"]
                                    or fleet_static["recompiled"]),
             fleet_completed=fleet_arb["fleet_completed"],
             # decode fast path (decode_heavy fused/gather A/B legs);
             # attn_phase_decode_us is the decode-shaped attn kernel
             # phase from the dispatch re-profile above
             **decode_ab,
             attn_phase_decode_us=dec_phases.get("attn", 0.0) * 1e6,
             **{k: float(v) for k, v in audit.summary().items()},
             **{k: float(v) for k, v in eng.accuracy.summary().items()})

    if verbose:
        print(f"trace: {len(trace)} requests over {horizon:.0f}s (virtual), "
              f"served by {end:.1f}s | iterations={eng.iterations}")
        print(f"TTFT   p50={s['ttft_p50']*1e3:7.1f}ms  "
              f"p99={s['ttft_p99']*1e3:7.1f}ms")
        print(f"TPOT  mean={s['tpot_mean']*1e3:7.1f}ms  "
              f"p99={s['tpot_p99']*1e3:7.1f}ms")
        print(f"E2E    p50={s['latency_p50']*1e3:7.1f}ms  "
              f"p99={s['latency_p99']*1e3:7.1f}ms | "
              f"{s['throughput_tok_s']:.0f} tok/s, "
              f"{s['throughput_req_s']:.2f} req/s, "
              f"preemptions={int(s['preemptions'])}")
        print("\nwindow  t_end   skew  imbalance  strategy  "
              "pred_hit  pred_kl")
        for w in eng.metrics.windows:
            hit = f"{w.pred_hit_rate:8.2f}" if w.pred_hit_rate == \
                w.pred_hit_rate else "       -"
            kl = f"{w.pred_kl:7.3f}" if w.pred_kl == w.pred_kl else "      -"
            print(f"  {w.t_end:8.1f}s {w.skew:5.2f}  {w.imbalance:9.2f}  "
                  f"{w.strategy:16s} {hit} {kl}")
        print("\ncontroller switches:")
        for line in controller.switch_log():
            print("  " + line)
        print("\nGPS audit (last 4 verdicts of "
              f"{int(s['gps_verdicts'])}):")
        for line in audit.explain(last=4).splitlines():
            print("  " + line)
        if s.get("pred_windows", 0):
            print(f"\npredictor accuracy: {int(s['pred_windows'])} windows, "
                  f"hit_rate={s['pred_hit_rate']:.2f} "
                  f"kl={s['pred_kl']:.3f} l1={s['pred_l1']:.3f}")
        print(f"\nreplica migration: replans={int(s['migration_replans'])} "
              f"planned={s['migration_planned_bytes'] / 1e6:.2f}MB "
              f"moved={s['migration_bytes_moved'] / 1e6:.2f}MB "
              f"stall={s['migration_stall_us']:.0f}us "
              f"(hidden={s['migration_hidden_s']*1e6:.0f}us / "
              f"exposed={s['migration_exposed_s']*1e6:.0f}us) "
              f"rejected={int(s['migration_rejected'])} "
              f"prebegun={int(s['migration_prebegun'])} "
              f"cancelled={int(s['migration_cancelled'])}")
        print(f"meshed EP smoke: step p50={s['meshed_step_p50_ms']:.0f}ms "
              f"p99={s['meshed_step_p99_ms']:.0f}ms "
              f"(SLO {s['meshed_slo_ms']:.0f}ms -> "
              f"{'OK' if s['meshed_slo_ok'] else 'MISS'}), "
              f"recompiles={int(s['meshed_recompiled'])}, "
              f"completed={int(s['meshed_completed'])}")
        print(f"reschedule lever A/B (capf=0.5): duplicate drops "
              f"{dup_leg['dropped_tokens']:.0f} tok | reschedule drops "
              f"{res_leg['dropped_tokens']:.0f} of "
              f"{res_leg['overflow_tokens']:.0f} overflow "
              f"(absorbed={res_leg['overflow_absorbed_frac']:.2f}, "
              f"a2a={res_leg['resched_a2a_bytes'] / 1e6:.2f}MB, "
              f"plans={res_leg['resched_plans']:.0f}, "
              f"p50 {dup_leg['step_p50_ms']:.0f}ms -> "
              f"{res_leg['step_p50_ms']:.0f}ms)")
        print(f"fleet A/B (traffic shift, 2 models @ 2x4 mesh): "
              f"attainment static={fleet_static['fleet_slo_attainment']:.2f} "
              f"-> arbiter={fleet_arb['fleet_slo_attainment']:.2f} "
              f"(chat {fleet_static['chat_attainment']:.2f} -> "
              f"{fleet_arb['chat_attainment']:.2f}), "
              f"moves={int(fleet_arb['fleet_arbiter_moves'])}, "
              f"chat kv quota {int(fleet_static['chat_kv_quota'])} -> "
              f"{int(fleet_arb['chat_kv_quota'])} of 24, "
              f"dup quota -> {int(fleet_arb['chat_dup_quota'])}, "
              f"step p50={fleet_arb['fleet_step_p50_ms']:.0f}ms, "
              f"recompiles={int(s['fleet_recompiled'])}")
        for line in fleet_arb["moves"]:
            print("  " + line)
        print(f"decode fast path (decode_heavy A/B): "
              f"{decode_ab['decode_toks_per_s']:.0f} decode tok/s, "
              f"roofline fused_vs_gather="
              f"{decode_ab['fused_vs_gather_speedup']:.2f}x "
              f"(alloc/live blocks), "
              f"attn phase decode={s['attn_phase_decode_us']:.0f}us | "
              f"interpret-mode walls (trend only): "
              f"fused={decode_ab['attn_fused_us']:.0f}us "
              f"gather={decode_ab['attn_gather_us']:.0f}us "
              f"ab_ratio={decode_ab['decode_ab_ratio']:.2f}")
        print(f"trace artifact: {trace_path} "
              f"({int(s['trace_events'])} events, "
              f"{'valid' if trace_ok else 'INVALID: ' + '; '.join(errors[:3] + missing)}) | "
              f"gps audit: {audit_path} | "
              f"tracer-off overhead={overhead_frac:.2e} of a meshed step "
              f"(budget {TRACER_OFF_BUDGET_FRAC:.0%})")
        if phases:
            print("\ndispatch phase breakdown (prefill vs decode shape, "
                  f"impl={eng.moe_cfg.dispatch_impl}):")
            total = phases.get("total", 0.0) or 1.0
            for k in ("route", "pack", "a2a", "ffn", "combine"):
                print(f"  {k:8s} {phases[k]*1e6:9.0f}us "
                      f"({100.0 * phases[k] / total:4.1f}%)  "
                      f"decode {dec_phases[k]*1e6:9.0f}us")
            if "attn" in phases:
                print(f"  {'attn':8s} {phases['attn']*1e6:9.0f}us "
                      f"(paged decode kernel, impl="
                      f"{getattr(cfg, 'paged_attn_impl', 'fused')})  "
                      f"decode {dec_phases['attn']*1e6:9.0f}us")
            if "migrate" in phases:
                print(f"  {'migrate':8s} {phases['migrate']*1e6:9.0f}us "
                      "(per plan-switch chunk, not per step)")
            if "prefetch" in phases:
                print(f"  {'prefetch':8s} {phases['prefetch']*1e6:9.0f}us "
                      "(overlapped-fill issue cost on the critical path)")

    assert n_completed == len(trace), (n_completed, len(trace))
    if not smoke:
        assert n_switches >= 1, "controller never switched strategy"
    assert len(audit) >= 1, "GPS audit log recorded no verdicts"
    assert s.get("pred_windows", 0) >= 1, \
        "predictor-accuracy tracker scored no windows"
    assert trace_ok == 1.0, \
        f"trace artifact invalid: {errors[:5]} missing={missing}"
    assert overhead_frac < TRACER_OFF_BUDGET_FRAC, (
        f"disabled tracer costs {overhead_frac:.1%} of a meshed step "
        f"(budget {TRACER_OFF_BUDGET_FRAC:.0%})")
    # the combined strategy space's acceptance: under identical capacity
    # pressure the reschedule lever beats duplicate-only — it sees real
    # overflow yet drops nothing, where the duplicate leg drops tokens
    assert dup_leg["dropped_tokens"] > 0, \
        "duplicate leg saw no drops — capacity pressure recipe broken"
    assert res_leg["overflow_tokens"] > 0, \
        "reschedule leg saw no overflow — lever never engaged"
    assert res_leg["dropped_tokens"] == 0.0, (
        f"reschedule lever dropped {res_leg['dropped_tokens']:.0f} of "
        f"{res_leg['overflow_tokens']:.0f} overflow tokens")
    assert s["resched_recompiled"] == 0.0, \
        "lever A/B legs recompiled after warmup"
    # decode fast path acceptance: both A/B legs must finish the whole
    # decode-heavy trace, the fused leg must show real decode throughput,
    # and the roofline ratio is structurally >= 1.0 (the gather view can
    # never cover fewer blocks than are live)
    assert decode_ab["decode_completed"] \
        == decode_ab["decode_trace_requests"] \
        == decode_ab["decode_completed_gather"], decode_ab
    assert decode_ab["decode_toks_per_s"] > 0, \
        "decode_heavy trace produced no pure-decode iterations"
    assert decode_ab["fused_vs_gather_speedup"] >= 1.0, (
        f"attention roofline ratio "
        f"{decode_ab['fused_vs_gather_speedup']:.3f} < 1.0 — live-block "
        f"accounting is broken")
    # fleet A/B acceptance: the static equal split must visibly violate
    # the hot tenant's SLO, the arbiter leg must commit >= 1 move and
    # recover attainment, and neither leg may recompile after warmup
    assert fleet_static["chat_attainment"] < 0.9, (
        f"static split never starved the chat tenant "
        f"(attainment {fleet_static['chat_attainment']:.2f}) — the fleet "
        f"A/B pressure recipe is broken")
    assert fleet_arb["fleet_arbiter_moves"] >= 1, \
        "arbiter committed no moves under a sustained traffic shift"
    assert fleet_arb["fleet_slo_attainment"] \
        > fleet_static["fleet_slo_attainment"], (
        f"arbiter leg did not beat the static split: "
        f"{fleet_arb['fleet_slo_attainment']:.2f} vs "
        f"{fleet_static['fleet_slo_attainment']:.2f}")
    assert fleet_static["drained"] and fleet_arb["drained"], fleet_ab
    assert s["fleet_recompiled"] == 0.0, \
        "fleet legs recompiled after warmup — a quota move changed shapes"

    derived = (f"completed={n_completed}/{len(trace)} "
               f"switches={n_switches} "
               f"verdicts={int(s['gps_verdicts'])} "
               f"pred_hit={s.get('pred_hit_rate', float('nan')):.2f} "
               f"ttft_p99={s['ttft_p99']*1e3:.0f}ms "
               f"tpot_p99={s['tpot_p99']*1e3:.0f}ms "
               f"meshed_p50={s['meshed_step_p50_ms']:.0f}ms "
               f"resched_absorbed={s['overflow_absorbed_frac']:.2f} "
               f"decode_tok_s={s['decode_toks_per_s']:.0f} "
               f"attn_roofline={s['fused_vs_gather_speedup']:.2f}x "
               f"fleet_slo={s['fleet_slo_attainment_static']:.2f}->"
               f"{s['fleet_slo_attainment']:.2f} "
               f"(moves={int(s['fleet_arbiter_moves'])})")
    return s, derived


if __name__ == "__main__":
    run(verbose=True)
