"""Fig 7: Distribution-Only saving minus best Token-to-Expert saving,
across interconnect bandwidths (600/200/64/16 GB/s) x skews. Bars above
zero: Distribution-Only wins; high skew + slow links flip the sign.
Plus the TPU adaptation: ICI (90 GB/s effective) vs DCN (6 GB/s).
"""

from __future__ import annotations

from repro.configs.registry import get_config
from repro.core.gps import run_gps
from repro.core.simulator import A100_NVLINK, TPU_V5E_DCN, TPU_V5E_POD

MIX = get_config("mixtral-8x7b")
BWS = (600e9, 200e9, 64e9, 16e9)
SKEWS = (1.4, 2.0, 3.0)


def run(verbose: bool = True):
    rows = []
    if verbose:
        print(f"{'link GB/s':>10s} " +
              " ".join(f"skew {s:<6.1f}" for s in SKEWS) +
              "   (saving diff: >0 => Distribution-Only wins)")
    for bw in BWS:
        hw = A100_NVLINK.with_(name=f"4xA100-{bw/1e9:.0f}GBs", link_bw=bw)
        diffs = []
        for skew in SKEWS:
            rep = run_gps(MIX, hw, batch=1, seq=512, skew=skew)
            diffs.append(rep.saving_difference)
            rows.append(dict(link_gbs=bw / 1e9, skew=skew,
                             saving_diff=round(rep.saving_difference, 4),
                             dist_only_saving=round(rep.dist_only_saving, 4),
                             t2e_saving=round(rep.t2e_saving, 4)))
        if verbose:
            print(f"{bw/1e9:10.0f} " +
                  " ".join(f"{d:+10.1%}" for d in diffs))
    for hw in (TPU_V5E_POD, TPU_V5E_DCN):
        diffs = []
        for skew in SKEWS:
            rep = run_gps(MIX, hw, batch=8, seq=2048, skew=skew)
            diffs.append(rep.saving_difference)
            rows.append(dict(link_gbs=hw.link_bw / 1e9, skew=skew, hw=hw.name,
                             saving_diff=round(rep.saving_difference, 4)))
        if verbose:
            print(f"{hw.name:>10s} " + " ".join(f"{d:+10.1%}" for d in diffs))
    # derived: monotonicity — saving_diff at (600 GB/s, skew1.4) minus at
    # (16 GB/s, skew3.0): positive means the Fig-7 trend is reproduced
    hi = next(r for r in rows if r["link_gbs"] == 600 and r["skew"] == 1.4)
    lo = next(r for r in rows if r["link_gbs"] == 16 and r["skew"] == 3.0)
    return rows, hi["saving_diff"] - lo["saving_diff"]


if __name__ == "__main__":
    run()
