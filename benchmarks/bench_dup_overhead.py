"""Sec 5: expert-duplication weight-movement overhead vs attention-layer
time — when can the move be hidden? Sweeps batch x seq on the paper's
A100 links and the TPU target, reporting the hide/no-hide crossover.
"""

from __future__ import annotations

from repro.configs.registry import get_config
from repro.core.simulator import (A100_NVLINK, A100_PCIE, TPU_V5E_POD,
                                  duplication_move_time, layer_latency)

MIX = get_config("mixtral-8x7b")
HWS = [A100_NVLINK.with_(name="A100-NVLink3-2TBs", link_bw=2e12),  # paper's
       A100_NVLINK, A100_PCIE, TPU_V5E_POD]
SIZES = [(1, 512), (16, 2048), (64, 2048), (32, 8192)]


def run(verbose: bool = True):
    rows = []
    if verbose:
        print(f"{'hardware':>20s} {'move ms':>8s} " +
              " ".join(f"B{b}xS{s}" for b, s in SIZES) +
              "   (v = hidden under attention)")
    for hw in HWS:
        move = duplication_move_time(MIX, hw)
        marks = []
        for b, s in SIZES:
            attn = layer_latency(MIX, hw, batch=b, seq=s, skew=1.0).attention
            hidden = move <= attn
            marks.append("v" if hidden else "x")
            rows.append(dict(hw=hw.name, batch=b, seq=s,
                             move_ms=round(move * 1e3, 3),
                             attn_ms=round(attn * 1e3, 3), hidden=hidden))
        if verbose:
            print(f"{hw.name:>20s} {move*1e3:8.3f} " +
                  "      ".join(marks))
    if verbose:
        print("\nNote: the paper (Sec 5, no-FlashAttention simulator) finds "
              "PCIe hideable at B16xS2048; our flash-style attention model "
              "needs ~4x more tokens — recorded in EXPERIMENTS.md.")
    hidden_count = sum(r["hidden"] for r in rows)
    return rows, hidden_count


if __name__ == "__main__":
    run()
