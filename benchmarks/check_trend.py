"""CI per-metric perf-reference gate + trend report (ReFrame-style).

Usage:
  PYTHONPATH=src python -m benchmarks.check_trend BENCH_smoke.json \
      [--references benchmarks/references.json] \
      [--history benchmarks/history.jsonl] [--last 8] [--markdown OUT.md]

Replaces the old single >25%-total-wall-time tolerance: every metric
named in ``benchmarks/references.json`` is gated against its own
``[ref, lower_tol, upper_tol]`` band (null = that side unbounded;
``repro.sweep.references`` documents the format), structurally empty
documents fail loudly, and the trend database is scanned for monotonic
drift across the last N entries (reported, not gated — drift inside the
band is a warning, not a regression).

``--markdown`` writes the gate table + trend table as markdown (CI
appends it to the GitHub Actions job summary).

Refresh path: REPRO_BENCH_REFRESH_REFERENCES=1 rewrites references.json
from the current document using per-metric-class default tolerances
(commit the refreshed file). Refreshing from an empty document is
refused.
"""

from __future__ import annotations

import json
import os
import sys


def _import_sweep():
    try:
        from repro.sweep import history, references, report
    except ImportError as e:
        print(f"cannot import repro.sweep ({e}); run with PYTHONPATH=src",
              file=sys.stderr)
        raise SystemExit(2)
    return history, references, report


def main(argv=None) -> int:
    history, references, report_mod = _import_sweep()
    argv = list(sys.argv[1:] if argv is None else argv)
    refs_path = os.path.join(os.path.dirname(__file__), "references.json")
    history_path = os.path.join(os.path.dirname(__file__), "history.jsonl")
    last_n, md_path = 8, ""
    for flag, setter in (("--references", "refs"), ("--history", "hist"),
                         ("--last", "last"), ("--markdown", "md")):
        if flag in argv:
            i = argv.index(flag)
            try:
                val = argv[i + 1]
            except IndexError:
                print(f"{flag} requires an argument", file=sys.stderr)
                return 2
            if setter == "refs":
                refs_path = val
            elif setter == "hist":
                history_path = val
            elif setter == "last":
                last_n = int(val)
            else:
                md_path = val
            del argv[i:i + 2]
    if len(argv) != 1:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        current = json.load(f)

    if os.environ.get("REPRO_BENCH_REFRESH_REFERENCES") == "1":
        refs = references.refresh_references(current)
        with open(refs_path, "w") as f:
            json.dump(refs, f, indent=2)
        n = sum(len(v) for v in refs["benches"].values()) + 1
        print(f"references refreshed from {argv[0]} -> {refs_path} "
              f"({n} metric bands; commit the updated file)")
        return 0

    if not os.path.exists(refs_path):
        print(f"no references at {refs_path}; run with "
              "REPRO_BENCH_REFRESH_REFERENCES=1 to create them",
              file=sys.stderr)
        return 2
    with open(refs_path) as f:
        refs = json.load(f)

    failures, checked = references.gate_document(current, refs)
    if checked == 0:
        failures.append("references file declares zero metric bands")

    entries = history.load_history(history_path)
    smap = history.series(entries)
    warns = report_mod.drift_warnings(smap, last_n=last_n)

    print(f"per-metric reference gate: {checked} bands checked, "
          f"{len(failures)} violations, {len(warns)} drift warnings "
          f"({len(entries)} history entries)")
    for w in warns:
        print(f"  drift: {w}")

    if md_path:
        lines = ["## Perf-reference gate",
                 f"_{checked} metric bands checked against "
                 f"`{os.path.basename(refs_path)}`_", ""]
        if failures:
            lines.append("**GATE FAILED:**")
            lines += [f"- ❌ {m}" for m in failures]
        else:
            lines.append("✅ every metric inside its reference band")
        lines += ["", report_mod.render_report(
            history_path, refs_path, last_n=last_n)]
        with open(md_path, "w") as f:
            f.write("\n".join(lines) + "\n")
        print(f"wrote {md_path}")

    if failures:
        print("\nPER-METRIC REFERENCE GATE FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        print("  (deliberate change? refresh with "
              "REPRO_BENCH_REFRESH_REFERENCES=1 and commit "
              "references.json)", file=sys.stderr)
        return 1
    print("per-metric reference gate: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
