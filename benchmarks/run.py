"""Benchmark harness: one entry per paper table/figure + serving traces.

Usage: PYTHONPATH=src python -m benchmarks.run [--smoke] [name ...]

Prints a ``name,us_per_call,derived`` CSV line per benchmark, where
``derived`` is the benchmark's key reproduced quantity (see each module).

``--smoke``: seconds-scale configurations (exported to the bench modules
via ``REPRO_BENCH_SMOKE=1``) so CI can exercise every benchmark end to
end without reproducing the full figures.
"""

from __future__ import annotations

import os
import sys
import time


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--smoke" in argv:
        argv.remove("--smoke")
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    # import AFTER the env flag so modules can read it at import time too
    from benchmarks import (bench_appendix_c, bench_dup_overhead, bench_fig4,
                            bench_fig6, bench_fig7, bench_runtime_balance,
                            bench_serve_traces, bench_table1)
    benches = {
        "table1_skew_vs_error": bench_table1.run,
        "fig4_accuracy_overhead_perf": bench_fig4.run,
        "fig6_latency_breakdown": bench_fig6.run,
        "fig7_savings_vs_interconnect": bench_fig7.run,
        "sec5_duplication_overhead": bench_dup_overhead.run,
        "runtime_measured_balance": bench_runtime_balance.run,
        "appendix_c_generality": bench_appendix_c.run,
        "serve_traces_continuous": bench_serve_traces.run,
    }

    names = argv or list(benches)
    unknown = [n for n in names if n not in benches]
    if unknown:
        print(f"unknown benchmark(s): {', '.join(unknown)}", file=sys.stderr)
        print(f"available: {', '.join(benches)}", file=sys.stderr)
        return 2
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        fn = benches[name]
        t0 = time.time()
        try:
            _, derived = fn(verbose=True)
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},{derived}")
        except Exception as e:      # keep the harness going
            failures += 1
            print(f"{name},FAILED,{type(e).__name__}: {e}")
        sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
