"""Benchmark harness: one entry per paper table/figure + serving traces.

Usage: PYTHONPATH=src python -m benchmarks.run [--smoke] [--json PATH] [name ...]

Prints a ``name,us_per_call,derived`` CSV line per benchmark, where
``derived`` is the benchmark's key reproduced quantity (see each module).

``--smoke``: seconds-scale configurations (exported to the bench modules
via ``REPRO_BENCH_SMOKE=1``) so CI can exercise every benchmark end to
end without reproducing the full figures.

``--json PATH``: additionally write a machine-readable result document
shared by all benches (the schema the CI bench-regression gate and the
BENCH_* trajectory tracking consume):

  {"schema": 2, "smoke": bool, "total_wall_s": float,
   "meta": {"git_sha": str, "timestamp_utc": str, "jax_version": str,
            "backend": str, "device_kind": str, "device_count": int,
            "python": str},
   "benches": {name: {"wall_us": float, "ok": bool, "derived": str,
                      "summary": {metric: number, ...} | null}}}

Benches whose ``run()`` returns a dict of scalars as its first element get
that dict embedded as ``summary``. ``benchmarks/bench_dispatch`` also
emits its own ``BENCH_dispatch.json`` phase-breakdown artifact.

``--history PATH``: append one JSONL line (``kind: "bench"``; meta +
total wall + per-bench wall/ok/summary metrics) per run — the trend
database ``repro.sweep.history`` reads back as per-(bench, metric,
config-key) series and ``benchmarks/check_trend.py`` scans for drift
(CI appends to ``benchmarks/history.jsonl`` and uploads it; sweep jobs
append ``kind: "sweep"`` lines to the same file).
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
import time
from datetime import datetime, timezone


def _scalar_summary(obj):
    """First element of a bench's return value, kept only if it is a flat
    dict of JSON-safe scalars (the shared schema stores metrics, not blobs)."""
    if not isinstance(obj, dict):
        return None
    out = {}
    for k, v in obj.items():
        if isinstance(v, bool) or not isinstance(v, (int, float, str)):
            return None
        out[str(k)] = v
    return out


def run_meta() -> dict:
    """Provenance for a result document: without the commit + software +
    device identity a BENCH_*.json number cannot be compared across runs."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    meta = {
        "git_sha": sha,
        "timestamp_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "python": platform.python_version(),
    }
    try:
        import jax
        dev = jax.devices()[0]
        meta.update(jax_version=jax.__version__,
                    backend=jax.default_backend(),
                    device_kind=dev.device_kind,
                    device_count=jax.device_count())
    except Exception as e:                       # keep the harness going
        meta.update(jax_version="unavailable", backend=str(e)[:80],
                    device_kind="unknown", device_count=0)
    return meta


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--smoke" in argv:
        argv.remove("--smoke")
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            print("--json requires a PATH argument", file=sys.stderr)
            return 2
        del argv[i:i + 2]
    history_path = None
    if "--history" in argv:
        i = argv.index("--history")
        try:
            history_path = argv[i + 1]
        except IndexError:
            print("--history requires a PATH argument", file=sys.stderr)
            return 2
        del argv[i:i + 2]

    # import AFTER the env flag so modules can read it at import time too
    from benchmarks import (bench_appendix_c, bench_dispatch,
                            bench_dup_overhead, bench_fig4, bench_fig6,
                            bench_fig7, bench_migration,
                            bench_runtime_balance, bench_serve_traces,
                            bench_table1)
    benches = {
        "table1_skew_vs_error": bench_table1.run,
        "fig4_accuracy_overhead_perf": bench_fig4.run,
        "fig6_latency_breakdown": bench_fig6.run,
        "fig7_savings_vs_interconnect": bench_fig7.run,
        "sec5_duplication_overhead": bench_dup_overhead.run,
        "runtime_measured_balance": bench_runtime_balance.run,
        "appendix_c_generality": bench_appendix_c.run,
        "serve_traces_continuous": bench_serve_traces.run,
        "dispatch_phase_breakdown": bench_dispatch.run,
        "migration_store_vs_gather": bench_migration.run,
    }

    names = argv or list(benches)
    unknown = [n for n in names if n not in benches]
    if unknown:
        print(f"unknown benchmark(s): {', '.join(unknown)}", file=sys.stderr)
        print(f"available: {', '.join(benches)}", file=sys.stderr)
        return 2
    print("name,us_per_call,derived")
    failures = 0
    records = {}
    t_all = time.time()
    for name in names:
        fn = benches[name]
        t0 = time.time()
        try:
            first, derived = fn(verbose=True)
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},{derived}")
            records[name] = {"wall_us": us, "ok": True,
                             "derived": str(derived),
                             "summary": _scalar_summary(first)}
        except Exception as e:      # keep the harness going
            failures += 1
            print(f"{name},FAILED,{type(e).__name__}: {e}")
            records[name] = {"wall_us": (time.time() - t0) * 1e6, "ok": False,
                             "derived": f"{type(e).__name__}: {e}",
                             "summary": None}
        sys.stdout.flush()
    meta = run_meta()
    total_wall_s = time.time() - t_all
    if json_path:
        doc = {
            "schema": 2,
            "smoke": os.environ.get("REPRO_BENCH_SMOKE") == "1",
            "total_wall_s": total_wall_s,
            "meta": meta,
            "benches": records,
        }
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {json_path}")
    if history_path:
        from repro.sweep.history import append_entry, bench_history_entry
        doc = {"smoke": os.environ.get("REPRO_BENCH_SMOKE") == "1",
               "total_wall_s": total_wall_s, "meta": meta,
               "benches": records}
        append_entry(history_path, bench_history_entry(doc))
        print(f"appended history to {history_path}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
