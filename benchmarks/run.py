"""Benchmark harness: one entry per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [name ...]

Prints a ``name,us_per_call,derived`` CSV line per benchmark, where
``derived`` is the benchmark's key reproduced quantity (see each module).
"""

from __future__ import annotations

import sys
import time

from benchmarks import (bench_appendix_c, bench_dup_overhead, bench_fig4,
                        bench_fig6, bench_fig7, bench_runtime_balance,
                        bench_table1)

BENCHES = {
    "table1_skew_vs_error": bench_table1.run,
    "fig4_accuracy_overhead_perf": bench_fig4.run,
    "fig6_latency_breakdown": bench_fig6.run,
    "fig7_savings_vs_interconnect": bench_fig7.run,
    "sec5_duplication_overhead": bench_dup_overhead.run,
    "runtime_measured_balance": bench_runtime_balance.run,
    "appendix_c_generality": bench_appendix_c.run,
}


def main(argv=None) -> int:
    names = (argv or sys.argv[1:]) or list(BENCHES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        fn = BENCHES[name]
        t0 = time.time()
        try:
            _, derived = fn(verbose=True)
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},{derived}")
        except Exception as e:      # keep the harness going
            failures += 1
            print(f"{name},FAILED,{type(e).__name__}: {e}")
        sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
