"""Runtime validation (beyond the simulator): measured per-rank token
loads in the REAL EP dispatch, no-prediction vs Distribution-Only, on an
8-fake-device mesh. The simulator's load factors (skew -> 1+eps) must show
up in actual slot counts. Runs as a subprocess (device-count isolation).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.registry import get_config
from repro.models.transformer import init_model
from repro.serve import ServeEngine, ServeConfig
from repro.data.synthetic import token_batches

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("mixtral-8x7b").reduced()
params = init_model(jax.random.PRNGKey(0), cfg)
out = {}
for strat in ("none", "dist_only"):
    eng = ServeEngine(cfg, params, ServeConfig(strategy=strat, dup_slots=1),
                      mesh=mesh, ep_ranks=4)
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    batch, seq, iters = (4, 32, 3) if smoke else (8, 64, 5)
    gen = token_batches(0, cfg.vocab_size, batch=batch, seq_len=seq)
    for i in range(iters):
        _, _, stats = eng.prefill({"tokens": jnp.asarray(next(gen)["tokens"])})
    rl = eng.rank_loads(np.asarray(stats["slot_counts"]))
    out[strat] = {
        "bottleneck_over_mean": float((rl.max(1) / rl.mean(1)).mean()),
        "routing_skew": eng.history[-1]["skew"],
    }
print(json.dumps(out))
"""


def run(verbose: bool = True):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_BODY)],
        capture_output=True, text=True, timeout=1200,
        env=dict(os.environ, PYTHONPATH=os.path.join(root, "src")))
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    data = json.loads(res.stdout.strip().splitlines()[-1])
    if verbose:
        for k, v in data.items():
            print(f"{k:10s}: measured rank bottleneck/mean = "
                  f"{v['bottleneck_over_mean']:.3f} "
                  f"(routing skew {v['routing_skew']:.2f})")
        print("(duplication moves the bottleneck toward 1.0 = balanced)")
    derived = (data["none"]["bottleneck_over_mean"]
               - data["dist_only"]["bottleneck_over_mean"])
    rows = [dict(strategy=k, **v) for k, v in data.items()]
    return rows, derived


if __name__ == "__main__":
    run()
